"""L2 model checks: shapes, determinism, numeric sanity for the family
the live plane serves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def rng_img():
    return jax.random.randint(
        jax.random.PRNGKey(7), (M.RAW_H, M.RAW_W, 3), 0, 256
    ).astype(jnp.uint8)


@pytest.mark.parametrize("name", list(M.MODEL_BUILDERS))
@pytest.mark.parametrize("batch", [1, 2])
def test_serving_shapes(name, batch):
    fn, specs, meta = M.serving_fn(name, batch)
    assert specs[0].shape == (batch, *meta.input_shape)
    out = fn(jnp.zeros(specs[0].shape, jnp.float32))[0]
    assert out.shape == (batch, *meta.output_shape)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("name", list(M.MODEL_BUILDERS))
def test_outputs_finite_and_nonconstant(name):
    fn, specs, _ = M.serving_fn(name, 1)
    x = jax.random.normal(jax.random.PRNGKey(0), specs[0].shape)
    out = np.asarray(fn(x)[0])
    assert np.isfinite(out).all()
    assert out.std() > 0, "degenerate constant output"


@pytest.mark.parametrize("name", list(M.MODEL_BUILDERS))
def test_weights_deterministic(name):
    """Two builds bake identical weights — artifacts are reproducible."""
    fn1, specs, _ = M.serving_fn(name, 1)
    fn2, _, _ = M.serving_fn(name, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), specs[0].shape)
    np.testing.assert_array_equal(np.asarray(fn1(x)[0]), np.asarray(fn2(x)[0]))


def test_batch_consistency():
    """A batched executable must equal per-item execution (batcher
    correctness depends on this)."""
    fn1, _, _ = M.serving_fn("tiny_resnet", 1)
    fn4, _, _ = M.serving_fn("tiny_resnet", 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, M.IN_H, M.IN_W, 3))
    batched = np.asarray(fn4(x)[0])
    single = np.concatenate([np.asarray(fn1(x[i : i + 1])[0]) for i in range(4)])
    np.testing.assert_allclose(batched, single, rtol=1e-4, atol=1e-5)


def test_preprocess_shape_and_range(rng_img):
    fn, specs, meta = M.preprocess_fn()
    out = np.asarray(fn(rng_img)[0])
    assert out.shape == (1, M.IN_H, M.IN_W, 3)
    # ImageNet normalization of [0,1] pixels stays within ~[-3, 3].
    assert out.min() > -4 and out.max() < 4


def test_raw_path_equals_two_stage(rng_img):
    """Fused raw executable == preprocess artifact + preprocessed model."""
    raw_fn, _, _ = M.raw_serving_fn("tiny_mobilenet")
    pre_fn, _, _ = M.preprocess_fn()
    cls_fn, _, _ = M.serving_fn("tiny_mobilenet", 1)
    fused = np.asarray(raw_fn(rng_img)[0])
    staged = np.asarray(cls_fn(pre_fn(rng_img)[0])[0])
    np.testing.assert_allclose(fused, staged, rtol=1e-5, atol=1e-6)


def test_gflops_ordering():
    """The family preserves Table II's compute ordering: mobilenet is the
    smallest, segnet (per-pixel head) the largest."""
    metas = {n: M.MODEL_BUILDERS[n]()[1] for n in M.MODEL_BUILDERS}
    assert metas["tiny_mobilenet"].gflops < metas["tiny_resnet"].gflops
    assert metas["tiny_resnet"].gflops < metas["tiny_segnet"].gflops


def test_segnet_output_is_large_io():
    """tiny_segnet mirrors DeepLabV3's response-dominated I/O profile."""
    _, _, meta = M.serving_fn("tiny_segnet", 1)
    out_bytes = int(np.prod(meta.output_shape)) * 4
    in_bytes = int(np.prod(meta.input_shape)) * 4
    assert out_bytes > in_bytes
