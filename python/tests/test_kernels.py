"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and tiling configurations) so the padded /
tile-boundary paths of the kernels are exercised, not just the happy
multiples-of-128 case.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as kconv
from compile.kernels import matmul as kmm
from compile.kernels import preprocess as kpre
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

dims = st.integers(min_value=1, max_value=70)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ----------------------------------------------------------------- matmul


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    y = jax.random.normal(ky, (k, n))
    np.testing.assert_allclose(
        kmm.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
    )


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    bm=st.integers(1, 16),
    bn=st.integers(1, 16),
    bk=st.integers(1, 16),
)
def test_matmul_any_tiling(m, k, n, bm, bn, bk):
    """The kernel is exact for *every* tile choice, not just divisors."""
    x = _rand(10, (m, k))
    y = _rand(11, (k, n))
    got = kmm.matmul(x, y, bm=min(bm, m), bn=min(bn, n), bk=min(bk, k))
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_large_tile_path():
    x = _rand(0, (256, 256))
    y = _rand(1, (256, 128))
    np.testing.assert_allclose(
        kmm.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kmm.matmul(jnp.ones((2, 3)), jnp.ones((4, 5)))
    with pytest.raises(ValueError):
        kmm.matmul(jnp.ones((2, 3, 4)), jnp.ones((4, 5)))


def test_largest_tile_divides():
    for dim in (1, 7, 64, 100, 1000, 1024, 129):
        t = kmm._largest_tile(dim)
        assert dim % t == 0 and 1 <= t <= 128


# ----------------------------------------------------------------- linear


@given(
    m=st.integers(1, 32),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref(m, k, n, act, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n))
    b = jax.random.normal(ks[2], (n,))
    np.testing.assert_allclose(
        kmm.linear(x, w, b, activation=act),
        ref.linear_ref(x, w, b, activation=act),
        rtol=1e-5,
        atol=1e-5,
    )


def test_linear_relu_is_nonnegative():
    x, w = _rand(3, (8, 8)), _rand(4, (8, 8))
    out = kmm.linear(x, w, jnp.zeros((8,)), activation="relu")
    assert (np.asarray(out) >= 0).all()


def test_linear_rejects_unknown_activation():
    with pytest.raises(ValueError):
        kmm.linear(jnp.ones((2, 2)), jnp.ones((2, 2)), jnp.ones((2,)), activation="gelu")


# ----------------------------------------------------------------- conv2d


@given(
    n=st.integers(1, 3),
    h=st.integers(4, 20),
    c_in=st.integers(1, 8),
    c_out=st.integers(1, 8),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, h, c_in, c_out, k, stride, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (n, h, h, c_in))
    w = jax.random.normal(ks[1], (k, k, c_in, c_out))
    np.testing.assert_allclose(
        kconv.conv2d(x, w, stride=stride),
        ref.conv2d_ref(x, w, stride=stride),
        rtol=1e-4,
        atol=1e-4,
    )


def test_conv2d_valid_padding():
    x = _rand(5, (1, 8, 8, 4))
    w = _rand(6, (3, 3, 4, 2))
    np.testing.assert_allclose(
        kconv.conv2d(x, w, padding="VALID"),
        ref.conv2d_ref(x, w, padding="VALID"),
        rtol=1e-4,
        atol=1e-4,
    )


def test_conv2d_rejects_channel_mismatch():
    with pytest.raises(ValueError):
        kconv.conv2d(jnp.ones((1, 4, 4, 3)), jnp.ones((3, 3, 5, 2)))


# ------------------------------------------------------------- preprocess


@given(
    h=st.sampled_from([8, 32, 64]),
    w=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_normalize_matches_ref(h, w, seed):
    img = jax.random.randint(jax.random.PRNGKey(seed), (h, w, 3), 0, 256).astype(
        jnp.uint8
    )
    np.testing.assert_allclose(
        kpre.normalize(img), ref.normalize_ref(img), rtol=1e-5, atol=1e-6
    )


def test_normalize_extremes():
    lo = jnp.zeros((16, 16, 3), jnp.uint8)
    hi = jnp.full((16, 16, 3), 255, jnp.uint8)
    np.testing.assert_allclose(kpre.normalize(lo), ref.normalize_ref(lo), atol=1e-6)
    np.testing.assert_allclose(kpre.normalize(hi), ref.normalize_ref(hi), atol=1e-6)


def test_normalize_rejects_bad_shape():
    with pytest.raises(ValueError):
        kpre.normalize(jnp.zeros((16, 16), jnp.uint8))


# -------------------------------------------------- perf-model estimators


def test_vmem_bytes_fits_tpu_vmem():
    # Default MXU tiles must fit comfortably in the ~16 MiB/core VMEM.
    assert kmm.vmem_bytes(128, 128, 128) < 16 * 1024 * 1024 / 4


def test_mxu_utilization_bounds():
    for args in [(128, 128, 128), (100, 100, 100), (1, 1000, 64)]:
        m, n, k = args
        bm, bn, bk = (
            kmm._largest_tile(m),
            kmm._largest_tile(n),
            kmm._largest_tile(k),
        )
        u = kmm.mxu_utilization(m, n, k, bm, bn, bk)
        assert 0.0 < u <= 1.0
    # Perfectly tiled full-MXU case is 100 % useful.
    assert kmm.mxu_utilization(256, 256, 256, 128, 128, 128) == pytest.approx(1.0)
