"""AOT path checks: HLO text well-formedness, manifest consistency, and a
python-side round-trip (HLO text -> xla_client compile -> execute) that
mirrors exactly what the rust runtime does with the same bytes."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ART, "manifest.json"))


def test_to_hlo_text_wellformed():
    fn, specs, _ = M.serving_fn("tiny_mobilenet", 1)
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "ROOT" in text
    # The kernels must have lowered to plain HLO (interpret mode), never
    # to a Mosaic custom-call the CPU PJRT client can't execute.
    assert "tpu_custom_call" not in text and "mosaic" not in text.lower()


def test_registry_covers_models_and_batches():
    reg = aot._registry()
    assert "preprocess" in reg
    for name in M.MODEL_BUILDERS:
        for b in (1, 2, 4, 8):
            assert f"{name}_b{b}" in reg
        assert f"{name}_raw" in reg


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
def test_manifest_matches_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] == 1
    assert len(man["artifacts"]) >= 4
    for a in man["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        assert os.path.getsize(path) == a["hlo_bytes"]
        assert a["output"]["dtype"] == "f32"


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
def test_hlo_text_parses_back():
    """The emitted HLO text must parse back into an HLO module whose entry
    signature matches the manifest — the structural half of the contract
    the rust runtime relies on (the numeric half is covered by the rust
    integration tests that execute the same bytes via PJRT)."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    entry = next(a for a in man["artifacts"] if a["name"] == "tiny_mobilenet_b1")
    with open(os.path.join(ART, entry["file"])) as f:
        text = f.read()
    mod = xc._xla.hlo_module_from_text(text)
    rendered = mod.to_string()
    assert "ENTRY" in rendered
    # Parameter and root shapes in the rendered entry must match the
    # manifest. return_tuple=True wraps the output in a 1-tuple.
    in_shape = ",".join(str(d) for d in entry["inputs"][0]["shape"])
    out_shape = ",".join(str(d) for d in entry["output"]["shape"])
    assert f"f32[{in_shape}]" in rendered
    assert f"f32[{out_shape}]" in rendered
    # Round-trip is lossless enough to re-serialize.
    assert len(mod.as_serialized_hlo_module_proto()) > 0
