"""AOT compile path: lower every live-plane serving executable to HLO
*text* plus a JSON manifest consumed by the rust runtime.

HLO text (NOT ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 crate) rejects with ``proto.id() <= INT_MAX``;
the HLO text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and README gotchas.

Run via ``make artifacts`` (from python/): python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M

# Every artifact the runtime may load: (artifact name, builder thunk).
# Batched variants give the rust dynamic batcher one compiled executable
# per (model, batch) pair — the PJRT analogue of TensorRT profiles.
def _registry():
    entries = {}
    entries["preprocess"] = M.preprocess_fn
    for name in M.MODEL_BUILDERS:
        for batch in (1, 2, 4, 8):
            entries[f"{name}_b{batch}"] = (
                lambda name=name, batch=batch: M.serving_fn(name, batch)
            )
        entries[f"{name}_raw"] = lambda name=name: M.raw_serving_fn(name)
    return entries


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "uint8": "u8", "int32": "i32"}.get(str(dt), str(dt))


def lower_one(name: str, builder, out_dir: str) -> dict:
    fn, specs, meta = builder()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_aval = jax.eval_shape(fn, *specs)[0]
    entry = {
        "name": name,
        "model": meta.name,
        "task": meta.task,
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)} for s in specs
        ],
        "output": {
            "shape": list(out_aval.shape),
            "dtype": _dtype_name(out_aval.dtype),
        },
        "gflops": meta.gflops,
        "params": meta.params,
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        "hlo_bytes": len(text),
    }
    print(f"  {name}: {len(text)} chars -> {path}")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact names (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    reg = _registry()
    names = args.only.split(",") if args.only else list(reg)
    unknown = [n for n in names if n not in reg]
    if unknown:
        print(f"unknown artifacts: {unknown}", file=sys.stderr)
        sys.exit(2)

    # With --only, merge into the existing manifest rather than dropping
    # entries for artifacts we did not rebuild.
    mpath = os.path.join(args.out_dir, "manifest.json")
    manifest = {"format": 1, "jax": jax.__version__, "artifacts": []}
    if args.only and os.path.exists(mpath):
        with open(mpath) as f:
            prev = json.load(f)
        manifest["artifacts"] = [
            a for a in prev.get("artifacts", []) if a["name"] not in names
        ]
    for name in names:
        manifest["artifacts"].append(lower_one(name, reg[name], args.out_dir))
    manifest["artifacts"].sort(key=lambda a: a["name"])
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
