"""L2: the accelserve live-plane model family, written in JAX over the
L1 Pallas kernels.

The paper serves six TensorRT CNNs (Table II). The live plane cannot run
those on a CPU-only PJRT client at serving latency, so it serves a
*scaled-down family with the same I/O archetypes* (DESIGN.md §1):

    tiny_mobilenet — small classifier, tiny compute, small I/O
    tiny_resnet    — residual classifier, the mid-size workhorse
    tiny_segnet    — encoder/decoder, per-pixel output => large response
                     (the DeepLabV3 archetype whose response dominates)

plus the standalone ``preprocess`` graph (raw uint8 camera frame ->
normalized NHWC f32 tensor) that mirrors the paper's server-side
preprocessing stage.

Weights are initialized from a fixed seed and closed over, so they lower
to HLO constants: each artifact is a self-contained serving executable.
Python never runs on the request path; rust loads the lowered HLO text.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import matmul as kmm
from .kernels import preprocess as kpre

# Raw camera frame submitted by clients (the paper's "raw images").
RAW_H, RAW_W = 64, 64
# Model input resolution after preprocessing.
IN_H, IN_W = 32, 32
NUM_CLASSES = 1000  # classification head, mirroring Table II
SEG_CLASSES = 21  # DeepLabV3's COCO-21 head, mirroring Table II


# --------------------------------------------------------------------------
# Parameter initialization (deterministic; baked into the artifact)
# --------------------------------------------------------------------------


def _he_conv(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * jnp.sqrt(
        2.0 / fan_in
    )


def _he_dense(key, cin, cout):
    return jax.random.normal(key, (cin, cout), jnp.float32) * jnp.sqrt(2.0 / cin)


# --------------------------------------------------------------------------
# Building blocks (all matmul arithmetic goes through the Pallas kernels)
# --------------------------------------------------------------------------


def _conv_relu(x, w, *, stride=1):
    return jnp.maximum(kconv.conv2d(x, w, stride=stride), 0.0)


def _global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def _upsample2(x):
    """Nearest-neighbour 2x upsample, NHWC."""
    n, h, w, c = x.shape
    return jnp.broadcast_to(
        x[:, :, None, :, None, :], (n, h, 2, w, 2, c)
    ).reshape(n, 2 * h, 2 * w, c)


def preprocess(raw_u8: jax.Array) -> jax.Array:
    """Raw (RAW_H, RAW_W, 3) uint8 frame -> (1, IN_H, IN_W, 3) f32 tensor.

    Nearest-neighbour resize (pure data movement, fused by XLA) followed
    by the Pallas streaming normalize kernel — the server-side
    preprocessing stage of the paper's pipeline.
    """
    ry = jnp.arange(IN_H) * RAW_H // IN_H
    rx = jnp.arange(IN_W) * RAW_W // IN_W
    resized = raw_u8[ry][:, rx]
    return kpre.normalize(resized)[None]


@dataclass(frozen=True)
class ModelMeta:
    """Static description of a live model, mirrored into the manifest."""

    name: str
    task: str
    input_shape: tuple  # per-request (excludes batch)
    output_shape: tuple  # per-request
    gflops: float
    params: int = 0
    extra: dict = field(default_factory=dict)


def _count_params(tree) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(tree))


def _conv_gflops(h, w, kh, kw, cin, cout, stride=1):
    return 2.0 * (h // stride) * (w // stride) * kh * kw * cin * cout / 1e9


# --------------------------------------------------------------------------
# tiny_mobilenet — small classifier (MobileNetV3 archetype)
# --------------------------------------------------------------------------


def make_tiny_mobilenet(seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = {
        "c1": _he_conv(keys[0], 3, 3, 3, 8),
        "c2": _he_conv(keys[1], 3, 3, 8, 16),
        "w": _he_dense(keys[2], 16, NUM_CLASSES),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }

    def fwd(x):  # x: (B, IN_H, IN_W, 3) f32
        x = _conv_relu(x, p["c1"], stride=2)  # (B, 16, 16, 8)
        x = _conv_relu(x, p["c2"], stride=2)  # (B, 8, 8, 16)
        x = _global_avg_pool(x)  # (B, 16)
        return kmm.linear(x, p["w"], p["b"])  # (B, 1000)

    gflops = (
        _conv_gflops(IN_H, IN_W, 3, 3, 3, 8, 2)
        + _conv_gflops(16, 16, 3, 3, 8, 16, 2)
        + 2 * 16 * NUM_CLASSES / 1e9
    )
    meta = ModelMeta(
        name="tiny_mobilenet",
        task="classification",
        input_shape=(IN_H, IN_W, 3),
        output_shape=(NUM_CLASSES,),
        gflops=gflops,
        params=_count_params(p),
    )
    return fwd, meta


# --------------------------------------------------------------------------
# tiny_resnet — residual classifier (ResNet50 archetype)
# --------------------------------------------------------------------------


def make_tiny_resnet(seed: int = 1):
    keys = jax.random.split(jax.random.PRNGKey(seed), 8)
    p = {
        "stem": _he_conv(keys[0], 3, 3, 3, 16),
        "b1a": _he_conv(keys[1], 3, 3, 16, 16),
        "b1b": _he_conv(keys[2], 3, 3, 16, 16),
        "down": _he_conv(keys[3], 3, 3, 16, 32),
        "b2a": _he_conv(keys[4], 3, 3, 32, 32),
        "b2b": _he_conv(keys[5], 3, 3, 32, 32),
        "w": _he_dense(keys[6], 32, NUM_CLASSES),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }

    def fwd(x):  # (B, IN_H, IN_W, 3)
        x = _conv_relu(x, p["stem"], stride=2)  # (B,16,16,16)
        h = _conv_relu(x, p["b1a"])
        x = jnp.maximum(x + kconv.conv2d(h, p["b1b"]), 0.0)
        x = _conv_relu(x, p["down"], stride=2)  # (B,8,8,32)
        h = _conv_relu(x, p["b2a"])
        x = jnp.maximum(x + kconv.conv2d(h, p["b2b"]), 0.0)
        x = _global_avg_pool(x)  # (B,32)
        return kmm.linear(x, p["w"], p["b"])

    gflops = (
        _conv_gflops(IN_H, IN_W, 3, 3, 3, 16, 2)
        + 2 * _conv_gflops(16, 16, 3, 3, 16, 16)
        + _conv_gflops(16, 16, 3, 3, 16, 32, 2)
        + 2 * _conv_gflops(8, 8, 3, 3, 32, 32)
        + 2 * 32 * NUM_CLASSES / 1e9
    )
    meta = ModelMeta(
        name="tiny_resnet",
        task="classification",
        input_shape=(IN_H, IN_W, 3),
        output_shape=(NUM_CLASSES,),
        gflops=gflops,
        params=_count_params(p),
    )
    return fwd, meta


# --------------------------------------------------------------------------
# tiny_segnet — encoder/decoder, per-pixel logits (DeepLabV3 archetype)
# --------------------------------------------------------------------------


def make_tiny_segnet(seed: int = 2):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    p = {
        "e1": _he_conv(keys[0], 3, 3, 3, 16),
        "e2": _he_conv(keys[1], 3, 3, 16, 32),
        "mid": _he_conv(keys[2], 3, 3, 32, 32),
        "d1": _he_conv(keys[3], 3, 3, 32, 32),
        "d2": _he_conv(keys[4], 3, 3, 32, 16),
        "head": _he_conv(keys[5], 1, 1, 16, SEG_CLASSES),
    }

    def fwd(x):  # (B, IN_H, IN_W, 3)
        x = _conv_relu(x, p["e1"], stride=2)  # (B,16,16,16)
        x = _conv_relu(x, p["e2"], stride=2)  # (B,8,8,32)
        x = _conv_relu(x, p["mid"])  # (B,8,8,32)
        x = _upsample2(x)  # (B,16,16,32)
        x = _conv_relu(x, p["d1"])  # (B,16,16,32)
        x = _conv_relu(x, p["d2"])  # (B,16,16,16)
        x = _upsample2(x)  # (B,32,32,16)
        return kconv.conv2d(x, p["head"])  # (B,32,32,21)

    gflops = (
        _conv_gflops(IN_H, IN_W, 3, 3, 3, 16, 2)
        + _conv_gflops(16, 16, 3, 3, 16, 32, 2)
        + _conv_gflops(8, 8, 3, 3, 32, 32)
        + _conv_gflops(16, 16, 3, 3, 32, 32)
        + _conv_gflops(16, 16, 3, 3, 32, 16)
        + _conv_gflops(IN_H, IN_W, 1, 1, 16, SEG_CLASSES)
    )
    meta = ModelMeta(
        name="tiny_segnet",
        task="segmentation",
        input_shape=(IN_H, IN_W, 3),
        output_shape=(IN_H, IN_W, SEG_CLASSES),
        gflops=gflops,
        params=_count_params(p),
    )
    return fwd, meta


# --------------------------------------------------------------------------
# Registry + AOT entry points
# --------------------------------------------------------------------------

MODEL_BUILDERS: dict[str, Callable] = {
    "tiny_mobilenet": make_tiny_mobilenet,
    "tiny_resnet": make_tiny_resnet,
    "tiny_segnet": make_tiny_segnet,
}


def serving_fn(name: str, batch: int):
    """Return (jit-able fn, example input spec, meta) for a preprocessed-
    input serving executable: (B, IN_H, IN_W, 3) f32 -> output tuple."""
    fwd, meta = MODEL_BUILDERS[name]()

    def fn(x):
        return (fwd(x),)

    spec = jax.ShapeDtypeStruct((batch, *meta.input_shape), jnp.float32)
    return fn, (spec,), meta


def preprocess_fn():
    """Standalone preprocessing executable: raw u8 frame -> model input."""

    def fn(raw):
        return (preprocess(raw),)

    spec = jax.ShapeDtypeStruct((RAW_H, RAW_W, 3), jnp.uint8)
    meta = ModelMeta(
        name="preprocess",
        task="preprocess",
        input_shape=(RAW_H, RAW_W, 3),
        output_shape=(1, IN_H, IN_W, 3),
        gflops=3 * IN_H * IN_W * 3 / 1e9,
    )
    return fn, (spec,), meta


def raw_serving_fn(name: str):
    """Fused raw-path executable: u8 frame -> preprocess -> model (B=1).

    Mirrors the paper's "raw images" pipeline where the server performs
    preprocessing on the accelerator before inference.
    """
    fwd, meta = MODEL_BUILDERS[name]()

    def fn(raw):
        return (fwd(preprocess(raw)),)

    spec = jax.ShapeDtypeStruct((RAW_H, RAW_W, 3), jnp.uint8)
    raw_meta = ModelMeta(
        name=f"{name}_raw",
        task=meta.task,
        input_shape=(RAW_H, RAW_W, 3),
        output_shape=meta.output_shape,
        gflops=meta.gflops + 3 * IN_H * IN_W * 3 / 1e9,
        params=meta.params,
        extra={"fused_preprocess": True},
    )
    return fn, (spec,), raw_meta
