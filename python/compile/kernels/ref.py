"""Pure-jnp correctness oracles for the Pallas kernels.

Every L1 kernel has an exact reference here; pytest asserts allclose
between kernel and oracle across a hypothesis-driven shape/dtype sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .preprocess import MEAN, STD


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def linear_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "none"
) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def normalize_ref(img_u8: jax.Array) -> jax.Array:
    mean = jnp.asarray(MEAN, jnp.float32).reshape(1, 1, 3)
    std = jnp.asarray(STD, jnp.float32).reshape(1, 1, 3)
    return (img_u8.astype(jnp.float32) / 255.0 - mean) / std


def conv2d_ref(
    x: jax.Array, w: jax.Array, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """NHWC x HWIO convolution oracle via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
