"""L1 Pallas kernels for the accelserve model family.

Public surface:
    matmul.matmul / matmul.linear — MXU-tiled matmul + fused linear
    conv.conv2d                   — im2col conv over the Pallas matmul
    preprocess.normalize          — streaming image normalize
    ref                           — pure-jnp oracles for all of the above
"""

from . import conv, matmul, preprocess, ref  # noqa: F401
