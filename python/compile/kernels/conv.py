"""L1: 2-D convolution lowered to the Pallas matmul (im2col / patch-matmul).

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of porting a
CUDA direct-conv threadblock kernel, the convolution is re-expressed so
the MXU systolic array does the work — patches are extracted with
``conv_general_dilated_patches`` (a data-movement op XLA lowers to
gathers/reshapes that fuse with neighbours) and the arithmetic hot-spot
(patches @ filters) runs in the tiled Pallas matmul kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """NHWC input, HWIO filter -> NHWC output, arithmetic in Pallas matmul."""
    if x.ndim != 4 or w.ndim != 4:
        raise ValueError(f"conv2d expects NHWC x HWIO, got {x.shape}, {w.shape}")
    n, h, ww, cin = x.shape
    kh, kw, wcin, cout = w.shape
    if wcin != cin:
        raise ValueError(f"channel mismatch: input {cin}, filter {wcin}")
    # Patches in NHWC; feature dim is (cin, kh, kw) flattened (see
    # conv_general_dilated_patches docs: spatial dims of the RHS become
    # trailing, channel-major ordering C x KH x KW).
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, oh, ow, feat = patches.shape
    # Reorder the filter to the same (cin, kh, kw) feature layout.
    wmat = jnp.transpose(w.astype(jnp.float32), (2, 0, 1, 3)).reshape(
        cin * kh * kw, cout
    )
    out = matmul(patches.reshape(n * oh * ow, feat), wmat)
    return out.reshape(n, oh, ow, cout)
