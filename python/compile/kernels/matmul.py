"""L1 Pallas kernels: tiled matmul and fused linear+bias+activation.

TPU adaptation of the paper's CUDA/TensorRT compute hot-spot (see
DESIGN.md §Hardware-Adaptation): the convolution / dense layers of the
served CNNs are expressed as MXU-targeted tiled matmuls. BlockSpec
expresses the HBM->VMEM schedule that CUDA did with threadblocks:

  * grid = (M/bm, N/bn, K/bk); the K axis is innermost and sequential so
    the (bm, bn) output tile stays resident in VMEM across the K loop
    (revisiting-output accumulation pattern).
  * default tile 128x128x128 matches the MXU systolic array; smaller
    shapes fall back to the largest divisor tile <= the dimension.

Kernels are lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); the structure is nevertheless the real-TPU structure and
is what the VMEM/MXU estimates in DESIGN.md §Perf are computed from.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. On a real TPU the systolic array is 128x128; we
# keep the same shape so the lowering story is unchanged on hardware.
MXU_TILE = 128


def _largest_tile(dim: int, cap: int = MXU_TILE) -> int:
    """Largest divisor of ``dim`` that is <= cap.

    Fewer grid steps beat power-of-two alignment for the interpret-mode
    grid loop; on real TPU the 128 cap keeps tiles MXU-shaped.
    """
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    for d in range(min(dim, cap), 0, -1):
        if dim % d == 0:
            return d
    return 1


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; accumulates over the sequential K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Fused (bm, bn) tile of relu/identity(x @ w + b)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Tiled Pallas matmul ``x @ y`` for f32 operands.

    Shapes need not be tile-multiples; inputs are zero-padded up to the
    chosen tile and the result is sliced back. Padding with zeros is
    exact for matmul.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {y.shape}")
    if x.shape[1] != y.shape[0]:
        raise ValueError(f"contracting dims mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    bm = bm or _largest_tile(m)
    bn = bn or _largest_tile(n)
    bk = bk or _largest_tile(k)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad2(x.astype(jnp.float32), mp, kp)
    yp = _pad2(y.astype(jnp.float32), kp, np_)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "none",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Fused Pallas ``activation(x @ w + b)`` (activation in {none, relu})."""
    if activation not in ("none", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    kw, n = w.shape
    if kw != k or b.shape != (n,):
        raise ValueError(f"linear shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    bm = bm or _largest_tile(m)
    bn = bn or _largest_tile(n)
    bk = bk or _largest_tile(k)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad2(x.astype(jnp.float32), mp, kp)
    wp = _pad2(w.astype(jnp.float32), kp, np_)
    bp = jnp.pad(b.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_linear_kernel, nk=nk, activation=activation),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM working-set estimate for one grid step of the matmul kernel.

    x tile (bm, bk) + y tile (bk, bn) + resident output tile (bm, bn).
    Used by the §Perf roofline notes in DESIGN.md / EXPERIMENTS.md.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU issue slots doing useful work, given padding waste."""
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    useful = m * n * k
    issued = mp * np_ * kp
    edge = (min(bm, MXU_TILE) / MXU_TILE) * (min(bn, MXU_TILE) / MXU_TILE)
    return (useful / issued) * edge


def _ceil_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))
