"""L1 Pallas kernel: image preprocessing (normalize) for the serving pipeline.

The paper's model-serving pipeline has an explicit *preprocessing* stage
executed on the GPU when the client submits raw data (uint8 camera
frames): resize + scale + per-channel normalize. Here the bandwidth-bound
normalize runs as a Pallas kernel whose BlockSpec expresses the
HBM->VMEM streaming schedule (rows-of-pixels tiles); the nearest
neighbour resize is a gather that XLA fuses around it (L2, see
model.py:preprocess).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ImageNet-style per-channel statistics, matching the paper's use of
# torchvision-preprocessed classification inputs.
MEAN = (0.485, 0.456, 0.406)
STD = (0.229, 0.224, 0.225)


def _normalize_kernel(x_ref, mean_ref, std_ref, o_ref):
    """One (rows, W, C) stripe: o = (u8/255 - mean) / std in f32."""
    x = x_ref[...].astype(jnp.float32) * (1.0 / 255.0)
    o_ref[...] = (x - mean_ref[...]) / std_ref[...]


def normalize(img_u8: jax.Array, *, block_rows: int | None = None) -> jax.Array:
    """Normalize an HWC uint8 image to f32 with ImageNet statistics.

    The grid streams ``block_rows`` image rows per step through VMEM —
    the TPU analogue of the paper's CUDA elementwise preprocessing
    kernels that stream through shared memory.
    """
    if img_u8.ndim != 3 or img_u8.shape[-1] != 3:
        raise ValueError(f"expected HWC 3-channel image, got {img_u8.shape}")
    h, w, c = img_u8.shape
    br = block_rows or _largest_divisor(h, 32)
    mean = jnp.asarray(MEAN, jnp.float32).reshape(1, 1, 3)
    std = jnp.asarray(STD, jnp.float32).reshape(1, 1, 3)
    return pl.pallas_call(
        _normalize_kernel,
        grid=(h // br,),
        in_specs=[
            pl.BlockSpec((br, w, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, c), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, 1, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((br, w, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, c), jnp.float32),
        interpret=True,
    )(img_u8, mean, std)


def _largest_divisor(dim: int, cap: int) -> int:
    for d in range(min(dim, cap), 0, -1):
        if dim % d == 0:
            return d
    return 1
