//! Priority scheduling demo, on both planes.
//!
//! Sim plane: reproduce the paper's Fig 16 — a high-priority client's
//! latency is insulated under GDR (block-level stream priority) but
//! erodes under RDMA (the copy engine interleaves at whole-request
//! granularity and ignores priority).
//!
//! Live plane: the executor's priority queue serving a high-priority
//! tiny_mobilenet client while low-priority tiny_resnet jobs saturate
//! the single execution stream.
//!
//! ```sh
//! make artifacts && cargo run --release --example priority_clients
//! ```

use std::sync::Arc;

use accelserve::coordinator::{BatchCfg, Executor};
use accelserve::models::zoo::PaperModel;
use accelserve::net::params::Transport;
use accelserve::runtime::TensorBuf;
use accelserve::sim::world::{Scenario, World};

fn main() -> anyhow::Result<()> {
    // ---------------------------------------------------------- sim plane
    println!("sim plane — YoloV4 preprocessed, 1 priority + N-1 normal clients\n");
    println!(
        "{:<6} {:>13} {:>13} {:>14} {:>14}",
        "cl", "GDR prio ms", "GDR norm ms", "RDMA prio ms", "RDMA norm ms"
    );
    let yolo = PaperModel::by_name("YoloV4").unwrap();
    for clients in [2usize, 4, 8, 16] {
        let mut row = Vec::new();
        for tr in [Transport::Gdr, Transport::Rdma] {
            let s = World::run(
                Scenario::direct(yolo, tr)
                    .with_clients(clients)
                    .with_requests(60)
                    .with_raw(false)
                    .with_priority_client(true),
            );
            row.push((s.priority.total.mean(), s.normal.total.mean()));
        }
        println!(
            "{:<6} {:>13.1} {:>13.1} {:>14.1} {:>14.1}",
            clients, row[0].0, row[0].1, row[1].0, row[1].1
        );
    }
    println!("\n(GDR keeps the priority client flat; RDMA's copy queue erodes it — Fig 16)\n");

    // --------------------------------------------------------- live plane
    accelserve::models::gen::ensure_artifacts("artifacts")?;
    println!("live plane — priority queue on the PJRT executor (1 stream)\n");
    let exec = Arc::new(Executor::start(
        "artifacts",
        1,
        BatchCfg::none(),
        &["tiny_mobilenet_b1", "tiny_resnet_b1"],
    )?);

    // Saturate with background jobs, then measure a priority job's
    // queue time vs a normal job submitted at the same moment.
    let bg: Vec<_> = (0..6)
        .map(|_| exec.submit("tiny_resnet", false, 0, TensorBuf::F32(vec![0.5; 32 * 32 * 3])))
        .collect();
    let normal = exec.submit(
        "tiny_mobilenet",
        false,
        0,
        TensorBuf::F32(vec![0.5; 32 * 32 * 3]),
    );
    let prio = exec.submit(
        "tiny_mobilenet",
        false,
        10,
        TensorBuf::F32(vec![0.5; 32 * 32 * 3]),
    );
    let prio_done = prio.recv()??;
    let normal_done = normal.recv()??;
    for rx in bg {
        rx.recv()??;
    }
    println!(
        "priority job queue wait: {:.3} ms    normal job queue wait: {:.3} ms",
        prio_done.stages.queue_ns as f64 / 1e6,
        normal_done.stages.queue_ns as f64 / 1e6
    );
    assert!(
        prio_done.stages.queue_ns < normal_done.stages.queue_ns,
        "priority job must overtake the normal job"
    );
    println!("priority job overtook the backlog — live priority queue works");
    Ok(())
}
