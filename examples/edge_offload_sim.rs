//! Edge-facility what-if study on the sim plane: a fleet operator
//! deciding whether to deploy RDMA/GDR in the edge fabric runs this to
//! see projected latencies for their workload mix across transports,
//! connection modes and client loads — the paper's Table II models on
//! the modeled A2 + 25 GbE testbed.
//!
//! ```sh
//! cargo run --release --example edge_offload_sim
//! ```

use accelserve::models::zoo::ZOO;
use accelserve::net::params::Transport;
use accelserve::sim::world::{Scenario, World};

fn main() {
    println!("edge offload projection: direct connection, raw camera frames\n");
    println!(
        "{:<20} {:>3} {:>11} {:>11} {:>11} {:>13}",
        "model", "cl", "GDR ms", "RDMA ms", "TCP ms", "GDR saves"
    );
    for model in ZOO {
        for clients in [1usize, 8, 16] {
            let reqs = if model.infer_ms > 20.0 { 80 } else { 250 };
            let mut totals = Vec::new();
            for tr in [Transport::Gdr, Transport::Rdma, Transport::Tcp] {
                let s = World::run(
                    Scenario::direct(model, tr)
                        .with_clients(clients)
                        .with_requests(reqs),
                );
                totals.push(s.all.total.mean());
            }
            println!(
                "{:<20} {:>3} {:>11.2} {:>11.2} {:>11.2} {:>11.1}% ",
                model.name,
                clients,
                totals[0],
                totals[1],
                totals[2],
                (totals[2] - totals[0]) / totals[2] * 100.0
            );
        }
    }

    println!("\nproxied connection (client->gateway->server), MobileNetV3 raw, 8 clients\n");
    println!("{:<14} {:>11} {:>9}", "pair", "total ms", "std");
    for (ch, sh) in [
        (Transport::Rdma, Transport::Gdr),
        (Transport::Rdma, Transport::Rdma),
        (Transport::Tcp, Transport::Gdr),
        (Transport::Tcp, Transport::Rdma),
        (Transport::Tcp, Transport::Tcp),
    ] {
        let m = accelserve::models::zoo::PaperModel::by_name("MobileNetV3").unwrap();
        let s = World::run(
            Scenario::proxied(m, ch, sh)
                .with_clients(8)
                .with_requests(250),
        );
        println!(
            "{:<14} {:>11.3} {:>9.3}",
            format!("{}/{}", ch.name(), sh.name()),
            s.all.total.mean(),
            s.all.total.std()
        );
    }

    println!("\ntakeaway: GDR wins where communication is a large latency fraction");
    println!("(small models, large-I/O models, many clients) — the paper's finding (1).");
}
