//! End-to-end live serving: start a server (PJRT engine behind a
//! stream-scheduler executor), a router-dealer gateway in front of it,
//! and closed-loop clients over real TCP — then the same workload over
//! the RDMA-verbs transport in GDR mode — and report latency /
//! throughput with the paper's stage breakdown.
//!
//! This is the proof that all three layers compose: Pallas kernels ->
//! JAX model -> HLO text -> PJRT executable -> rust coordinator ->
//! sockets. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example serve_e2e
//! ```
//!
//! Artifacts are generated on first run (`accelserve gen-artifacts`);
//! `make artifacts` (python/JAX) may overwrite them with the real ones.

use std::sync::Arc;

use accelserve::coordinator::{
    gateway_tcp, protocol, run_tcp, serve_tcp, BatchCfg, Executor, LoadCfg,
};
use accelserve::transport::rdma::{rdma_pair, RingCfg};
use accelserve::transport::MsgTransport;

fn main() -> anyhow::Result<()> {
    accelserve::models::gen::ensure_artifacts("artifacts")?;
    let models = ["tiny_mobilenet", "tiny_resnet", "tiny_segnet"];
    let exec = Arc::new(Executor::start(
        "artifacts",
        4,
        BatchCfg::opportunistic(4),
        &[
            "preprocess",
            "tiny_mobilenet_b1",
            "tiny_resnet_b1",
            "tiny_segnet_b1",
        ],
    )?);
    let server = serve_tcp("127.0.0.1:0", exec.clone())?;
    let gateway = gateway_tcp("127.0.0.1:0", server.addr)?;
    println!("server {}  gateway {}", server.addr, gateway.addr);
    println!();
    println!(
        "{:<16} {:>5} {:>9} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "model/path", "cl", "reqs", "thr rps", "p50 ms", "mean ms", "infer", "net"
    );

    for model in models {
        for (label, addr, clients) in [
            ("direct", server.addr, 1usize),
            ("direct", server.addr, 4),
            ("proxied", gateway.addr, 4),
        ] {
            let cfg = LoadCfg {
                model: model.into(),
                raw: false,
                spans: false,
                n_clients: clients,
                requests_per_client: 60,
                priority_client: false,
                payload_elems: 32 * 32 * 3,
                warmup: 5,
                deadline_us: None,
                credits: false,
                timeout: None,
            };
            let s = run_tcp(addr, &cfg)?;
            let lat = s.all.total.summary();
            println!(
                "{:<16} {:>5} {:>9} {:>10.1} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
                format!("{model}/{label}"),
                clients,
                s.all.n(),
                s.throughput_rps,
                lat.p50,
                lat.mean,
                s.all.infer.mean(),
                s.all.request.mean() + s.all.response.mean(),
            );
        }
    }

    // Raw-input pipeline (server-side preprocessing stage).
    let raw_cfg = LoadCfg {
        model: "tiny_resnet".into(),
        raw: true,
        spans: false,
        n_clients: 2,
        requests_per_client: 40,
        priority_client: false,
        payload_elems: 64 * 64 * 3,
        warmup: 4,
        deadline_us: None,
        credits: false,
        timeout: None,
    };
    let s = run_tcp(server.addr, &raw_cfg)?;
    println!(
        "\nraw pipeline (tiny_resnet, 2 clients): total={:.3} ms  preproc={:.3} ms  infer={:.3} ms",
        s.all.total.mean(),
        s.all.preproc.mean(),
        s.all.infer.mean()
    );

    // RDMA-verbs transport in GDR mode: raw frames, so the server-side
    // receive is genuinely zero-copy (the registered-region payload
    // reaches the engine as a TensorBuf::U8Region, no host bounce).
    let (mut cli, srv) = rdma_pair(RingCfg::default(), true);
    let e2 = exec.clone();
    let h = std::thread::spawn(move || accelserve::coordinator::handle_conn(srv, &e2));
    let req = protocol::Request {
        model: "tiny_resnet".into(),
        raw: true,
        spans: false,
        prio: 0,
        deadline_us: None,
        credits: false,
        payload: accelserve::models::zoo::WorkloadData::image(64 * 64 * 3, 3).bytes,
    }
    .encode();
    let mut lat = accelserve::metrics::stats::Series::new();
    for i in 0..60 {
        let t0 = std::time::Instant::now();
        cli.send(&req)?;
        let frame = cli.recv()?;
        if i >= 5 {
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        match protocol::Response::decode(&frame)? {
            protocol::Response::Ok { .. } => {}
            protocol::Response::Err(e) => anyhow::bail!("gdr server: {e}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }
    println!(
        "rdma-verbs (GDR zero-copy raw path) tiny_resnet: p50={:.3} ms mean={:.3} ms",
        lat.quantile(0.5),
        lat.mean()
    );
    drop(cli);
    h.join().ok();

    gateway.stop();
    server.stop();
    println!("\nOK — all layers composed (Pallas -> HLO -> PJRT -> coordinator -> transport)");
    Ok(())
}
