//! Quickstart: load the AOT artifacts and run a single inference
//! through the public API — no server, no sockets.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Artifacts are generated on first run (`accelserve gen-artifacts`);
//! `make artifacts` (python/JAX) may overwrite them with the real ones.

use accelserve::models::zoo::WorkloadData;
use accelserve::runtime::{Engine, TensorBuf};

fn main() -> anyhow::Result<()> {
    accelserve::models::gen::ensure_artifacts("artifacts")?;
    let engine = Engine::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest().artifacts.len());

    // A raw 64x64 RGB camera frame (synthetic pixels).
    let frame = WorkloadData::image(64 * 64 * 3, 7).bytes;

    // Warm: first call compiles the HLO (the once-per-process cost).
    let t_w = std::time::Instant::now();
    engine.warm(&["preprocess", "tiny_resnet_b1", "tiny_resnet_raw"])?;
    println!("compile (once per process): {:.1} ms", t_w.elapsed().as_secs_f64() * 1e3);

    // Stage 1 — preprocessing (resize + ImageNet normalize), the
    // server-side stage of the paper's pipeline, as its own executable.
    let t0 = std::time::Instant::now();
    let tensor = engine.infer("preprocess", &TensorBuf::U8(frame.clone()))?;
    let t_pre = t0.elapsed();

    // Stage 2 — classification on the preprocessed tensor.
    let t1 = std::time::Instant::now();
    let logits = engine.infer("tiny_resnet_b1", &TensorBuf::F32(tensor))?;
    let t_inf = t1.elapsed();

    let (argmax, max) = logits
        .iter()
        .enumerate()
        .fold((0, f32::NEG_INFINITY), |acc, (i, &v)| {
            if v > acc.1 {
                (i, v)
            } else {
                acc
            }
        });
    println!(
        "preprocess: {:.3} ms   inference: {:.3} ms   top-1 class {} (logit {:.4})",
        t_pre.as_secs_f64() * 1e3,
        t_inf.as_secs_f64() * 1e3,
        argmax,
        max
    );

    // The fused raw-path executable must agree with the two-stage path.
    let fused = engine.infer("tiny_resnet_raw", &TensorBuf::U8(frame))?;
    let delta: f32 = fused
        .iter()
        .zip(&logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("fused raw path max |delta| = {delta:.2e} (expect < 1e-4)");
    assert!(delta < 1e-4);
    Ok(())
}
