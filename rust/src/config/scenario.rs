//! Scenario config files: JSON descriptions of sim-plane experiments so
//! deployments can be swept without recompiling (`accelserve sim
//! --config scenario.json`).
//!
//! ```json
//! {
//!   "model": "DeepLabV3_ResNet50",
//!   "transport": "rdma",
//!   "client_hop": "tcp",
//!   "clients": 16,
//!   "requests": 500,
//!   "raw": true,
//!   "sharing": "mps",
//!   "streams": 8,
//!   "priority_client": true,
//!   "seed": 7,
//!   "max_batch": 8,
//!   "flush_us": 2000
//! }
//! ```
//!
//! `live_transport`, `max_batch`, `flush_us` and `model_batch`
//! configure the *live* coordinator when a scenario file drives it:
//! `accelserve matrix --config` reads `live_transport` (the matrix
//! pins batching at b1 so stage latencies stay per-request), while
//! `accelserve batchsweep --config` and `accelserve mixsweep --config`
//! read the batching knobs too. The sim plane ignores them. Two
//! multi-model keys drive the mixed workloads:
//!
//! ```json
//! {
//!   "model": "MobileNetV3",
//!   "transport": "gdr",
//!   "model_mix": ["MobileNetV3", "ResNet50"],
//!   "model_batch": {"tiny_resnet": "8@2000", "tiny_mobilenet": "4*2"}
//! }
//! ```
//!
//! `model_mix` (paper models, sim plane + `mixsweep --sim`) assigns
//! clients round-robin across the listed models; `model_batch` (live
//! plane) gives each served model its own lane policy — a
//! [`BatchCfg`](crate::coordinator::BatchCfg) spec with an optional
//! `*W` round-robin weight suffix.
//!
//! Three routing-tier keys (live plane, ignored by the sim like the
//! other live knobs): `backends` (coordinator count behind the
//! gateway), `placement` (`"hash"` or `"least-loaded"`), and
//! `pipeline` (chained stage models after `model`, the
//! `FLAG_PIPELINE` request form — at most
//! [`MAX_PIPELINE_STAGES`](crate::coordinator::protocol::MAX_PIPELINE_STAGES)
//! total stages, no duplicates).

use anyhow::{bail, Context, Result};

use crate::coordinator::{ModelPolicy, Placement};
use crate::gpu::Sharing;
use crate::models::zoo::PaperModel;
use crate::net::params::Transport;
use crate::sim::world::Scenario;
use crate::transport::TransportKind;

use super::json::Json;

/// Parse a scenario from JSON text. Unknown keys are rejected so typos
/// fail loudly instead of silently running the default.
pub fn parse_scenario(text: &str) -> Result<Scenario> {
    let v = Json::parse(text).context("scenario json")?;
    let obj = match &v {
        Json::Obj(m) => m,
        _ => bail!("scenario must be a JSON object"),
    };
    const KNOWN: &[&str] = &[
        "model",
        "transport",
        "client_hop",
        "clients",
        "requests",
        "raw",
        "sharing",
        "streams",
        "priority_client",
        "seed",
        "warmup_frac",
        "live_transport",
        "max_batch",
        "flush_us",
        "model_mix",
        "model_batch",
        "backends",
        "placement",
        "pipeline",
    ];
    for k in obj.keys() {
        if !KNOWN.contains(&k.as_str()) {
            bail!("unknown scenario key {k:?} (known: {KNOWN:?})");
        }
    }

    let model_name = v
        .get("model")
        .and_then(Json::as_str)
        .context("scenario needs \"model\"")?;
    let model = PaperModel::by_name(model_name)
        .with_context(|| format!("unknown model {model_name}"))?;
    let transport = v
        .get("transport")
        .and_then(Json::as_str)
        .and_then(Transport::by_name)
        .context("scenario needs a valid \"transport\"")?;

    let mut sc = Scenario::direct(model, transport);
    if let Some(ch) = v.get("client_hop").and_then(Json::as_str) {
        sc.client_hop =
            Some(Transport::by_name(ch).with_context(|| format!("bad client_hop {ch}"))?);
    }
    if let Some(n) = v.get("clients").and_then(Json::as_u64) {
        sc.n_clients = n.max(1) as usize;
    }
    if let Some(n) = v.get("requests").and_then(Json::as_u64) {
        sc.requests_per_client = n.max(1) as usize;
    }
    if let Some(Json::Bool(b)) = v.get("raw") {
        sc.raw_input = *b;
    }
    if let Some(s) = v.get("sharing").and_then(Json::as_str) {
        sc.sharing = match s.to_ascii_lowercase().as_str() {
            "multi-stream" | "multistream" => Sharing::MultiStream,
            "multi-context" | "multicontext" => Sharing::MultiContext,
            "mps" => Sharing::Mps,
            other => bail!("unknown sharing {other:?}"),
        };
    }
    if let Some(n) = v.get("streams").and_then(Json::as_u64) {
        sc.n_streams = n as usize;
    }
    if let Some(Json::Bool(b)) = v.get("priority_client") {
        sc.priority_client = *b;
    }
    if let Some(n) = v.get("seed").and_then(Json::as_u64) {
        sc.seed = n;
    }
    if let Some(f) = v.get("warmup_frac").and_then(Json::as_f64) {
        if !(0.0..1.0).contains(&f) {
            bail!("warmup_frac must be in [0, 1)");
        }
        sc.warmup_frac = f;
    }
    if let Some(lt) = v.get("live_transport").and_then(Json::as_str) {
        sc.live_transport = Some(
            TransportKind::by_name(lt)
                .with_context(|| format!("bad live_transport {lt} (tcp|shm|rdma|gdr)"))?,
        );
    }
    if let Some(n) = v.get("max_batch").and_then(Json::as_u64) {
        if n == 0 {
            bail!("max_batch must be >= 1 (1 disables batching)");
        }
        sc.max_batch = n as usize;
    }
    if let Some(n) = v.get("flush_us").and_then(Json::as_u64) {
        sc.flush_us = n;
    }
    if let Some(arr) = v.get("model_mix").and_then(Json::as_arr) {
        let mut mix = Vec::new();
        for entry in arr {
            let name = entry.as_str().context("model_mix entries must be model names")?;
            mix.push(
                PaperModel::by_name(name)
                    .with_context(|| format!("unknown model_mix model {name}"))?,
            );
        }
        if mix.is_empty() {
            bail!("model_mix must list at least one model");
        }
        sc.model_mix = mix;
    }
    if let Some(n) = v.get("backends").and_then(Json::as_u64) {
        if n == 0 {
            bail!("backends must be >= 1 (1 disables sharding)");
        }
        sc.backends = n as usize;
    }
    if let Some(p) = v.get("placement").and_then(Json::as_str) {
        sc.placement = Some(
            Placement::by_name(p)
                .with_context(|| format!("bad placement {p} (hash|least-loaded)"))?,
        );
    }
    if let Some(arr) = v.get("pipeline").and_then(Json::as_arr) {
        let mut stages = Vec::new();
        for entry in arr {
            let name = entry
                .as_str()
                .context("pipeline entries must be model names")?;
            if name.is_empty() {
                bail!("pipeline stage names must be non-empty");
            }
            if stages.iter().any(|s| s == name) {
                bail!("duplicate pipeline stage {name:?}");
            }
            stages.push(name.to_string());
        }
        if stages.is_empty() {
            bail!("pipeline must list at least one chained stage");
        }
        if 1 + stages.len() > crate::coordinator::protocol::MAX_PIPELINE_STAGES {
            bail!(
                "pipeline of {} stages exceeds the wire cap {}",
                1 + stages.len(),
                crate::coordinator::protocol::MAX_PIPELINE_STAGES
            );
        }
        sc.pipeline = stages;
    }
    if let Some(mb) = v.get("model_batch") {
        let obj = match mb {
            Json::Obj(m) => m,
            _ => bail!("model_batch must be an object of model: \"spec\" pairs"),
        };
        for (model, spec) in obj {
            let spec = spec
                .as_str()
                .with_context(|| format!("model_batch.{model} must be a policy string"))?;
            let policy = ModelPolicy::parse_spec(spec).with_context(|| {
                format!(
                    "bad model_batch.{model} spec {spec:?} \
                     (want N, N@FLUSH_US, or either with a *WEIGHT suffix)"
                )
            })?;
            sc.model_batch.push((model.clone(), policy));
        }
    }
    Ok(sc)
}

/// Load a scenario file.
pub fn load_scenario(path: &str) -> Result<Scenario> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_scenario(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_scenario_roundtrip() {
        let sc = parse_scenario(
            r#"{"model": "YoloV4", "transport": "rdma", "client_hop": "tcp",
                "clients": 8, "requests": 50, "raw": false, "sharing": "mps",
                "streams": 4, "priority_client": true, "seed": 9,
                "warmup_frac": 0.2, "live_transport": "gdr",
                "max_batch": 8, "flush_us": 2000}"#,
        )
        .unwrap();
        assert_eq!(sc.model.name, "YoloV4");
        assert_eq!(sc.transport, Transport::Rdma);
        assert_eq!(sc.client_hop, Some(Transport::Tcp));
        assert_eq!(sc.n_clients, 8);
        assert_eq!(sc.requests_per_client, 50);
        assert!(!sc.raw_input);
        assert_eq!(sc.sharing, Sharing::Mps);
        assert_eq!(sc.n_streams, 4);
        assert!(sc.priority_client);
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.live_transport, Some(TransportKind::Gdr));
        assert_eq!(sc.max_batch, 8);
        assert_eq!(sc.flush_us, 2000);
        // And it runs.
        let stats = crate::sim::world::World::run(sc);
        assert!(stats.all.n() > 0);
    }

    #[test]
    fn minimal_scenario_defaults() {
        let sc =
            parse_scenario(r#"{"model": "ResNet50", "transport": "gdr"}"#).unwrap();
        assert_eq!(sc.n_clients, 1);
        assert!(sc.raw_input);
        assert_eq!(sc.client_hop, None);
        assert_eq!(sc.live_transport, None);
        assert_eq!(sc.max_batch, 1);
        assert_eq!(sc.flush_us, 0);
    }

    #[test]
    fn multi_model_keys_roundtrip() {
        let sc = parse_scenario(
            r#"{"model": "MobileNetV3", "transport": "gdr",
                "model_mix": ["MobileNetV3", "ResNet50"],
                "model_batch": {"tiny_mobilenet": "4*2", "tiny_resnet": "8@2000"},
                "clients": 8, "requests": 40}"#,
        )
        .unwrap();
        assert_eq!(sc.model_mix.len(), 2);
        assert_eq!(sc.model_mix[1].name, "ResNet50");
        // BTreeMap ordering: keys come back sorted.
        assert_eq!(sc.model_batch.len(), 2);
        let (m, p) = &sc.model_batch[0];
        assert_eq!(m, "tiny_mobilenet");
        assert_eq!(
            *p,
            ModelPolicy::weighted(crate::coordinator::BatchCfg::opportunistic(4), 2)
        );
        let (r, p) = &sc.model_batch[1];
        assert_eq!(r, "tiny_resnet");
        assert_eq!(
            *p,
            ModelPolicy::new(crate::coordinator::BatchCfg::deadline(8, 2000))
        );
        // And the sim twin runs the mix.
        let stats = crate::sim::world::World::run(sc);
        assert_eq!(stats.per_model.len(), 2);
        assert!(stats.per_model.iter().all(|(_, agg)| agg.n() > 0));
    }

    #[test]
    fn rejects_bad_multi_model_keys() {
        for bad in [
            r#"{"model": "ResNet50", "transport": "gdr", "model_mix": []}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "model_mix": ["Nope"]}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "model_mix": [3]}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "model_batch": ["x"]}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "model_batch": {"m": "0"}}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "model_batch": {"m": "8*0"}}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "model_batch": {"m": 8}}"#,
        ] {
            assert!(parse_scenario(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn routing_keys_roundtrip() {
        let sc = parse_scenario(
            r#"{"model": "MobileNetV3", "transport": "gdr",
                "backends": 2, "placement": "least-loaded",
                "pipeline": ["tiny_segnet"]}"#,
        )
        .unwrap();
        assert_eq!(sc.backends, 2);
        assert_eq!(sc.placement, Some(Placement::LeastLoaded));
        assert_eq!(sc.pipeline, vec!["tiny_segnet".to_string()]);
        // Defaults: no sharding, no chain.
        let plain = parse_scenario(r#"{"model": "ResNet50", "transport": "gdr"}"#).unwrap();
        assert_eq!(plain.backends, 1);
        assert_eq!(plain.placement, None);
        assert!(plain.pipeline.is_empty());
    }

    #[test]
    fn rejects_bad_routing_keys() {
        for bad in [
            r#"{"model": "ResNet50", "transport": "gdr", "backends": 0}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "placement": "psychic"}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "pipeline": []}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "pipeline": [""]}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "pipeline": [3]}"#,
            r#"{"model": "ResNet50", "transport": "gdr", "pipeline": ["a", "a"]}"#,
            r#"{"model": "ResNet50", "transport": "gdr",
                "pipeline": ["a","b","c","d","e","f","g","h"]}"#,
        ] {
            assert!(parse_scenario(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(parse_scenario(r#"{"transport": "gdr"}"#).is_err());
        assert!(parse_scenario(r#"{"model": "Nope", "transport": "gdr"}"#).is_err());
        assert!(parse_scenario(r#"{"model": "ResNet50", "transport": "warp"}"#).is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "typo_key": 1}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "sharing": "magic"}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "warmup_frac": 1.5}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "live_transport": "warp"}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "max_batch": 0}"#
        )
        .is_err());
        assert!(parse_scenario("[]").is_err());
    }
}
