//! Scenario config files: JSON descriptions of sim-plane experiments so
//! deployments can be swept without recompiling (`accelserve sim
//! --config scenario.json`).
//!
//! ```json
//! {
//!   "model": "DeepLabV3_ResNet50",
//!   "transport": "rdma",
//!   "client_hop": "tcp",
//!   "clients": 16,
//!   "requests": 500,
//!   "raw": true,
//!   "sharing": "mps",
//!   "streams": 8,
//!   "priority_client": true,
//!   "seed": 7,
//!   "max_batch": 8,
//!   "flush_us": 2000
//! }
//! ```
//!
//! `live_transport`, `max_batch` and `flush_us` configure the *live*
//! coordinator when a scenario file drives it: `accelserve matrix
//! --config` reads `live_transport` (the matrix pins batching at b1 so
//! stage latencies stay per-request), while `accelserve batchsweep
//! --config` reads all three. The sim plane ignores them.

use anyhow::{bail, Context, Result};

use crate::gpu::Sharing;
use crate::models::zoo::PaperModel;
use crate::net::params::Transport;
use crate::sim::world::Scenario;
use crate::transport::TransportKind;

use super::json::Json;

/// Parse a scenario from JSON text. Unknown keys are rejected so typos
/// fail loudly instead of silently running the default.
pub fn parse_scenario(text: &str) -> Result<Scenario> {
    let v = Json::parse(text).context("scenario json")?;
    let obj = match &v {
        Json::Obj(m) => m,
        _ => bail!("scenario must be a JSON object"),
    };
    const KNOWN: &[&str] = &[
        "model",
        "transport",
        "client_hop",
        "clients",
        "requests",
        "raw",
        "sharing",
        "streams",
        "priority_client",
        "seed",
        "warmup_frac",
        "live_transport",
        "max_batch",
        "flush_us",
    ];
    for k in obj.keys() {
        if !KNOWN.contains(&k.as_str()) {
            bail!("unknown scenario key {k:?} (known: {KNOWN:?})");
        }
    }

    let model_name = v
        .get("model")
        .and_then(Json::as_str)
        .context("scenario needs \"model\"")?;
    let model = PaperModel::by_name(model_name)
        .with_context(|| format!("unknown model {model_name}"))?;
    let transport = v
        .get("transport")
        .and_then(Json::as_str)
        .and_then(Transport::by_name)
        .context("scenario needs a valid \"transport\"")?;

    let mut sc = Scenario::direct(model, transport);
    if let Some(ch) = v.get("client_hop").and_then(Json::as_str) {
        sc.client_hop =
            Some(Transport::by_name(ch).with_context(|| format!("bad client_hop {ch}"))?);
    }
    if let Some(n) = v.get("clients").and_then(Json::as_u64) {
        sc.n_clients = n.max(1) as usize;
    }
    if let Some(n) = v.get("requests").and_then(Json::as_u64) {
        sc.requests_per_client = n.max(1) as usize;
    }
    if let Some(Json::Bool(b)) = v.get("raw") {
        sc.raw_input = *b;
    }
    if let Some(s) = v.get("sharing").and_then(Json::as_str) {
        sc.sharing = match s.to_ascii_lowercase().as_str() {
            "multi-stream" | "multistream" => Sharing::MultiStream,
            "multi-context" | "multicontext" => Sharing::MultiContext,
            "mps" => Sharing::Mps,
            other => bail!("unknown sharing {other:?}"),
        };
    }
    if let Some(n) = v.get("streams").and_then(Json::as_u64) {
        sc.n_streams = n as usize;
    }
    if let Some(Json::Bool(b)) = v.get("priority_client") {
        sc.priority_client = *b;
    }
    if let Some(n) = v.get("seed").and_then(Json::as_u64) {
        sc.seed = n;
    }
    if let Some(f) = v.get("warmup_frac").and_then(Json::as_f64) {
        if !(0.0..1.0).contains(&f) {
            bail!("warmup_frac must be in [0, 1)");
        }
        sc.warmup_frac = f;
    }
    if let Some(lt) = v.get("live_transport").and_then(Json::as_str) {
        sc.live_transport = Some(
            TransportKind::by_name(lt)
                .with_context(|| format!("bad live_transport {lt} (tcp|shm|rdma|gdr)"))?,
        );
    }
    if let Some(n) = v.get("max_batch").and_then(Json::as_u64) {
        if n == 0 {
            bail!("max_batch must be >= 1 (1 disables batching)");
        }
        sc.max_batch = n as usize;
    }
    if let Some(n) = v.get("flush_us").and_then(Json::as_u64) {
        sc.flush_us = n;
    }
    Ok(sc)
}

/// Load a scenario file.
pub fn load_scenario(path: &str) -> Result<Scenario> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_scenario(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_scenario_roundtrip() {
        let sc = parse_scenario(
            r#"{"model": "YoloV4", "transport": "rdma", "client_hop": "tcp",
                "clients": 8, "requests": 50, "raw": false, "sharing": "mps",
                "streams": 4, "priority_client": true, "seed": 9,
                "warmup_frac": 0.2, "live_transport": "gdr",
                "max_batch": 8, "flush_us": 2000}"#,
        )
        .unwrap();
        assert_eq!(sc.model.name, "YoloV4");
        assert_eq!(sc.transport, Transport::Rdma);
        assert_eq!(sc.client_hop, Some(Transport::Tcp));
        assert_eq!(sc.n_clients, 8);
        assert_eq!(sc.requests_per_client, 50);
        assert!(!sc.raw_input);
        assert_eq!(sc.sharing, Sharing::Mps);
        assert_eq!(sc.n_streams, 4);
        assert!(sc.priority_client);
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.live_transport, Some(TransportKind::Gdr));
        assert_eq!(sc.max_batch, 8);
        assert_eq!(sc.flush_us, 2000);
        // And it runs.
        let stats = crate::sim::world::World::run(sc);
        assert!(stats.all.n() > 0);
    }

    #[test]
    fn minimal_scenario_defaults() {
        let sc =
            parse_scenario(r#"{"model": "ResNet50", "transport": "gdr"}"#).unwrap();
        assert_eq!(sc.n_clients, 1);
        assert!(sc.raw_input);
        assert_eq!(sc.client_hop, None);
        assert_eq!(sc.live_transport, None);
        assert_eq!(sc.max_batch, 1);
        assert_eq!(sc.flush_us, 0);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(parse_scenario(r#"{"transport": "gdr"}"#).is_err());
        assert!(parse_scenario(r#"{"model": "Nope", "transport": "gdr"}"#).is_err());
        assert!(parse_scenario(r#"{"model": "ResNet50", "transport": "warp"}"#).is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "typo_key": 1}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "sharing": "magic"}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "warmup_frac": 1.5}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "live_transport": "warp"}"#
        )
        .is_err());
        assert!(parse_scenario(
            r#"{"model": "ResNet50", "transport": "gdr", "max_batch": 0}"#
        )
        .is_err());
        assert!(parse_scenario("[]").is_err());
    }
}
