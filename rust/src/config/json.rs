//! Minimal JSON parser (no external deps in the offline build).
//!
//! Supports the full JSON value grammar the artifact manifest and config
//! files use: objects, arrays, strings (with escapes), numbers, bools,
//! null. Not streaming; documents here are a few KB.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{"format": 1, "artifacts": [{"name": "m_b1",
            "inputs": [{"shape": [1, 32, 32, 3], "dtype": "f32"}],
            "gflops": 0.005, "ok": true, "n": null}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_u64(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("m_b1"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 4);
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(arts[0].get("n"), Some(&Json::Null));
    }

    #[test]
    fn strings_with_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_depth() {
        let v = Json::parse("[[[[[1]]]]]").unwrap();
        let mut cur = &v;
        for _ in 0..5 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }
}
