//! Configuration: a dependency-free JSON parser plus scenario-file
//! loading for the sim plane.

pub mod json;
pub mod scenario;

pub use scenario::{load_scenario, parse_scenario};
