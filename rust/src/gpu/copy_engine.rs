//! GPU copy engines (H2D / D2H DMA over PCIe).
//!
//! The A2 has two copy engines; we dedicate one per direction (the common
//! CUDA runtime assignment). The crucial behaviour from the paper:
//!
//! * within one process (multi-stream sharing), the engine interleaves at
//!   the granularity of a whole request's copy — FCFS, no priority — so
//!   a high-priority client's copy waits behind every queued bulk copy
//!   (§VI-B, Fig 16);
//! * across processes (multi-context / MPS), the engines interleave at a
//!   finer chunk granularity, which changes how copy overhead is shared
//!   (§VI-C, Fig 17).

use std::collections::VecDeque;

use crate::sim::time::Ns;

use super::params::GpuConfig;

/// Copy direction (engine selector). The two directions are the sim
/// plane's source for the Table I copy stages — the same `copy-h2d` /
/// `copy-d2h` slots of the shared stage taxonomy
/// ([`crate::trace::Stage`]) that the live plane fills from
/// `Engine::infer_timed` staging/fetch stamps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyDir {
    H2D,
    D2H,
}

impl CopyDir {
    pub fn index(self) -> usize {
        match self {
            CopyDir::H2D => 0,
            CopyDir::D2H => 1,
        }
    }

    /// The shared-taxonomy stage this direction's copy time lands in.
    pub fn stage(self) -> crate::trace::Stage {
        match self {
            CopyDir::H2D => crate::trace::Stage::CopyH2d,
            CopyDir::D2H => crate::trace::Stage::CopyD2h,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CopyDir::H2D => "H2D",
            CopyDir::D2H => "D2H",
        }
    }
}

/// Interleaving granularity of the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyDiscipline {
    /// Whole-request FCFS: single-process (multi-stream) sharing.
    RequestFcfs,
    /// Chunked round-robin: cross-process (multi-context / MPS) sharing.
    ChunkRr,
}

#[derive(Debug, Clone)]
struct CopyJob {
    req: usize,
    remaining: u64,
}

/// One copy engine: a queue plus an in-service marker. The owner drives
/// it with `start/step` and schedules the returned completion times.
#[derive(Debug, Clone)]
pub struct CopyEngine {
    cfg_fixed_us: f64,
    chunk: u64,
    pub discipline: CopyDiscipline,
    queue: VecDeque<CopyJob>,
    busy: bool,
    /// Invalidates stale scheduled steps after state changes.
    pub epoch: u64,
    /// Total busy time accumulated (utilization metric).
    pub busy_ns: u64,
}

/// Result of one engine step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// A request's copy fully completed.
    Done { req: usize },
    /// A chunk completed but the copy continues (ChunkRr).
    Continue,
    /// Engine idle (nothing queued).
    Idle,
}

impl CopyEngine {
    pub fn new(cfg: &GpuConfig, discipline: CopyDiscipline) -> CopyEngine {
        CopyEngine {
            cfg_fixed_us: cfg.copy_fixed_us,
            chunk: cfg.copy_chunk_bytes,
            discipline,
            queue: VecDeque::new(),
            busy: false,
            epoch: 0,
            busy_ns: 0,
        }
    }

    /// True if the engine has queued or in-flight work.
    pub fn is_busy(&self) -> bool {
        self.busy || !self.queue.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len() + usize::from(self.busy)
    }

    /// Enqueue a copy of `bytes` for `req`. If the engine was idle the
    /// caller must schedule a step at the returned time. `bw_gbs` is the
    /// *current* effective DMA bandwidth (degraded under execution-engine
    /// and queue load — see `GpuSim::copy_bw_gbs`).
    pub fn submit(&mut self, now: Ns, req: usize, bytes: u64, bw_gbs: f64) -> Option<(Ns, u64)> {
        self.queue.push_back(CopyJob {
            req,
            remaining: bytes.max(1),
        });
        if self.busy {
            None
        } else {
            Some(self.begin_service(now, bw_gbs))
        }
    }

    /// Begin serving the head job (engine must be idle, queue non-empty).
    fn begin_service(&mut self, now: Ns, bw_gbs: f64) -> (Ns, u64) {
        debug_assert!(!self.busy && !self.queue.is_empty());
        self.busy = true;
        self.epoch += 1;
        let head = self.queue.front().unwrap();
        let serve_bytes = match self.discipline {
            CopyDiscipline::RequestFcfs => head.remaining,
            CopyDiscipline::ChunkRr => head.remaining.min(self.chunk),
        };
        // Fixed launch cost applies per cudaMemcpy call; chunked service
        // pays a reduced per-chunk setup (DMA descriptor ring).
        let fixed = match self.discipline {
            CopyDiscipline::RequestFcfs => self.cfg_fixed_us,
            CopyDiscipline::ChunkRr => self.cfg_fixed_us * 0.25,
        };
        let dur = Ns::from_us(fixed + serve_bytes as f64 / bw_gbs.max(0.05) / 1_000.0);
        self.busy_ns += dur.0;
        (now + dur, self.epoch)
    }

    /// A scheduled step fired. Returns what happened plus, if the engine
    /// continues, the next step to schedule.
    pub fn step(&mut self, now: Ns, epoch: u64, bw_gbs: f64) -> (StepOutcome, Option<(Ns, u64)>) {
        if epoch != self.epoch || !self.busy {
            return (StepOutcome::Idle, None); // stale event
        }
        self.busy = false;
        let mut head = self.queue.pop_front().expect("busy engine with empty queue");
        let outcome = match self.discipline {
            CopyDiscipline::RequestFcfs => StepOutcome::Done { req: head.req },
            CopyDiscipline::ChunkRr => {
                let served = head.remaining.min(self.chunk);
                head.remaining -= served;
                if head.remaining == 0 {
                    StepOutcome::Done { req: head.req }
                } else {
                    // Rotate: unfinished copy goes to the back (RR).
                    self.queue.push_back(head);
                    StepOutcome::Continue
                }
            }
        };
        let next = if self.queue.is_empty() {
            None
        } else {
            Some(self.begin_service(now, bw_gbs))
        };
        (outcome, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GpuConfig {
        GpuConfig::default()
    }

    const BW: f64 = 4.0;

    /// Drive an engine to completion, returning (req, finish) pairs.
    fn drain(eng: &mut CopyEngine, submits: &[(usize, u64)]) -> Vec<(usize, Ns)> {
        let mut done = Vec::new();
        let mut pending: Option<(Ns, u64)> = None;
        for &(req, bytes) in submits {
            if let Some(p) = eng.submit(Ns::ZERO, req, bytes, BW) {
                pending = Some(p);
            }
        }
        while let Some((t, ep)) = pending.take() {
            let (out, next) = eng.step(t, ep, BW);
            if let StepOutcome::Done { req } = out {
                done.push((req, t));
            }
            pending = next;
        }
        done
    }

    #[test]
    fn fcfs_serves_whole_requests_in_order() {
        let mut eng = CopyEngine::new(&cfg(), CopyDiscipline::RequestFcfs);
        let done = drain(&mut eng, &[(1, 8_000_000), (2, 1_000), (3, 1_000)]);
        let order: Vec<usize> = done.iter().map(|d| d.0).collect();
        assert_eq!(order, vec![1, 2, 3]);
        // Small copies wait behind the 8 MB head-of-line copy (~2 ms).
        assert!(done[1].1.as_us() > 2_000.0);
    }

    #[test]
    fn chunk_rr_lets_small_copies_overtake() {
        let mut eng = CopyEngine::new(&cfg(), CopyDiscipline::ChunkRr);
        let done = drain(&mut eng, &[(1, 8_000_000), (2, 1_000)]);
        let pos1 = done.iter().position(|d| d.0 == 1).unwrap();
        let pos2 = done.iter().position(|d| d.0 == 2).unwrap();
        assert!(pos2 < pos1, "small copy must finish first under RR");
    }

    #[test]
    fn completion_exactly_once() {
        for disc in [CopyDiscipline::RequestFcfs, CopyDiscipline::ChunkRr] {
            let mut eng = CopyEngine::new(&cfg(), disc);
            let submits: Vec<(usize, u64)> =
                (0..20).map(|i| (i, 100_000 + i as u64 * 777_777)).collect();
            let done = drain(&mut eng, &submits);
            let mut reqs: Vec<usize> = done.iter().map(|d| d.0).collect();
            reqs.sort();
            assert_eq!(reqs, (0..20).collect::<Vec<_>>(), "{disc:?}");
        }
    }

    #[test]
    fn stale_epoch_ignored() {
        let mut eng = CopyEngine::new(&cfg(), CopyDiscipline::RequestFcfs);
        let (t, ep) = eng.submit(Ns::ZERO, 1, 1_000, BW).unwrap();
        let (out, _) = eng.step(t, ep + 99, BW);
        assert_eq!(out, StepOutcome::Idle);
        assert!(eng.is_busy());
        let (out, _) = eng.step(t, ep, BW);
        assert_eq!(out, StepOutcome::Done { req: 1 });
    }

    #[test]
    fn busy_time_tracks_service() {
        let mut eng = CopyEngine::new(&cfg(), CopyDiscipline::RequestFcfs);
        drain(&mut eng, &[(1, 4_000_000)]);
        let want_us = cfg().copy_fixed_us + 4_000_000.0 / BW / 1_000.0;
        assert!((eng.busy_ns as f64 / 1_000.0 - want_us).abs() < 1.0);
    }
}
