//! Calibrated configuration of the simulated NVIDIA A2 (Table III).

/// GPU device + scheduling-model parameters.
///
/// Defaults model the A2 in server S2: 10 execution engines, 16 GB
/// device memory, two copy engines on a PCIe Gen4 x8 link whose
/// *effective* per-copy bandwidth (small-transfer interleave, pinned
/// staging) is ~4 GB/s per direction — back-derived from the paper's
/// §V copy-time ranges (10–366 ms for DeepLabV3 at 1..16 clients).
#[derive(Debug, Clone)]
pub struct GpuConfig {
    /// Streaming-multiprocessor-like execution engines ("10 execution
    /// engines" on the A2, §III-D).
    pub n_engines: usize,
    /// Device memory, bytes (16 GB on the A2). Bounds GDR session count.
    pub device_mem_bytes: u64,
    /// Nominal copy-engine bandwidth per direction (idle device), GB/s.
    pub pcie_gbs: f64,
    /// DMA bandwidth degradation per unit of execution-engine activity
    /// (device-memory contention between kernels and the copy engines).
    pub pcie_contention: f64,
    /// Fixed per-copy launch cost (cudaMemcpy issue + DMA setup), us.
    pub copy_fixed_us: f64,
    /// Chunk size for cross-process copy-engine interleaving, bytes.
    pub copy_chunk_bytes: u64,
    /// Context time-slice quantum (multi-context sharing), us.
    pub slice_us: f64,
    /// Context switch penalty, us.
    pub ctx_switch_us: f64,
    /// Baseline per-request execution-time noise (CoV), dimensionless.
    pub base_cov: f64,
    /// Additional execution-time noise per unit of engine contention.
    pub contention_cov: f64,
    /// Execution slowdown/jitter coupling when same-context copies are in
    /// flight (the GigaThread interference of Fig 15c / finding 3).
    pub copy_interference: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            n_engines: 10,
            device_mem_bytes: 16 * 1024 * 1024 * 1024,
            pcie_gbs: 5.0,
            pcie_contention: 2.5,
            copy_fixed_us: 15.0,
            copy_chunk_bytes: 1 << 20,
            slice_us: 500.0,
            ctx_switch_us: 40.0,
            base_cov: 0.03,
            contention_cov: 0.30,
            copy_interference: 0.55,
        }
    }
}

impl GpuConfig {
    /// Copy duration for `bytes` through one copy engine, us (excluding
    /// queueing).
    pub fn copy_us(&self, bytes: u64) -> f64 {
        self.copy_fixed_us + bytes as f64 / self.pcie_gbs / 1_000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a2_shape() {
        let c = GpuConfig::default();
        assert_eq!(c.n_engines, 10);
        assert_eq!(c.device_mem_bytes, 16 << 30);
    }

    #[test]
    fn copy_time_matches_paper_scale() {
        // DeepLabV3 response (45.4 MB) must take ~10 ms per D2H copy so
        // that 16 closed-loop clients queue into the paper's 264-366 ms
        // copy-time range.
        let c = GpuConfig::default();
        let dl_resp = 2 * 21 * 520 * 520 * 4u64;
        // Idle device: ~9 ms (paper single-client copy-time ~9-10 ms).
        let t = c.copy_us(dl_resp) / 1_000.0;
        assert!((7.0..12.0).contains(&t), "idle copy {t} ms");
        // Fully busy execution engines: DMA degrades heavily (the §V
        // mechanism behind 264-366 ms copy times at 16 clients).
        let loaded = dl_resp as f64 / (c.pcie_gbs / (1.0 + c.pcie_contention)) / 1e6;
        assert!((20.0..120.0).contains(&loaded), "loaded copy {loaded} ms");
    }
}
