//! The GPU device simulator: execution engines + GigaThread dispatch +
//! stream/context/MPS sharing + the two copy engines.
//!
//! Event integration: the owner (the serving `World`, or a unit test)
//! keeps the event calendar. `GpuSim` methods return/emit `(Ns, GpuEv)`
//! pairs the owner must schedule, and delivering an event back via
//! `handle()` yields zero or more `GpuNotify` pipeline notifications.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::sim::rng::Rng;
use crate::sim::time::Ns;

use super::copy_engine::{CopyDir, CopyDiscipline, CopyEngine, StepOutcome};
use super::params::GpuConfig;

/// One kernel of a job: `blocks` thread blocks of `block_us` each.
#[derive(Debug, Clone, Copy)]
pub struct KernelSpec {
    pub blocks: u32,
    pub block_us: f64,
}

/// The GPU work of one request: an ordered kernel sequence. Kernels with
/// index < `preproc_boundary` are the preprocessing stage; the rest are
/// inference. `gap_us` is the stream-local launch gap between kernels.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub kernels: Vec<KernelSpec>,
    pub preproc_boundary: usize,
    pub gap_us: f64,
}

impl JobSpec {
    /// Execution-engine seconds this job needs (for utilization math).
    pub fn engine_us(&self) -> f64 {
        self.kernels
            .iter()
            .map(|k| k.blocks as f64 * k.block_us)
            .sum()
    }

    /// Latency of this job run alone on an idle device, us.
    pub fn alone_us(&self, n_engines: usize) -> f64 {
        self.kernels
            .iter()
            .map(|k| {
                let waves = (k.blocks as usize).div_ceil(n_engines) as f64;
                self.gap_us + waves * k.block_us
            })
            .sum()
    }
}

/// GPU sharing method under multi-client load (§VI-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sharing {
    /// One CUDA context, one stream per client slot (default).
    MultiStream,
    /// One context per client, time-sliced execution engines.
    MultiContext,
    /// Multi-Process Service: contexts packed onto the engines.
    Mps,
}

impl Sharing {
    pub fn name(self) -> &'static str {
        match self {
            Sharing::MultiStream => "multi-stream",
            Sharing::MultiContext => "multi-context",
            Sharing::Mps => "MPS",
        }
    }

    /// Copy-engine interleave granularity for this sharing mode.
    fn copy_discipline(self) -> CopyDiscipline {
        match self {
            // Single process: whole-request FCFS (coarse).
            Sharing::MultiStream => CopyDiscipline::RequestFcfs,
            // Separate processes: chunk-level round robin.
            Sharing::MultiContext | Sharing::Mps => CopyDiscipline::ChunkRr,
        }
    }

    /// Scale on the copy/exec interference coupling: separate contexts
    /// issue copies through their own command processors, which hides
    /// most of the interference (§VI-C hypothesis).
    fn interference_scale(self) -> f64 {
        match self {
            Sharing::MultiStream => 1.0,
            Sharing::MultiContext | Sharing::Mps => 0.25,
        }
    }
}

/// Events the owner schedules on behalf of the GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuEv {
    /// A thread block of `job` finishes on an engine.
    Block { job: usize },
    /// `job` requests its next kernel launch (enters the command FIFO).
    KernelReady { job: usize },
    /// `job`'s kernel launch completed through the command frontend; its
    /// blocks become dispatchable.
    KernelIssued { job: usize },
    /// A copy-engine service step completes.
    CopyStep { dir: usize, epoch: u64 },
    /// Context time-slice expires.
    Slice { epoch: u64 },
}

/// Pipeline notifications surfaced to the serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuNotify {
    PreprocDone { req: usize },
    InferDone { req: usize },
    CopyDone { req: usize, dir: CopyDir },
}

#[derive(Debug)]
struct Job {
    req: usize,
    prio: i32,
    spec: Arc<JobSpec>,
    cur_kernel: usize,
    pending: u32,
    inflight: u32,
    factor: f64,
    stream: usize,
    ctx: usize,
    done: bool,
}

/// The simulated device.
pub struct GpuSim {
    pub cfg: GpuConfig,
    pub sharing: Sharing,
    n_streams: usize,
    /// stream slot -> running job index.
    streams: Vec<Option<usize>>,
    /// jobs waiting for a free stream slot (priority-ordered, FIFO ties).
    wait: VecDeque<usize>,
    jobs: Vec<Job>,
    engines_free: usize,
    rr: usize,
    active_ctx: usize,
    ctx_ready_at: Ns,
    /// Global command-frontend FIFO (GigaThread): kernel launches from
    /// all streams serialize through this point.
    cmd_free_at: Ns,

    slice_epoch: u64,
    slice_armed: bool,
    copy: [CopyEngine; 2],
    rng: Rng,
    emit: Vec<(Ns, GpuEv)>,
    /// Device-memory accounting for pinned GDR session buffers (§VII).
    mem_used: u64,
    /// Stats: total engine busy nanoseconds, executed blocks.
    pub engine_busy_ns: u64,
    pub blocks_executed: u64,
}

impl GpuSim {
    /// `n_streams` is the concurrency limit (stream pool size); under
    /// MultiContext/Mps each slot is its own context.
    pub fn new(cfg: GpuConfig, sharing: Sharing, n_streams: usize, seed: u64) -> GpuSim {
        assert!(n_streams >= 1, "need at least one stream");
        let disc = sharing.copy_discipline();
        GpuSim {
            engines_free: cfg.n_engines,
            copy: [CopyEngine::new(&cfg, disc), CopyEngine::new(&cfg, disc)],
            cfg,
            sharing,
            n_streams,
            streams: vec![None; n_streams],
            wait: VecDeque::new(),
            jobs: Vec::new(),
            rr: 0,
            active_ctx: 0,
            ctx_ready_at: Ns::ZERO,
            cmd_free_at: Ns::ZERO,
            slice_epoch: 0,
            slice_armed: false,
            rng: Rng::new(seed ^ 0xD00D_F00D),
            emit: Vec::new(),
            mem_used: 0,
            engine_busy_ns: 0,
            blocks_executed: 0,
        }
    }

    /// Drain events the owner must schedule.
    pub fn drain(&mut self) -> Vec<(Ns, GpuEv)> {
        std::mem::take(&mut self.emit)
    }

    /// Are any copies queued or in flight (either engine)?
    pub fn copies_busy(&self) -> bool {
        self.copy.iter().any(|e| e.is_busy())
    }

    pub fn copy_queue_len(&self, dir: CopyDir) -> usize {
        self.copy[dir.index()].queue_len()
    }

    pub fn copy_busy_ns(&self) -> u64 {
        self.copy.iter().map(|e| e.busy_ns).sum()
    }

    /// Size of the stream/context pool.
    pub fn n_streams(&self) -> usize {
        self.n_streams
    }

    /// Reserve pinned device memory for a GDR session (paper §VII:
    /// per-client buffers bound the session count). Returns false when
    /// the device is out of memory.
    pub fn reserve_session(&mut self, bytes: u64) -> bool {
        if self.mem_used + bytes > self.cfg.device_mem_bytes {
            return false;
        }
        self.mem_used += bytes;
        true
    }

    pub fn release_session(&mut self, bytes: u64) {
        self.mem_used = self.mem_used.saturating_sub(bytes);
    }

    // ------------------------------------------------------------ copies

    /// Effective DMA bandwidth right now: the nominal PCIe rate degraded
    /// by execution-engine activity (kernel memory traffic competes with
    /// DMA on the device memory system — the §V mechanism by which copy
    /// time balloons under concurrency) and mildly by queue pressure
    /// (descriptor-ring overheads).
    pub fn copy_bw_gbs(&self, _dir: CopyDir) -> f64 {
        let exec_frac = (self.cfg.n_engines - self.engines_free) as f64
            / self.cfg.n_engines as f64;
        self.cfg.pcie_gbs / (1.0 + self.cfg.pcie_contention * exec_frac)
    }

    /// Device-sync penalty paid by each copy when the engine interleaves
    /// at request granularity (single-process multi-stream sharing).
    ///
    /// The paper's server issues `cudaMemcpy` — the *synchronous* API —
    /// from per-client threads (§III-A). In the legacy default-stream
    /// semantics that implies a device synchronization: the copy cannot
    /// start until kernels already submitted by every stream in the
    /// context drain. The penalty therefore scales with how much kernel
    /// work the active jobs have in flight. Cross-process sharing
    /// (MPS/multi-context, ChunkRr) has no shared context to sync with.
    fn copy_sync_us(&self) -> f64 {
        if self.copy[0].discipline != CopyDiscipline::RequestFcfs {
            return 0.0;
        }
        // Drain time of one in-flight kernel wave per active stream,
        // executed across the engines.
        let inflight_block_us: f64 = self
            .streams
            .iter()
            .flatten()
            .filter(|&&j| !self.jobs[j].done)
            .map(|&j| {
                let job = &self.jobs[j];
                let k = &job.spec.kernels[job.cur_kernel.min(job.spec.kernels.len() - 1)];
                k.blocks as f64 * k.block_us * job.factor
            })
            .sum();
        1.5 * inflight_block_us / self.cfg.n_engines as f64
    }

    /// Submit an H2D/D2H staging copy for `req`.
    pub fn submit_copy(&mut self, now: Ns, req: usize, dir: CopyDir, bytes: u64) {
        let bw = self.copy_bw_gbs(dir);
        let sync = Ns::from_us(self.copy_sync_us());
        let eng = &mut self.copy[dir.index()];
        if let Some((t, epoch)) = eng.submit(now + sync, req, bytes, bw) {
            self.emit.push((
                t,
                GpuEv::CopyStep {
                    dir: dir.index(),
                    epoch,
                },
            ));
        }
    }

    // -------------------------------------------------------------- jobs

    /// Submit the GPU work of a request. Returns the job id. The job
    /// waits for a free stream slot if all `n_streams` are busy (§VI-A:
    /// requests queue until a stream is available).
    pub fn submit_job(&mut self, now: Ns, req: usize, prio: i32, spec: Arc<JobSpec>) -> usize {
        assert!(!spec.kernels.is_empty(), "job with no kernels");
        let id = self.jobs.len();
        self.jobs.push(Job {
            req,
            prio,
            spec,
            cur_kernel: 0,
            pending: 0,
            inflight: 0,
            factor: 1.0,
            stream: usize::MAX,
            ctx: 0,
            done: false,
        });
        // Priority-ordered insertion (stable FIFO within a priority).
        let pos = self
            .wait
            .iter()
            .position(|&j| self.jobs[j].prio < prio)
            .unwrap_or(self.wait.len());
        self.wait.insert(pos, id);
        self.fill_streams(now);
        id
    }

    /// Assign waiting jobs to free stream slots.
    fn fill_streams(&mut self, now: Ns) {
        while let Some(slot) = self.streams.iter().position(|s| s.is_none()) {
            let Some(job_id) = self.wait.pop_front() else {
                break;
            };
            self.streams[slot] = Some(job_id);
            let factor = self.job_factor(job_id);
            let job = &mut self.jobs[job_id];
            job.stream = slot;
            job.ctx = match self.sharing {
                Sharing::MultiStream => 0,
                _ => slot,
            };
            job.factor = factor;
            self.emit.push((now, GpuEv::KernelReady { job: job_id }));
        }
        self.arm_slice(now);
    }

    /// Per-request stochastic slowdown factor (DESIGN.md §1: calibrated
    /// contention model). Composed of baseline measurement noise,
    /// engine-contention jitter scaled by competing load at/above this
    /// job's priority, and copy/exec interference when staging copies
    /// are in flight in a coupled context.
    fn job_factor(&mut self, job_id: usize) -> f64 {
        let me = self.jobs[job_id].prio;
        let others = self
            .streams
            .iter()
            .flatten()
            .filter(|&&j| j != job_id && !self.jobs[j].done && self.jobs[j].prio >= me)
            .count();
        let frac = (others as f64 / self.cfg.n_engines as f64).min(1.0);
        let mut f = self.rng.noise(self.cfg.base_cov);
        // Contention jitter is zero-mean: throughput is conserved across
        // streams; burstiness only spreads per-request completion times.
        f *= 1.0 + self.cfg.contention_cov * frac * self.rng.normal();
        // Copy/exec interference both slows (mean > 1) and jitters
        // execution, growing with copy-queue pressure.
        let qlen = self.copy[0].queue_len() + self.copy[1].queue_len();
        if qlen > 0 {
            let scale = self.sharing.interference_scale() * (qlen as f64 / 6.0).min(1.0);
            f *= 1.0 + self.cfg.copy_interference * scale * self.rng.normal().abs();
        }
        f.clamp(0.4, 4.0)
    }

    // ---------------------------------------------------------- dispatch

    /// GigaThread dispatch: fill free engines with blocks from issueable
    /// streams — highest priority first, round-robin among equals, FCFS
    /// within a kernel (block-granular interleave, paper refs [11][12]).
    fn dispatch(&mut self, now: Ns) {
        while self.engines_free > 0 {
            let Some(job_id) = self.pick_stream() else {
                break;
            };
            let job = &mut self.jobs[job_id];
            job.pending -= 1;
            job.inflight += 1;
            self.engines_free -= 1;
            let k = &job.spec.kernels[job.cur_kernel];
            let dur_us = k.block_us * job.factor;
            let start = now.max(self.ctx_ready_at);
            let dur = Ns::from_us(dur_us.max(0.01));
            self.engine_busy_ns += dur.0;
            self.emit.push((start + dur, GpuEv::Block { job: job_id }));
        }
    }

    /// Select the next stream to issue a block from: strictly highest
    /// priority first; a random lottery among equals (observed GigaThread
    /// arbitration is priority-accommodating but bursty across streams,
    /// which is the source of processing-time variability under
    /// concurrency — Fig 15c).
    fn pick_stream(&mut self) -> Option<usize> {
        let n = self.streams.len();
        let mut best_prio = i32::MIN;
        let mut count = 0usize;
        let mut chosen = None;
        let start = self.rr;
        for off in 0..n {
            let slot = (start + off) % n;
            let Some(job_id) = self.streams[slot] else {
                continue;
            };
            let job = &self.jobs[job_id];
            if job.pending == 0 {
                continue;
            }
            if self.sharing == Sharing::MultiContext && job.ctx != self.active_ctx {
                continue;
            }
            if job.prio > best_prio {
                best_prio = job.prio;
                count = 1;
                chosen = Some(slot);
            } else if job.prio == best_prio {
                // Reservoir-sample uniformly among equal-priority streams.
                count += 1;
                if self.rng.below(count) == 0 {
                    chosen = Some(slot);
                }
            }
        }
        let slot = chosen?;
        self.rr = (slot + 1) % n;
        self.streams[slot]
    }

    /// Arm the context time-slice timer when >1 context has live work.
    fn arm_slice(&mut self, now: Ns) {
        if self.sharing != Sharing::MultiContext || self.slice_armed {
            return;
        }
        if self.live_ctx_count() > 1 {
            self.slice_epoch += 1;
            self.slice_armed = true;
            self.emit.push((
                now + Ns::from_us(self.cfg.slice_us),
                GpuEv::Slice {
                    epoch: self.slice_epoch,
                },
            ));
        }
    }

    fn live_ctx_count(&self) -> usize {
        self.streams
            .iter()
            .flatten()
            .filter(|&&j| !self.jobs[j].done)
            .map(|&j| self.jobs[j].ctx)
            .collect::<std::collections::HashSet<_>>()
            .len()
    }

    // ------------------------------------------------------------ events

    /// Deliver a scheduled event; returns pipeline notifications.
    pub fn handle(&mut self, now: Ns, ev: GpuEv) -> Vec<GpuNotify> {
        let mut out = Vec::new();
        match ev {
            GpuEv::KernelReady { job } => {
                // Acquire a command-frontend slot (global FIFO); launches
                // additionally wait out any legacy-sync memcpy barrier.
                let gap = Ns::from_us(self.jobs[job].spec.gap_us);
                let slot = now.max(self.cmd_free_at);
                self.cmd_free_at = slot + gap;
                self.emit.push((slot + gap, GpuEv::KernelIssued { job }));
            }
            GpuEv::KernelIssued { job } => {
                let j = &mut self.jobs[job];
                debug_assert!(!j.done);
                j.pending = j.spec.kernels[j.cur_kernel].blocks;
                self.dispatch(now);
            }
            GpuEv::Block { job } => {
                self.engines_free += 1;
                self.blocks_executed += 1;
                let j = &mut self.jobs[job];
                j.inflight -= 1;
                if j.pending == 0 && j.inflight == 0 {
                    // Kernel complete.
                    j.cur_kernel += 1;
                    if j.cur_kernel == j.spec.preproc_boundary {
                        out.push(GpuNotify::PreprocDone { req: j.req });
                    }
                    if j.cur_kernel == j.spec.kernels.len() {
                        j.done = true;
                        out.push(GpuNotify::InferDone { req: j.req });
                        let slot = j.stream;
                        self.streams[slot] = None;
                        self.fill_streams(now);
                    } else {
                        self.emit.push((now, GpuEv::KernelReady { job }));
                    }
                }
                self.dispatch(now);
            }
            GpuEv::CopyStep { dir, epoch } => {
                let d = if dir == 0 { CopyDir::H2D } else { CopyDir::D2H };
                let bw = self.copy_bw_gbs(d);
                let sync = Ns::from_us(self.copy_sync_us());
                let (outcome, next) = self.copy[dir].step(now + sync, epoch, bw);
                if let StepOutcome::Done { req } = outcome {
                    out.push(GpuNotify::CopyDone { req, dir: d });
                }
                if let Some((t, ep)) = next {
                    self.emit.push((t, GpuEv::CopyStep { dir, epoch: ep }));
                }
            }
            GpuEv::Slice { epoch } => {
                if epoch != self.slice_epoch {
                    return out; // stale
                }
                self.slice_armed = false;
                // Rotate to the next context with live work.
                let ctxs: Vec<usize> = {
                    let mut v: Vec<usize> = self
                        .streams
                        .iter()
                        .flatten()
                        .filter(|&&j| !self.jobs[j].done)
                        .map(|&j| self.jobs[j].ctx)
                        .collect();
                    v.sort_unstable();
                    v.dedup();
                    v
                };
                if ctxs.len() > 1 {
                    let next = ctxs
                        .iter()
                        .copied()
                        .find(|&c| c > self.active_ctx)
                        .unwrap_or(ctxs[0]);
                    self.active_ctx = next;
                    self.ctx_ready_at = now + Ns::from_us(self.cfg.ctx_switch_us);
                    self.dispatch(now);
                }
                self.arm_slice(now);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;

    /// Minimal event-loop harness around GpuSim for tests.
    struct Harness {
        gpu: GpuSim,
        heap: BinaryHeap<std::cmp::Reverse<(Ns, u64, HarnessEv)>>,
        seq: u64,
        now: Ns,
        notifications: Vec<(Ns, GpuNotify)>,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    enum HarnessEv {
        Gpu(GpuEvOrd),
    }

    // GpuEv lacks Ord; wrap via a canonical encoding.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    struct GpuEvOrd(u8, usize, u64);

    fn enc(ev: GpuEv) -> GpuEvOrd {
        match ev {
            GpuEv::Block { job } => GpuEvOrd(0, job, 0),
            GpuEv::KernelReady { job } => GpuEvOrd(1, job, 0),
            GpuEv::KernelIssued { job } => GpuEvOrd(4, job, 0),
            GpuEv::CopyStep { dir, epoch } => GpuEvOrd(2, dir, epoch),
            GpuEv::Slice { epoch } => GpuEvOrd(3, 0, epoch),
        }
    }

    fn dec(e: GpuEvOrd) -> GpuEv {
        match e.0 {
            0 => GpuEv::Block { job: e.1 },
            1 => GpuEv::KernelReady { job: e.1 },
            4 => GpuEv::KernelIssued { job: e.1 },
            2 => GpuEv::CopyStep {
                dir: e.1,
                epoch: e.2,
            },
            _ => GpuEv::Slice { epoch: e.2 },
        }
    }

    impl Harness {
        fn new(sharing: Sharing, n_streams: usize) -> Harness {
            Harness {
                gpu: GpuSim::new(GpuConfig::default(), sharing, n_streams, 42),
                heap: BinaryHeap::new(),
                seq: 0,
                now: Ns::ZERO,
                notifications: Vec::new(),
            }
        }

        fn pump(&mut self) {
            for (t, ev) in self.gpu.drain() {
                self.seq += 1;
                self.heap
                    .push(std::cmp::Reverse((t, self.seq, HarnessEv::Gpu(enc(ev)))));
            }
        }

        fn run(&mut self) {
            self.pump();
            while let Some(std::cmp::Reverse((t, _, HarnessEv::Gpu(e)))) = self.heap.pop() {
                assert!(t >= self.now, "time went backwards");
                self.now = t;
                for n in self.gpu.handle(t, dec(e)) {
                    self.notifications.push((t, n));
                }
                self.pump();
            }
        }

        fn infer_done(&self, req: usize) -> Ns {
            self.notifications
                .iter()
                .find(|(_, n)| matches!(n, GpuNotify::InferDone { req: r } if *r == req))
                .map(|(t, _)| *t)
                .unwrap_or_else(|| panic!("req {req} never finished"))
        }
    }

    fn job(kernels: usize, blocks: u32, block_us: f64) -> JobSpec {
        JobSpec {
            kernels: vec![
                KernelSpec {
                    blocks,
                    block_us,
                };
                kernels
            ],
            preproc_boundary: 0,
            gap_us: 25.0,
        }
    }

    #[test]
    fn single_job_latency_near_alone_time() {
        let mut h = Harness::new(Sharing::MultiStream, 1);
        let spec = job(10, 20, 50.0);
        let alone = spec.alone_us(10);
        h.gpu.submit_job(Ns::ZERO, 0, 0, spec.into());
        h.run();
        let got = h.infer_done(0).as_us();
        assert!(
            (got - alone).abs() / alone < 0.25,
            "got {got}us want ~{alone}us"
        );
    }

    #[test]
    fn no_lost_blocks() {
        let mut h = Harness::new(Sharing::MultiStream, 8);
        let mut want = 0u64;
        for r in 0..8 {
            let spec = job(5, 20, 30.0);
            want += spec.kernels.iter().map(|k| k.blocks as u64).sum::<u64>();
            h.gpu.submit_job(Ns::ZERO, r, 0, spec.into());
        }
        h.run();
        assert_eq!(h.gpu.blocks_executed, want);
        assert_eq!(h.gpu.engines_free, 10);
        for r in 0..8 {
            h.infer_done(r);
        }
    }

    #[test]
    fn throughput_conserved_under_sharing() {
        // 4 identical jobs on 4 streams: total makespan ~= sum of engine
        // work / engines (plus gaps), and every job finishes.
        let mut h = Harness::new(Sharing::MultiStream, 4);
        for r in 0..4 {
            h.gpu.submit_job(Ns::ZERO, r, 0, job(10, 20, 100.0).into());
        }
        h.run();
        let makespan = h.now.as_us();
        let engine_work: f64 = 4.0 * 10.0 * 20.0 * 100.0 / 10.0;
        assert!(makespan > engine_work * 0.9, "{makespan} vs {engine_work}");
        assert!(makespan < engine_work * 1.6, "{makespan} vs {engine_work}");
    }

    #[test]
    fn priority_job_overtakes() {
        // Launch 6 normal jobs, then a high-priority one: with block-level
        // priority dispatch its latency must be far below the normals'.
        let mut h = Harness::new(Sharing::MultiStream, 7);
        for r in 0..6 {
            h.gpu.submit_job(Ns::ZERO, r, 0, job(20, 20, 100.0).into());
        }
        h.gpu.submit_job(Ns::from_us(50.0), 6, 10, job(20, 20, 100.0).into());
        h.run();
        let hi = h.infer_done(6).as_us();
        let normal_avg: f64 =
            (0..6).map(|r| h.infer_done(r).as_us()).sum::<f64>() / 6.0;
        assert!(
            hi < normal_avg * 0.55,
            "priority {hi}us vs normal avg {normal_avg}us"
        );
    }

    #[test]
    fn stream_limit_queues_jobs() {
        // 4 jobs, 1 stream: strictly serialized => last finishes ~4x alone.
        let mut h = Harness::new(Sharing::MultiStream, 1);
        let spec = job(10, 20, 50.0);
        let alone = spec.alone_us(10);
        for r in 0..4 {
            h.gpu.submit_job(Ns::ZERO, r, 0, spec.clone().into());
        }
        h.run();
        let last = (0..4).map(|r| h.infer_done(r).as_us()).fold(0.0, f64::max);
        assert!(last > 3.5 * alone, "last {last} vs alone {alone}");
    }

    #[test]
    fn preproc_boundary_notifies() {
        let mut h = Harness::new(Sharing::MultiStream, 1);
        let spec = JobSpec {
            kernels: vec![KernelSpec { blocks: 20, block_us: 10.0 }; 6],
            preproc_boundary: 2,
            gap_us: 25.0,
        };
        h.gpu.submit_job(Ns::ZERO, 0, 0, spec.into());
        h.run();
        let pre = h
            .notifications
            .iter()
            .find(|(_, n)| matches!(n, GpuNotify::PreprocDone { .. }))
            .map(|(t, _)| *t)
            .expect("no preproc notification");
        assert!(pre < h.infer_done(0));
    }

    #[test]
    fn copies_complete_and_notify() {
        let mut h = Harness::new(Sharing::MultiStream, 1);
        h.gpu.submit_copy(Ns::ZERO, 5, CopyDir::H2D, 1_000_000);
        h.gpu.submit_copy(Ns::ZERO, 6, CopyDir::D2H, 2_000_000);
        h.run();
        let dirs: Vec<CopyDir> = h
            .notifications
            .iter()
            .filter_map(|(_, n)| match n {
                GpuNotify::CopyDone { dir, .. } => Some(*dir),
                _ => None,
            })
            .collect();
        assert_eq!(dirs.len(), 2);
        assert!(!h.gpu.copies_busy());
    }

    #[test]
    fn multicontext_slower_than_mps() {
        // 8 jobs across 8 client slots: time-sliced contexts must yield a
        // larger makespan than MPS packing (Fig 17).
        let mut makespans = Vec::new();
        for sharing in [Sharing::Mps, Sharing::MultiContext] {
            let mut h = Harness::new(sharing, 8);
            for r in 0..8 {
                h.gpu.submit_job(Ns::ZERO, r, 0, job(10, 10, 80.0).into());
            }
            h.run();
            makespans.push(h.now.as_us());
        }
        assert!(
            makespans[1] > makespans[0] * 1.2,
            "multi-context {} !>> mps {}",
            makespans[1],
            makespans[0]
        );
    }

    #[test]
    fn session_memory_accounting() {
        let mut gpu = GpuSim::new(GpuConfig::default(), Sharing::MultiStream, 1, 1);
        assert!(gpu.reserve_session(8 << 30));
        assert!(!gpu.reserve_session(10 << 30), "over-commit allowed");
        gpu.release_session(8 << 30);
        assert!(gpu.reserve_session(10 << 30));
    }
}
