//! GPU simulator: an event-level model of the NVIDIA A2 the paper serves
//! on (10 execution engines, 2 copy engines, GigaThread dispatch).
//!
//! The paper's GPU findings are *scheduling* phenomena; this module
//! reproduces them mechanistically:
//!
//! * blocks are dispatched FCFS onto free execution engines, streams are
//!   interleaved round-robin with priority accommodation at **block**
//!   granularity (Amert et al., RTSS'17 — paper refs [11], [12]);
//! * the copy engines interleave at **request** granularity within a
//!   process (the coarse interleave that defeats priorities, §VI-B) and
//!   at chunk granularity across processes (MPS/multi-context, §VI-C);
//! * issuing copies interferes with execution dispatch (the GigaThread
//!   central-unit artifact the paper observes in Fig 15c);
//! * contexts time-slice the execution engines; MPS packs contexts.

pub mod copy_engine;
pub mod device;
pub mod params;

pub use copy_engine::{CopyDir, CopyDiscipline, CopyEngine};
pub use device::{GpuEv, GpuNotify, GpuSim, JobSpec, KernelSpec, Sharing};
pub use params::GpuConfig;
