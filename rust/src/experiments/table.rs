//! Plain-text result tables for the figure/benchmark harnesses: aligned
//! console output plus CSV export, one table per paper figure.

use std::fmt::Write as _;

/// One regenerated figure/table: rows of labeled numeric series.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Heading printed above the table.
    pub title: String,
    /// Column names, in print order.
    pub columns: Vec<String>,
    /// `(row label, one value per column)` in insertion order.
    pub rows: Vec<(String, Vec<f64>)>,
    /// Paper-reported reference points, printed beneath the table.
    pub notes: Vec<String>,
}

impl Table {
    /// An empty table with the given title and column names.
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Table {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append one labeled row (must match the column count).
    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) -> &mut Self {
        let label = label.into();
        debug_assert_eq!(
            values.len(),
            self.columns.len(),
            "row {label} width mismatch"
        );
        self.rows.push((label, values));
        self
    }

    /// Append a footnote printed beneath the table.
    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    /// Look up a cell by row label and column name.
    pub fn get(&self, row: &str, col: &str) -> Option<f64> {
        let c = self.columns.iter().position(|x| x == col)?;
        self.rows
            .iter()
            .find(|(l, _)| l == row)
            .and_then(|(_, v)| v.get(c).copied())
    }

    /// Render aligned for the console.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([5])
            .max()
            .unwrap_or(5)
            .max(5);
        let col_w: Vec<usize> = self.columns.iter().map(|c| c.len().max(9)).collect();
        let _ = write!(out, "{:label_w$}", "");
        for (c, w) in self.columns.iter().zip(&col_w) {
            let _ = write!(out, "  {c:>w$}");
        }
        let _ = writeln!(out);
        for (label, vals) in &self.rows {
            let _ = write!(out, "{label:label_w$}");
            for (v, w) in vals.iter().zip(&col_w) {
                let _ = write!(out, "  {:>w$}", fmt_num(*v));
            }
            let _ = writeln!(out);
        }
        for n in &self.notes {
            let _ = writeln!(out, "  # {n}");
        }
        out
    }

    /// CSV (label + columns header, one row per label).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "label");
        for c in &self.columns {
            let _ = write!(out, ",{c}");
        }
        let _ = writeln!(out);
        for (label, vals) in &self.rows {
            let _ = write!(out, "{label}");
            for v in vals {
                let _ = write!(out, ",{v}");
            }
            let _ = writeln!(out);
        }
        out
    }
}

fn fmt_num(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_and_csv() {
        let mut t = Table::new("Fig X", &["total_ms", "copy_ms"]);
        t.row("GDR", vec![1.5, 0.0]);
        t.row("TCP", vec![3.25, 0.5]);
        t.note("paper: GDR < TCP");
        let s = t.render();
        assert!(s.contains("Fig X") && s.contains("GDR") && s.contains("3.25"));
        let csv = t.to_csv();
        assert!(csv.starts_with("label,total_ms,copy_ms"));
        assert!(csv.contains("TCP,3.25,0.5"));
        assert_eq!(t.get("TCP", "copy_ms"), Some(0.5));
        assert_eq!(t.get("TCP", "nope"), None);
        assert_eq!(t.get("nope", "copy_ms"), None);
    }
}
