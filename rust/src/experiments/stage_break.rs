//! Live-plane stage breakdown: per-stage latency per **transport ×
//! batch policy**, measured from wire-carried span timelines
//! (`accelserve stagebreak`) — the live reproduction of the paper's
//! Table I / Figs 5–6 decomposition, with a `--sim` twin that emits
//! the same columns from the sim plane's `ReqRecord` so the two are
//! comparable cell-for-cell.
//!
//! Every client requests span timelines (protocol v2); the server
//! returns the stamps taken at the transport ring boundary, the lane,
//! the scheduler, and the engine, and the client collapses them onto
//! the nine-stage taxonomy ([`Stage`]). Because each breakdown
//! partitions the client-observed round trip exactly, the stage
//! columns of the default (mean) table sum to the end-to-end latency
//! by construction — the structural check the paper's profiling rests
//! on, asserted here per cell.
//!
//! Reading the table: across transports under `b1`, the `req_ms` /
//! `resp_ms` columns carry the whole transport effect (Fig 5); under a
//! batched policy, `gather_ms` shows what the flush window costs and
//! `infer_ms` what fusing buys back (the batching-vs-communication
//! tradeoff the transport comparison turns on).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{BatchCfg, Executor};
use crate::metrics::stats::{Series, Stat};
use crate::models::gen;
use crate::models::manifest::Manifest;
use crate::models::zoo::PaperModel;
use crate::net::params::Transport;
use crate::sim::world::{Scenario, World};
use crate::trace::Stage;
use crate::transport::TransportKind;

use super::{drain_executor, drive_model_clients, Table};

/// Stage-breakdown experiment configuration.
#[derive(Debug, Clone)]
pub struct StageBreakCfg {
    /// Served model (must have artifacts in the manifest).
    pub model: String,
    /// Concurrent closed-loop clients per cell.
    pub clients: usize,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams (1 keeps queueing/batching effects visible).
    pub streams: usize,
    pub transports: Vec<TransportKind>,
    pub policies: Vec<BatchCfg>,
    /// Which statistic the stage columns show. With [`Stat::Mean`]
    /// (the default) the components sum to the end-to-end mean
    /// exactly; quantile columns are near-additive for stable cells.
    pub stat: Stat,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for StageBreakCfg {
    fn default() -> StageBreakCfg {
        StageBreakCfg {
            model: "tiny_mobilenet".to_string(),
            clients: 4,
            requests: 40,
            warmup: 4,
            streams: 1,
            transports: TransportKind::ALL.to_vec(),
            policies: vec![BatchCfg::none(), BatchCfg::deadline(8, 2000)],
            stat: Stat::Mean,
            artifacts_dir: None,
        }
    }
}

/// Column names: the nine stage columns, their sum, and the end-to-end
/// statistics (shared verbatim by the live table and the sim twin).
pub fn stage_columns() -> Vec<&'static str> {
    let mut cols: Vec<&'static str> = Stage::ALL.iter().map(|s| s.column()).collect();
    cols.extend(["sum_ms", "e2e_ms", "p50_ms", "p99_ms"]);
    cols
}

/// One table row from per-stage series plus the end-to-end total.
fn row_values(stages: &[&Series], total: &Series, stat: Stat) -> Vec<f64> {
    let mut vals: Vec<f64> = stages.iter().map(|s| s.stat(stat)).collect();
    let sum: f64 = vals.iter().sum();
    let t = total.summary();
    vals.push(sum);
    vals.push(t.get(stat));
    vals.push(t.p50);
    vals.push(t.p99);
    vals
}

/// Run the live sweep: one row per transport × policy, stage columns
/// from the wire-carried spans.
pub fn run_stage_break(cfg: &StageBreakCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let warm: Vec<String> = manifest
        .batch_sizes(&cfg.model)
        .into_iter()
        .map(|b| format!("{}_b{b}", cfg.model))
        .collect();
    if warm.is_empty() {
        anyhow::bail!(
            "model {} has no artifacts under {} — nothing to measure",
            cfg.model,
            dir.display()
        );
    }
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();

    let mut t = Table::new(
        format!(
            "stage breakdown ({}) — {} × {} clients, {} requests each, {} stream(s)",
            cfg.stat.name(),
            cfg.model,
            cfg.clients,
            cfg.requests,
            cfg.streams
        ),
        &stage_columns(),
    );
    for &policy in &cfg.policies {
        let exec = Arc::new(
            Executor::start(&dir, cfg.streams, policy, &warm_refs)
                .with_context(|| format!("stagebreak executor over {}", dir.display()))?,
        );
        let mut failed: Option<anyhow::Error> = None;
        for &kind in &cfg.transports {
            let stats = match drive_model_clients(
                kind,
                &exec,
                &cfg.model,
                cfg.clients,
                cfg.requests,
                cfg.warmup,
                true, // spans on: the whole experiment reads them
            )
            .with_context(|| format!("cell {} {}", kind.name(), policy.label()))
            {
                Ok(s) => s,
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            };
            if stats.spans.n() == 0 {
                failed = Some(anyhow::anyhow!(
                    "cell {} {}: server returned no span timelines",
                    kind.name(),
                    policy.label()
                ));
                break;
            }
            let stages: Vec<&Series> =
                Stage::ALL.iter().map(|&s| stats.spans.stage(s)).collect();
            t.row(
                format!("{} {}", kind.name(), policy.label()),
                row_values(&stages, &stats.spans.total, cfg.stat),
            );
        }
        // Drain before propagating any cell error — bailing first would
        // park the stream workers forever (same discipline as the other
        // sweeps).
        if !drain_executor(exec) && failed.is_none() {
            anyhow::bail!("stagebreak still holds executor clones");
        }
        if let Some(e) = failed {
            return Err(e);
        }
    }
    t.note("stage columns derive from wire-carried span timelines (protocol v2); sum_ms is their sum and matches e2e_ms exactly under the mean statistic");
    t.note("req/resp include the client wire halves; req also carries the receive-side host bounce that GDR eliminates (Fig 2b)");
    t.note("queue = lane wait before first gather consideration; gather = flush-window wait; disp = sealed-batch wait for a stream");
    Ok(t)
}

/// The simulated twin (`accelserve stagebreak --sim`): identical
/// columns from the sim plane's per-request records, at paper scale.
/// The sim models per-request execution (no lane machinery), so the
/// `queue/gather/disp` columns are structurally zero and its
/// stream-slot queueing lands in `infer_ms` — rows are labeled `b1`
/// for cell-for-cell comparison against the live table's unbatched
/// rows.
pub fn run_sim_stage_break(
    model: &'static PaperModel,
    transports: &[Transport],
    clients: usize,
    requests: usize,
    stat: Stat,
) -> Table {
    let mut t = Table::new(
        format!(
            "sim stage breakdown ({}) — {} × {} clients, {} requests",
            stat.name(),
            model.name,
            clients,
            requests
        ),
        &stage_columns(),
    );
    let zero = Series::new();
    for &tr in transports {
        let sc = Scenario::direct(model, tr)
            .with_clients(clients)
            .with_requests(requests);
        let stats = World::run(sc);
        let a = &stats.all;
        let stages: Vec<&Series> = vec![
            &a.request,  // request-transport
            &zero,       // lane-queue (live-plane machinery)
            &zero,       // gather-wait
            &zero,       // dispatch-wait
            &a.copy_h2d, // copy-h2d
            &a.preproc,  // preproc
            &a.infer,    // infer (incl. stream-slot queueing)
            &a.copy_d2h, // copy-d2h
            &a.response, // response-transport
        ];
        t.row(format!("{} b1", tr.name()), row_values(&stages, &a.total, stat));
    }
    t.note("sim models per-request execution: queue/gather/disp are structurally zero and stream queueing lands in infer_ms");
    t.note("compare against the live table's b1 rows cell-for-cell (same columns, same stage semantics)");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_stage_components_sum_to_e2e() {
        // The acceptance property: every cell's stage components sum
        // to within 5% of the reported end-to-end latency (exact under
        // the mean statistic, up to f64 rounding).
        let cfg = StageBreakCfg {
            clients: 3,
            requests: 6,
            warmup: 2,
            transports: vec![TransportKind::Tcp, TransportKind::Gdr],
            policies: vec![BatchCfg::none(), BatchCfg::deadline(4, 500)],
            ..StageBreakCfg::default()
        };
        let t = run_stage_break(&cfg).unwrap();
        assert_eq!(t.rows.len(), 4);
        for policy in ["b1", "b4@500us"] {
            for kind in ["tcp", "gdr"] {
                let row = format!("{kind} {policy}");
                let sum = t.get(&row, "sum_ms").unwrap();
                let e2e = t.get(&row, "e2e_ms").unwrap();
                assert!(e2e > 0.0, "{row}: e2e {e2e}");
                assert!(
                    (sum - e2e).abs() / e2e < 0.05,
                    "{row}: stages sum to {sum} but e2e is {e2e}"
                );
                assert!(t.get(&row, "infer_ms").unwrap() > 0.0, "{row}");
                assert!(t.get(&row, "p99_ms").unwrap() >= t.get(&row, "p50_ms").unwrap());
            }
        }
    }

    #[test]
    fn quantile_stat_produces_rows() {
        let cfg = StageBreakCfg {
            clients: 2,
            requests: 5,
            warmup: 1,
            transports: vec![TransportKind::Shm],
            policies: vec![BatchCfg::none()],
            stat: Stat::P50,
            ..StageBreakCfg::default()
        };
        let t = run_stage_break(&cfg).unwrap();
        assert_eq!(t.rows.len(), 1);
        let e2e = t.get("shm b1", "e2e_ms").unwrap();
        assert_eq!(e2e, t.get("shm b1", "p50_ms").unwrap());
    }

    #[test]
    fn sim_twin_has_same_columns_and_sums() {
        let model = PaperModel::by_name("MobileNetV3").unwrap();
        let t = run_sim_stage_break(
            model,
            &[Transport::Tcp, Transport::Rdma, Transport::Gdr],
            2,
            80,
            Stat::Mean,
        );
        assert_eq!(t.columns, stage_columns());
        assert_eq!(t.rows.len(), 3);
        for tr in ["tcp", "rdma", "gdr"] {
            let row = format!("{tr} b1");
            let sum = t.get(&row, "sum_ms").unwrap();
            let e2e = t.get(&row, "e2e_ms").unwrap();
            assert!(
                (sum - e2e).abs() / e2e < 0.05,
                "{row}: stages sum to {sum} but e2e is {e2e}"
            );
            assert_eq!(t.get(&row, "queue_ms"), Some(0.0), "{row}");
        }
        // The sim's structural property: GDR has no copies, RDMA does.
        assert_eq!(t.get("gdr b1", "h2d_ms"), Some(0.0));
        assert!(t.get("rdma b1", "h2d_ms").unwrap() > 0.0);
    }
}
