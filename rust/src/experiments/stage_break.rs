//! Live-plane stage breakdown: per-stage latency per **transport ×
//! batch policy**, measured from wire-carried span timelines
//! (`accelserve stagebreak`) — the live reproduction of the paper's
//! Table I / Figs 5–6 decomposition, with a `--sim` twin that emits
//! the same columns from the sim plane's `ReqRecord` so the two are
//! comparable cell-for-cell.
//!
//! Every client requests span timelines (protocol v2); the server
//! returns the stamps taken at the transport ring boundary, the lane,
//! the scheduler, and the engine, and the client collapses them onto
//! the nine-stage taxonomy ([`Stage`]). Because each breakdown
//! partitions the client-observed round trip exactly, the stage
//! columns of the default (mean) table sum to the end-to-end latency
//! by construction — the structural check the paper's profiling rests
//! on, asserted here per cell.
//!
//! Reading the table: across transports under `b1`, the `req_ms` /
//! `resp_ms` columns carry the whole transport effect (Fig 5); under a
//! batched policy, `gather_ms` shows what the flush window costs and
//! `infer_ms` what fusing buys back (the batching-vs-communication
//! tradeoff the transport comparison turns on).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{BatchCfg, Executor, SEAL_REASON_NAMES};
use crate::metrics::stats::{Series, Stat};
use crate::models::gen;
use crate::models::manifest::Manifest;
use crate::models::zoo::PaperModel;
use crate::net::params::Transport;
use crate::sim::world::{RunStats, Scenario, World};
use crate::trace::{ArgVal, ChromeTrace, Stage};
use crate::transport::TransportKind;

use super::{drain_executor, drive_model_clients, Table};

/// Stage-breakdown experiment configuration.
#[derive(Debug, Clone)]
pub struct StageBreakCfg {
    /// Served model (must have artifacts in the manifest).
    pub model: String,
    /// Concurrent closed-loop clients per cell.
    pub clients: usize,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams (1 keeps queueing/batching effects visible).
    pub streams: usize,
    pub transports: Vec<TransportKind>,
    pub policies: Vec<BatchCfg>,
    /// Which statistic the stage columns show. With [`Stat::Mean`]
    /// (the default) the components sum to the end-to-end mean
    /// exactly; quantile columns are near-additive for stable cells.
    pub stat: Stat,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
    /// Write a Chrome trace-event JSON of every cell's request
    /// timelines here (`--trace-out`; load in `ui.perfetto.dev`).
    pub trace_out: Option<PathBuf>,
}

impl Default for StageBreakCfg {
    fn default() -> StageBreakCfg {
        StageBreakCfg {
            model: "tiny_mobilenet".to_string(),
            clients: 4,
            requests: 40,
            warmup: 4,
            streams: 1,
            transports: TransportKind::ALL.to_vec(),
            policies: vec![BatchCfg::none(), BatchCfg::deadline(8, 2000)],
            stat: Stat::Mean,
            artifacts_dir: None,
            trace_out: None,
        }
    }
}

/// Column names: the nine stage columns, their sum, and the end-to-end
/// statistics (shared verbatim by the live table and the sim twin).
pub fn stage_columns() -> Vec<&'static str> {
    let mut cols: Vec<&'static str> = Stage::ALL.iter().map(|s| s.column()).collect();
    cols.extend(["sum_ms", "e2e_ms", "p50_ms", "p99_ms"]);
    cols
}

/// One table row from per-stage series plus the end-to-end total.
fn row_values(stages: &[&Series], total: &Series, stat: Stat) -> Vec<f64> {
    let mut vals: Vec<f64> = stages.iter().map(|s| s.stat(stat)).collect();
    let sum: f64 = vals.iter().sum();
    let t = total.summary();
    vals.push(sum);
    vals.push(t.get(stat));
    vals.push(t.p50);
    vals.push(t.p99);
    vals
}

/// Run the live sweep: one row per transport × policy, stage columns
/// from the wire-carried spans.
pub fn run_stage_break(cfg: &StageBreakCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let warm: Vec<String> = manifest
        .batch_sizes(&cfg.model)
        .into_iter()
        .map(|b| format!("{}_b{b}", cfg.model))
        .collect();
    if warm.is_empty() {
        anyhow::bail!(
            "model {} has no artifacts under {} — nothing to measure",
            cfg.model,
            dir.display()
        );
    }
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();

    let mut t = Table::new(
        format!(
            "stage breakdown ({}) — {} × {} clients, {} requests each, {} stream(s)",
            cfg.stat.name(),
            cfg.model,
            cfg.clients,
            cfg.requests,
            cfg.streams
        ),
        &stage_columns(),
    );
    let mut tc = ChromeTrace::new();
    for &policy in &cfg.policies {
        let exec = Arc::new(
            Executor::start(&dir, cfg.streams, policy, &warm_refs)
                .with_context(|| format!("stagebreak executor over {}", dir.display()))?,
        );
        let mut failed: Option<anyhow::Error> = None;
        for &kind in &cfg.transports {
            let stats = match drive_model_clients(
                kind,
                &exec,
                &cfg.model,
                cfg.clients,
                cfg.requests,
                cfg.warmup,
                true, // spans on: the whole experiment reads them
            )
            .with_context(|| format!("cell {} {}", kind.name(), policy.label()))
            {
                Ok(s) => s,
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            };
            if stats.spans.n() == 0 {
                failed = Some(anyhow::anyhow!(
                    "cell {} {}: server returned no span timelines",
                    kind.name(),
                    policy.label()
                ));
                break;
            }
            let stages: Vec<&Series> =
                Stage::ALL.iter().map(|&s| stats.spans.stage(s)).collect();
            if cfg.trace_out.is_some() {
                // One track per transport ring (client connection),
                // namespaced by cell so policies don't overlap.
                for rec in &stats.timeline {
                    let track = tc.track(&format!(
                        "ring/{}/{}/c{}",
                        kind.name(),
                        policy.label(),
                        rec.client
                    ));
                    let args = [("client", ArgVal::U64(rec.client as u64))];
                    tc.block(track, rec.t0_ns, &rec.span, rec.total_ns, &args);
                }
            }
            t.row(
                format!("{} {}", kind.name(), policy.label()),
                row_values(&stages, &stats.spans.total, cfg.stat),
            );
        }
        if cfg.trace_out.is_some() && failed.is_none() {
            export_counter_tracks(&mut tc, &exec, &policy.label());
        }
        // Drain before propagating any cell error — bailing first would
        // park the stream workers forever (same discipline as the other
        // sweeps).
        if !drain_executor(exec) && failed.is_none() {
            anyhow::bail!("stagebreak still holds executor clones");
        }
        if let Some(e) = failed {
            return Err(e);
        }
    }
    if let Some(path) = &cfg.trace_out {
        tc.save(path)?;
        t.note(format!(
            "wrote {} timeline events to {} (load in ui.perfetto.dev)",
            tc.len(),
            path.display()
        ));
    }
    t.note("stage columns derive from wire-carried span timelines (protocol v2); sum_ms is their sum and matches e2e_ms exactly under the mean statistic");
    t.note("req/resp include the client wire halves; req also carries the receive-side host bounce that GDR eliminates (Fig 2b)");
    t.note("queue = lane wait before first gather consideration; gather = flush-window wait; disp = sealed-batch wait for a stream");
    Ok(t)
}

/// Export one executor's telemetry as a counter track
/// (`counters/{label}`): per-tick counter deltas and gauge levels from
/// the sampler ring, closed by the current gauge levels read straight
/// from the registry — so every export carries at least one `"ph":"C"`
/// sample even when the run finished inside the first sampler period.
pub(crate) fn export_counter_tracks(tc: &mut ChromeTrace, exec: &Executor, label: &str) {
    let track = tc.track(&format!("counters/{label}"));
    let mut last_ms = 0;
    for s in exec.sample_ring() {
        let ts_ns = s.at_ms * 1_000_000;
        for (name, delta) in &s.counters {
            tc.counter(track, name, ts_ns, *delta);
        }
        for (name, level) in &s.gauges {
            tc.counter(track, name, ts_ns, *level);
        }
        last_ms = s.at_ms;
    }
    let snap = exec.telemetry().snapshot();
    let ts_ns = (last_ms + 1) * 1_000_000;
    for (name, level) in &snap.gauges {
        tc.counter(track, name, ts_ns, *level);
    }
}

/// The simulated twin (`accelserve stagebreak --sim`): identical
/// columns from the sim plane's per-request records, at paper scale.
/// The sim lane model is always on here, so the `queue/gather/disp`
/// columns carry real scheduler residence — one row per transport ×
/// policy, cell-for-cell comparable against the live table. With
/// `trace_out`, the sim's request timelines and per-stream batch
/// windows export in the same Chrome-trace format as the live run.
#[allow(clippy::too_many_arguments)]
pub fn run_sim_stage_break(
    model: &'static PaperModel,
    transports: &[Transport],
    policies: &[BatchCfg],
    clients: usize,
    requests: usize,
    streams: usize,
    stat: Stat,
    trace_out: Option<&Path>,
) -> Result<Table> {
    let mut t = Table::new(
        format!(
            "sim stage breakdown ({}) — {} × {} clients, {} requests, {} stream(s)",
            stat.name(),
            model.name,
            clients,
            requests,
            streams
        ),
        &stage_columns(),
    );
    let mut tc = ChromeTrace::new();
    for &policy in policies {
        for &tr in transports {
            let mut sc = Scenario::direct(model, tr)
                .with_clients(clients)
                .with_requests(requests)
                .with_streams(streams)
                .with_batching(policy.max_batch, policy.flush_us)
                .with_lanes();
            if trace_out.is_some() {
                sc = sc.with_trace();
            }
            let stats = World::run(sc);
            if trace_out.is_some() {
                export_sim_cell(&mut tc, &stats, tr, policy);
            }
            let a = &stats.all;
            let stages: Vec<&Series> = vec![
                &a.request,
                &a.lane_queue,
                &a.gather_wait,
                &a.dispatch_wait,
                &a.copy_h2d,
                &a.preproc,
                &a.infer,
                &a.copy_d2h,
                &a.response,
            ];
            t.row(
                format!("{} {}", tr.name(), policy.label()),
                row_values(&stages, &a.total, stat),
            );
        }
    }
    if let Some(path) = trace_out {
        tc.save(path)?;
        t.note(format!(
            "wrote {} timeline events to {} (load in ui.perfetto.dev)",
            tc.len(),
            path.display()
        ));
    }
    t.note("sim lane model on: queue/gather/disp carry scheduler residence under the row's policy");
    t.note("compare against the live table cell-for-cell (same columns, same stage semantics)");
    Ok(t)
}

/// Export one sim cell into `tc`: per-client request timelines (nine
/// stage tiles each) plus one event per executed batch on its stream's
/// track. Shared with the other sim sweeps (`mixsweep --sim`).
pub(crate) fn export_sim_cell(
    tc: &mut ChromeTrace,
    stats: &RunStats,
    tr: Transport,
    policy: BatchCfg,
) {
    for span in &stats.timeline {
        let track = tc.track(&format!(
            "sim/{}/{}/c{}",
            tr.name(),
            policy.label(),
            span.client
        ));
        let args = [("client", ArgVal::U64(span.client as u64))];
        tc.record(track, span.t_sent.0, &span.rec, &args);
    }
    let mut streams: Vec<usize> = stats.batches.iter().map(|b| b.stream).collect();
    streams.sort_unstable();
    streams.dedup();
    for s in streams {
        let track = tc.track(&format!("stream/{}/{}/s{s}", tr.name(), policy.label()));
        for b in stats.batches.iter().filter(|b| b.stream == s) {
            let seal = SEAL_REASON_NAMES[b.reason as usize];
            let args = [
                ("batch", ArgVal::U64(b.size as u64)),
                ("seal", ArgVal::Str(seal.to_string())),
            ];
            tc.event(
                track,
                &b.model,
                "batch",
                b.dispatch.0,
                b.done.0.saturating_sub(b.dispatch.0),
                &args,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_stage_components_sum_to_e2e() {
        // The acceptance property: every cell's stage components sum
        // to within 5% of the reported end-to-end latency (exact under
        // the mean statistic, up to f64 rounding).
        let cfg = StageBreakCfg {
            clients: 3,
            requests: 6,
            warmup: 2,
            transports: vec![TransportKind::Tcp, TransportKind::Gdr],
            policies: vec![BatchCfg::none(), BatchCfg::deadline(4, 500)],
            ..StageBreakCfg::default()
        };
        let t = run_stage_break(&cfg).unwrap();
        assert_eq!(t.rows.len(), 4);
        for policy in ["b1", "b4@500us"] {
            for kind in ["tcp", "gdr"] {
                let row = format!("{kind} {policy}");
                let sum = t.get(&row, "sum_ms").unwrap();
                let e2e = t.get(&row, "e2e_ms").unwrap();
                assert!(e2e > 0.0, "{row}: e2e {e2e}");
                assert!(
                    (sum - e2e).abs() / e2e < 0.05,
                    "{row}: stages sum to {sum} but e2e is {e2e}"
                );
                assert!(t.get(&row, "infer_ms").unwrap() > 0.0, "{row}");
                assert!(t.get(&row, "p99_ms").unwrap() >= t.get(&row, "p50_ms").unwrap());
            }
        }
    }

    #[test]
    fn quantile_stat_produces_rows() {
        let cfg = StageBreakCfg {
            clients: 2,
            requests: 5,
            warmup: 1,
            transports: vec![TransportKind::Shm],
            policies: vec![BatchCfg::none()],
            stat: Stat::P50,
            ..StageBreakCfg::default()
        };
        let t = run_stage_break(&cfg).unwrap();
        assert_eq!(t.rows.len(), 1);
        let e2e = t.get("shm b1", "e2e_ms").unwrap();
        assert_eq!(e2e, t.get("shm b1", "p50_ms").unwrap());
    }

    #[test]
    fn sim_twin_has_same_columns_and_sums() {
        let model = PaperModel::by_name("MobileNetV3").unwrap();
        let t = run_sim_stage_break(
            model,
            &[Transport::Tcp, Transport::Rdma, Transport::Gdr],
            &[BatchCfg::none(), BatchCfg::deadline(4, 500)],
            2,
            80,
            0,
            Stat::Mean,
            None,
        )
        .unwrap();
        assert_eq!(t.columns, stage_columns());
        assert_eq!(t.rows.len(), 6);
        for policy in ["b1", "b4@500us"] {
            for tr in ["tcp", "rdma", "gdr"] {
                let row = format!("{tr} {policy}");
                let sum = t.get(&row, "sum_ms").unwrap();
                let e2e = t.get(&row, "e2e_ms").unwrap();
                assert!(
                    (sum - e2e).abs() / e2e < 0.05,
                    "{row}: stages sum to {sum} but e2e is {e2e}"
                );
            }
        }
        // Unbatched with ample streams: zero scheduler residence.
        assert_eq!(t.get("tcp b1", "queue_ms"), Some(0.0));
        assert_eq!(t.get("tcp b1", "gather_ms"), Some(0.0));
        // A flush window makes batch heads wait for peers.
        assert!(t.get("tcp b4@500us", "gather_ms").unwrap() > 0.0);
        // The sim's structural property: GDR has no copies, RDMA does.
        assert_eq!(t.get("gdr b1", "h2d_ms"), Some(0.0));
        assert!(t.get("rdma b1", "h2d_ms").unwrap() > 0.0);
    }
}
