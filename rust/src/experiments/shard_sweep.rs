//! Live-plane sharding sweep: aggregate throughput and tail latency per
//! **backend count × transport × placement policy** through the routing
//! gateway (`accelserve shardsweep`) — the repo's multi-coordinator
//! scaling experiment.
//!
//! The paper's serving pipeline "spans across multiple compute nodes
//! and proxies interconnected via a dedicated network fabric" (§I);
//! this sweep builds that fabric in-process. Each cell starts N fresh
//! single-stream coordinators, fronts them with a [`Router`] under the
//! chosen placement policy, and drives a fixed closed-loop client pool
//! spread over three models. With one backend the shared stream is the
//! bottleneck; with two, placement splits the models across backends
//! and aggregate throughput should approach 2× — the scaling curve the
//! table renders. A final pipeline row chains
//! `tiny_mobilenet → tiny_segnet` through [`FLAG_PIPELINE`] requests:
//! the gateway runs stage 1 on its placed backend feeding stage 0's
//! output straight across the fabric, with **zero client round-trips**
//! between stages — verified here by decoding a spans-on chain reply
//! and checking the stage windows sit back-to-back on the gateway
//! clock.
//!
//! [`FLAG_PIPELINE`]: crate::coordinator::protocol::FLAG_PIPELINE
//!
//! Every cell cross-checks the router's per-backend job accounting
//! against the client tally: single-stage cells must satisfy
//! `Σ backend jobs == oks`, pipeline cells `Σ backend jobs == 2 × oks`
//! (each chained request is one job per stage).

use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::protocol::{PipelineStage, Request, Response};
use crate::coordinator::router::{BackendSpec, Placement, Router, RouterCfg};
use crate::coordinator::{
    handle_conn, handle_routed_conn, run_client_loop, BatchCfg, Executor, LoadCfg, SchedCfg,
    TimelineRec, DEFAULT_QUEUE_CAP,
};
use crate::metrics::telemetry::{Histo, HistoSnap};
use crate::models::gen;
use crate::trace::{ArgVal, ChromeTrace};
use crate::transport::{connected_pair, TransportKind};

use super::{drain_executor, Table};

/// The model mix every cell serves, assigned to clients round-robin.
/// Three models over two backends forces an uneven (2:1) split under
/// any placement — the realistic sharding shape.
pub const SHARD_MODELS: [&str; 3] = ["tiny_mobilenet", "tiny_resnet", "tiny_segnet"];

/// The chain the pipeline row exercises: stage 0 → stage 1.
pub const PIPELINE_STAGES: [&str; 2] = ["tiny_mobilenet", "tiny_segnet"];

/// Sharding-sweep configuration.
#[derive(Debug, Clone)]
pub struct ShardCfg {
    /// Backend counts to sweep (one row per count × transport ×
    /// placement).
    pub backends: Vec<usize>,
    pub placements: Vec<Placement>,
    pub transports: Vec<TransportKind>,
    /// Closed-loop clients, spread over [`SHARD_MODELS`] round-robin.
    pub clients: usize,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams per backend (1 keeps each backend trivially
    /// saturable, so the scaling curve is about placement, not GPUs).
    pub streams: usize,
    /// Append a pipeline row (2-stage chain) at the largest backend
    /// count per transport.
    pub pipeline: bool,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
    /// Write a Chrome trace-event timeline of every measured request
    /// (all cells, one track per cell × client) to this path.
    pub trace_out: Option<PathBuf>,
}

impl Default for ShardCfg {
    fn default() -> ShardCfg {
        ShardCfg {
            backends: vec![1, 2],
            placements: Placement::all().to_vec(),
            transports: vec![TransportKind::Tcp],
            clients: 6,
            requests: 30,
            warmup: 3,
            streams: 1,
            pipeline: true,
            artifacts_dir: None,
            trace_out: None,
        }
    }
}

/// Start `n` fresh single-purpose backends and wrap them in a router.
/// Each [`BackendSpec`] dials an in-process connected pair and spawns a
/// [`handle_conn`] server thread for it, parked in `threads` so the
/// cell can join them once the router (and with it every pooled
/// connection) is gone.
fn build_router(
    kind: TransportKind,
    execs: &[Arc<Executor>],
    placement: Placement,
    hint: usize,
    threads: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) -> Router {
    let specs = execs
        .iter()
        .enumerate()
        .map(|(i, exec)| {
            let exec = exec.clone();
            let threads = threads.clone();
            BackendSpec::new(format!("backend-{i}"), move || {
                let (client, server) = connected_pair(kind, hint)?;
                let e2 = exec.clone();
                threads
                    .lock()
                    .unwrap()
                    .push(std::thread::spawn(move || handle_conn(server, &e2)));
                Ok(client)
            })
        })
        .collect();
    Router::new(
        specs,
        RouterCfg {
            placement,
            ..RouterCfg::default()
        },
    )
}

/// What one cell measured.
struct CellOut {
    /// End-to-end latency histogram (ns) — the telemetry plane's
    /// mergeable bucket layout, so the row's p50/p99 read through the
    /// same quantile path the live Prometheus export uses.
    total: HistoSnap,
    /// Requests answered OK (warmup included).
    oks: usize,
    duration_s: f64,
    rebalances: u64,
    /// Measured-request spans for timeline export (empty unless the
    /// sweep is tracing; pipeline replies carry no single-span block).
    timeline: Vec<TimelineRec>,
}

/// Drive the client pool through routed gateway connections. Every
/// client gets a private connected pair whose server side runs
/// [`handle_routed_conn`] against the shared router; the scope joins
/// both halves before returning.
fn drive_cell(
    kind: TransportKind,
    router: &Router,
    cfg: &ShardCfg,
    hint: usize,
    pipeline: bool,
) -> Result<CellOut> {
    let payload_elems = gen::IN_H * gen::IN_W * gen::CHANNELS;
    let fwd = AtomicU64::new(0);
    let t0 = Instant::now();
    let runs: Vec<_> = std::thread::scope(|s| -> Result<Vec<_>> {
        let mut handles = Vec::with_capacity(cfg.clients);
        for c in 0..cfg.clients {
            let (mut client, server) = connected_pair(kind, hint)?;
            let fwd_ref = &fwd;
            s.spawn(move || handle_routed_conn(server, router, fwd_ref));
            let lc = LoadCfg {
                model: if pipeline {
                    PIPELINE_STAGES[0].to_string()
                } else {
                    SHARD_MODELS[c % SHARD_MODELS.len()].to_string()
                },
                raw: false,
                spans: cfg.trace_out.is_some() && !pipeline,
                n_clients: cfg.clients,
                requests_per_client: cfg.requests + cfg.warmup,
                priority_client: false,
                payload_elems,
                warmup: cfg.warmup,
                deadline_us: None,
                credits: false,
                timeout: None,
                pipeline: if pipeline {
                    vec![PIPELINE_STAGES[1].to_string()]
                } else {
                    vec![]
                },
            };
            handles.push(s.spawn(move || run_client_loop(client.as_mut(), &lc, c)));
        }
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("shardsweep client panicked")))
            .collect()
    })?;
    let duration_s = t0.elapsed().as_secs_f64();

    let total_h = Histo::new();
    let mut oks = 0usize;
    let mut timeline = Vec::new();
    for run in runs {
        if let Some(e) = run.fatal {
            return Err(e.context("shardsweep client died"));
        }
        if run.req_errors > 0 || run.sheds > 0 {
            bail!(
                "unloaded shardsweep cell saw {} request error(s), {} shed(s)",
                run.req_errors,
                run.sheds
            );
        }
        oks += run.oks;
        for rec in &run.recs {
            total_h.observe(rec.rec.total.0);
            if let Some(block) = &rec.span {
                timeline.push(TimelineRec {
                    client: rec.rec.client,
                    t0_ns: rec.sent_at.saturating_duration_since(t0).as_nanos() as u64,
                    total_ns: rec.rec.total.0,
                    span: block.clone(),
                });
            }
        }
    }
    Ok(CellOut {
        total: total_h.snap(),
        oks,
        duration_s,
        rebalances: router.rebalances(),
        timeline,
    })
}

/// One spans-on chained request through the router, decoded and checked
/// for the zero-round-trip property: consecutive stage windows must sit
/// back-to-back on the gateway clock (stage K+1 dispatched after stage
/// K replied, with no hop back to the client in between), and each
/// stage must carry the backend's span timeline. Returns the verified
/// stage records — the raw material for the cross-tier timeline
/// (gateway window tiles + backend span tiles + flow arrows).
fn verify_pipeline_spans(
    kind: TransportKind,
    router: &Router,
    hint: usize,
) -> Result<Vec<PipelineStage>> {
    let payload_elems = gen::IN_H * gen::IN_W * gen::CHANNELS;
    let fwd = AtomicU64::new(0);
    std::thread::scope(|s| -> Result<Vec<PipelineStage>> {
        let (mut client, server) = connected_pair(kind, hint)?;
        let fwd_ref = &fwd;
        s.spawn(move || handle_routed_conn(server, router, fwd_ref));
        let req = Request {
            model: PIPELINE_STAGES[0].to_string(),
            raw: false,
            spans: true,
            prio: 0,
            deadline_us: None,
            credits: false,
            pipeline: vec![PIPELINE_STAGES[1].to_string()],
            payload: crate::coordinator::protocol::f32s_to_bytes(&vec![0.5; payload_elems]),
        };
        client.send(&req.encode())?;
        let resp = Response::decode(&client.recv()?)?;
        drop(client);
        let Response::Pipeline { stages, payload } = resp else {
            bail!("pipeline probe answered with a non-pipeline response");
        };
        if stages.len() != PIPELINE_STAGES.len() {
            bail!("chain ran {} stages, wanted {}", stages.len(), PIPELINE_STAGES.len());
        }
        for (stage, want) in stages.iter().zip(PIPELINE_STAGES) {
            if stage.model != want {
                bail!("stage order corrupted: got {}, wanted {want}", stage.model);
            }
            if stage.span.is_empty() {
                bail!("stage {} returned no span timeline", stage.model);
            }
            if stage.recv_ns < stage.sent_ns {
                bail!("stage {} window runs backwards", stage.model);
            }
        }
        // The zero-round-trip acceptance check: stage 1 left the gateway
        // only after stage 0's reply arrived, on the same clock — there
        // is no client-side gap for a round-trip to hide in.
        if stages[1].sent_ns < stages[0].recv_ns {
            bail!("stage 1 dispatched before stage 0 replied");
        }
        if payload.is_empty() || payload.len() % 4 != 0 {
            bail!("chain output is not an f32 tensor ({} bytes)", payload.len());
        }
        Ok(stages)
    })
}

/// Export the verified pipeline probe as a cross-tier timeline: one
/// gateway track tiling each stage's send→recv window, one backend
/// track per stage tiling the backend's own span inside that window,
/// and an `"s"`/`"f"` flow arrow per stage tying the gateway tile to
/// its backend counterpart — Fig 2's multi-node pipeline, drawn.
fn export_pipeline_flows(tc: &mut ChromeTrace, row: &str, stages: &[PipelineStage]) {
    let gw = tc.track(&format!("gateway/{row}"));
    for (i, st) in stages.iter().enumerate() {
        let dur = st.recv_ns.saturating_sub(st.sent_ns);
        tc.event(
            gw,
            &st.model,
            "stage",
            st.sent_ns,
            dur,
            &[("stage", ArgVal::U64(i as u64))],
        );
    }
    for (i, st) in stages.iter().enumerate() {
        let be = tc.track(&format!("backend/{row}/{}", st.model));
        let dur = st.recv_ns.saturating_sub(st.sent_ns);
        tc.block(be, st.sent_ns, &st.span, dur, &[("stage", ArgVal::U64(i as u64))]);
        let id = i as u64 + 1;
        tc.flow_start(gw, &st.model, st.sent_ns, id);
        tc.flow_finish(be, &st.model, st.sent_ns + dur / 2, id);
    }
}

/// Run the sweep. Each cell: N fresh executors → router → fixed client
/// pool → one table row. Pipeline rows ride at the largest backend
/// count per transport and additionally verify the span timeline of a
/// chained request.
pub fn run_shard_sweep(cfg: &ShardCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    let warm: Vec<String> = SHARD_MODELS.iter().map(|m| format!("{m}_b1")).collect();
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();
    // Big enough for the fattest frame in the mix (the segnet output
    // tensor), so RDMA/GDR stay single-chunk on the inter-stage hop.
    let hint = 21504 * 4 + 96;

    let mut t = Table::new(
        format!(
            "shard sweep — {} clients over {:?}, {} stream(s)/backend, {} requests/client",
            cfg.clients, SHARD_MODELS, cfg.streams, cfg.requests
        ),
        &["backends", "clients", "p50_ms", "p99_ms", "thr_rps", "share_max", "rebal"],
    );
    let mut tc = ChromeTrace::new();
    for &kind in &cfg.transports {
        for &placement in &cfg.placements {
            for &n in &cfg.backends {
                let row = format!("{} n{n} {}", kind.name(), placement.name());
                run_cell(
                    cfg,
                    &dir,
                    &warm_refs,
                    kind,
                    placement,
                    n,
                    hint,
                    false,
                    &row,
                    &mut t,
                    &mut tc,
                )
                .with_context(|| format!("cell {row}"))?;
            }
        }
        if cfg.pipeline {
            let n = cfg.backends.iter().copied().max().unwrap_or(1);
            let row = format!("{} pipe n{n}", kind.name());
            run_cell(
                cfg,
                &dir,
                &warm_refs,
                kind,
                Placement::ConsistentHash,
                n,
                hint,
                true,
                &row,
                &mut t,
                &mut tc,
            )
            .with_context(|| format!("cell {row}"))?;
        }
    }
    if let Some(path) = &cfg.trace_out {
        tc.save(path)?;
        t.note(format!(
            "wrote {} timeline events to {} (load in ui.perfetto.dev)",
            tc.len(),
            path.display()
        ));
    }
    t.note("share_max = largest backend's share of answered jobs (%); rebal = routing decisions diverging from the home placement");
    t.note("pipe rows chain tiny_mobilenet → tiny_segnet inside the gateway (FLAG_PIPELINE): one client round-trip for the whole chain; a spans-on probe verifies the stage windows are back-to-back");
    t.note("cross-checked per cell: Σ backend jobs == oks (×2 for pipeline rows, one job per chained stage)");
    Ok(t)
}

/// One cell: fresh executors, router, client pool, invariants, row.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    cfg: &ShardCfg,
    dir: &std::path::Path,
    warm_refs: &[&str],
    kind: TransportKind,
    placement: Placement,
    n: usize,
    hint: usize,
    pipeline: bool,
    row: &str,
    t: &mut Table,
    tc: &mut ChromeTrace,
) -> Result<()> {
    let sched = || SchedCfg {
        // Batching off: each backend's throughput cap is exactly
        // streams / svc, so the scaling curve isolates placement.
        default: BatchCfg::none(),
        per_model: Vec::new(),
        queue_cap: DEFAULT_QUEUE_CAP,
    };
    let mut execs = Vec::with_capacity(n);
    for _ in 0..n {
        execs.push(Arc::new(
            Executor::start_with(dir, cfg.streams, sched(), warm_refs)
                .with_context(|| format!("shardsweep executor over {}", dir.display()))?,
        ));
    }
    let backend_threads = Arc::new(Mutex::new(Vec::new()));
    let router = build_router(kind, &execs, placement, hint, &backend_threads);
    let out = drive_cell(kind, &router, cfg, hint, pipeline);
    let probe = if pipeline && out.is_ok() {
        verify_pipeline_spans(kind, &router, hint).map(Some)
    } else {
        Ok(None)
    };
    // Teardown in dependency order: the router owns the pooled backend
    // connections, so dropping it lets every parked `handle_conn`
    // thread see the close and exit before we reclaim the executors.
    let jobs_after = router.jobs_per_backend();
    drop(router);
    for th in backend_threads.lock().unwrap().drain(..) {
        th.join().map_err(|_| anyhow!("backend server thread panicked"))?;
    }
    for exec in execs {
        if !drain_executor(exec) {
            bail!("shardsweep still holds executor clones");
        }
    }
    let out = out?;
    let probe_stages = probe?;
    for rec in &out.timeline {
        let track = tc.track(&format!("ring/{row}/c{}", rec.client));
        let args = [("client", ArgVal::U64(rec.client as u64))];
        tc.block(track, rec.t0_ns, &rec.span, rec.total_ns, &args);
    }
    if let (Some(stages), true) = (&probe_stages, cfg.trace_out.is_some()) {
        export_pipeline_flows(tc, row, stages);
    }

    // Job-share bookkeeping must reconcile with the client tally; the
    // spans probe (pipeline rows) adds one more chained request.
    let stages = if pipeline { 2 } else { 1 };
    let oks_total = out.oks + usize::from(pipeline);
    let expect = (oks_total * stages) as u64;
    let jobs_sum: u64 = jobs_after.iter().sum();
    if jobs_sum != expect {
        bail!("job accounting drift: backends answered {jobs_sum}, clients saw {expect}");
    }

    let share_max = 100.0 * jobs_after.iter().copied().max().unwrap_or(0) as f64
        / jobs_sum.max(1) as f64;
    t.row(
        row.to_string(),
        vec![
            n as f64,
            cfg.clients as f64,
            out.total.quantile(0.5) as f64 / 1e6,
            out.total.quantile(0.99) as f64 / 1e6,
            out.oks as f64 / out.duration_s.max(f64::EPSILON),
            share_max,
            out.rebalances as f64,
        ],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shardsweep_two_backends_scale_and_pipeline_chains() {
        // Smoke: tcp only, hash placement, 1 vs 2 backends plus the
        // pipeline row. Two single-stream backends must clear >1.5× the
        // aggregate throughput of one at saturation (six closed-loop
        // clients keep both sides pinned), and the pipeline row must
        // complete its chain — the span back-to-back check runs inside
        // the cell and fails the sweep on any client round-trip.
        let cfg = ShardCfg {
            backends: vec![1, 2],
            placements: vec![Placement::ConsistentHash],
            transports: vec![TransportKind::Tcp],
            requests: 25,
            warmup: 3,
            ..ShardCfg::default()
        };
        let t = run_shard_sweep(&cfg).unwrap();
        assert_eq!(t.rows.len(), 3);
        let thr1 = t.get("tcp n1 hash", "thr_rps").unwrap();
        let thr2 = t.get("tcp n2 hash", "thr_rps").unwrap();
        assert!(thr1 > 0.0);
        assert!(
            thr2 > 1.5 * thr1,
            "2 backends reached only {thr2:.1} rps vs {thr1:.1} on one — not scaling"
        );
        // Clean cells never walk off the home placement.
        assert_eq!(t.get("tcp n1 hash", "rebal").unwrap(), 0.0);
        assert_eq!(t.get("tcp n2 hash", "rebal").unwrap(), 0.0);
        // The known 2-backend split of the three models is 2:1.
        let share = t.get("tcp n2 hash", "share_max").unwrap();
        assert!(share < 100.0, "one backend answered everything");
        let pipe = t.get("tcp pipe n2", "thr_rps").unwrap();
        assert!(pipe > 0.0, "pipeline row served nothing");
    }
}
