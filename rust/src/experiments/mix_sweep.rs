//! Live-plane model-mix sweep: per-model latency, throughput and
//! achieved batch per **transport × model**, with every model's
//! clients running *concurrently* against one shared executor
//! (`accelserve mixsweep`) — the experiment that shows continuous
//! multi-model batching actually interleaving on the stream pool.
//!
//! PR 3's `batchsweep` fuses same-model requests but measures one
//! model at a time; a mixed workload (the paper's multi-stage,
//! multi-model pipeline setting, and the explicit concern of
//! "GPUs, CPUs, and NICs: Rethinking the Network's Role in Serving
//! Complex AI Pipelines", arXiv:2502.15712) additionally needs the
//! scheduler to serve *different* models concurrently from one stream
//! pool instead of queueing one model behind the other. Each cell
//! here drives `clients_per_model` closed-loop clients **per model**
//! at the same time; the table reports per-model p50/p99/mean
//! latency, per-model throughput, the per-model mean achieved batch
//! ([`Executor::model_batch_counters`]), and the executor's
//! cross-model **interleave count** — how many dispatches switched
//! model relative to the previous dispatch. A serialized scheduler
//! scores ~1 interleave per cell; the continuous scheduler scores
//! many.
//!
//! [`run_sim_mix`] is the simulated twin: the same mixed workload at
//! paper scale (`Scenario::with_model_mix` over the paper's models)
//! reporting per-model latency and the sim's own interleave counter.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{BatchCfg, Executor, LiveStats, ModelPolicy, SchedCfg};
use crate::models::gen;
use crate::models::manifest::Manifest;
use crate::models::zoo::PaperModel;
use crate::net::params::Transport;
use crate::sim::world::{Scenario, World};
use crate::trace::{ArgVal, ChromeTrace};
use crate::transport::TransportKind;

use super::stage_break::export_sim_cell;
use super::{drain_executor, drive_model_clients, Table};

/// Mix-sweep configuration.
#[derive(Debug, Clone)]
pub struct MixCfg {
    /// Served models, each driven by its own client group (must all
    /// have artifacts in the manifest).
    pub models: Vec<String>,
    /// Concurrent closed-loop clients **per model**.
    pub clients_per_model: usize,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams shared by all models. 2 (the default) lets
    /// two models run concurrently while staying oversubscribed
    /// enough that batching stays visible.
    pub streams: usize,
    pub transports: Vec<TransportKind>,
    /// Default batching policy for every model lane.
    pub policy: BatchCfg,
    /// Per-model policy overrides (`--model-batch`, scenario
    /// `model_batch`).
    pub per_model: Vec<(String, ModelPolicy)>,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
    /// Write a Chrome trace-event JSON of every cell's request
    /// timelines here (`--trace-out`). Turns spans on for the run.
    pub trace_out: Option<PathBuf>,
}

impl Default for MixCfg {
    fn default() -> MixCfg {
        MixCfg {
            models: vec!["tiny_mobilenet".to_string(), "tiny_resnet".to_string()],
            clients_per_model: 4,
            requests: 32,
            warmup: 4,
            streams: 2,
            transports: TransportKind::ALL.to_vec(),
            policy: BatchCfg::deadline(8, 1000),
            per_model: Vec::new(),
            artifacts_dir: None,
            trace_out: None,
        }
    }
}

/// One cell: every model's client group runs concurrently against the
/// shared executor over private `kind` connections. Returns per-model
/// stats in `cfg.models` order.
fn run_mix_cell(
    kind: TransportKind,
    exec: &Arc<Executor>,
    cfg: &MixCfg,
) -> Result<Vec<LiveStats>> {
    let results: Vec<Result<LiveStats>> = std::thread::scope(|s| {
        let handles: Vec<_> = cfg
            .models
            .iter()
            .map(|model| {
                s.spawn(move || {
                    // Spans stay off (v1-identical wire conditions)
                    // unless the run exports timelines, which need them.
                    drive_model_clients(
                        kind,
                        exec,
                        model,
                        cfg.clients_per_model,
                        cfg.requests,
                        cfg.warmup,
                        cfg.trace_out.is_some(),
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("mix client group panicked")))
            })
            .collect()
    });
    let mut out = Vec::with_capacity(results.len());
    for (model, res) in cfg.models.iter().zip(results) {
        out.push(res.with_context(|| format!("client group for {model}"))?);
    }
    Ok(out)
}

/// Run the live mix sweep: one row per transport × model with
/// client-observed latency, per-model throughput, the per-model mean
/// achieved batch, and the cell's cross-model interleave count
/// (identical on every row of a transport group — it is a property of
/// the shared executor, not of one model).
pub fn run_mix_sweep(cfg: &MixCfg) -> Result<Table> {
    if cfg.models.len() < 2 {
        anyhow::bail!("mixsweep needs at least two models (got {:?})", cfg.models);
    }
    // Duplicate names would make the per-model rows and counter deltas
    // ambiguous (two client groups, one lane); weight a model's share
    // with `--model-batch model=SPEC*W` or `--clients` instead.
    let mut seen = cfg.models.clone();
    seen.sort();
    seen.dedup();
    if seen.len() != cfg.models.len() {
        anyhow::bail!("mixsweep models must be distinct (got {:?})", cfg.models);
    }
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    // Warm every batch variant of every swept model so compilation
    // never lands inside a measured request.
    let manifest = Manifest::load(&dir)?;
    let mut warm: Vec<String> = Vec::new();
    for model in &cfg.models {
        let sizes = manifest.batch_sizes(model);
        if sizes.is_empty() {
            anyhow::bail!(
                "model {model} has no artifacts under {} (servable: {:?})",
                dir.display(),
                manifest.models()
            );
        }
        warm.extend(sizes.into_iter().map(|b| format!("{model}_b{b}")));
    }
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();
    let sched = SchedCfg {
        per_model: cfg.per_model.clone(),
        ..SchedCfg::uniform(cfg.policy)
    };

    let mut t = Table::new(
        format!(
            "mix sweep — {{{}}} × {} clients each, {} requests, {} stream(s), default {}",
            cfg.models.join(", "),
            cfg.clients_per_model,
            cfg.requests,
            cfg.streams,
            cfg.policy.label()
        ),
        &[
            "p50_ms",
            "p99_ms",
            "mean_ms",
            "thr_rps",
            "avg_batch",
            "interleaves",
        ],
    );
    let mut tc = ChromeTrace::new();
    for &kind in &cfg.transports {
        // A fresh executor per transport cell, so the per-model
        // counters and the interleave count are the cell's own.
        let exec = Arc::new(
            Executor::start_with(&dir, cfg.streams, sched.clone(), &warm_refs)
                .with_context(|| format!("mix executor over {}", dir.display()))?,
        );
        let cell = run_mix_cell(kind, &exec, cfg)
            .with_context(|| format!("mix cell {}", kind.name()));
        let stats = match cell {
            Ok(s) => s,
            Err(e) => {
                // Drain the executor before propagating — bailing with
                // live worker threads would park them forever. Server
                // threads may hold clones for a moment after a failed
                // cell, so this retries rather than racing try_unwrap.
                if !drain_executor(exec) {
                    log::warn!("mix cell failed and executor clones leaked");
                }
                return Err(e);
            }
        };
        let interleaves = exec.interleave_count() as f64;
        let counters = exec.model_batch_counters();
        if cfg.trace_out.is_some() {
            // One track per model group's transport ring; the groups
            // run concurrently, so each (model, client) pair gets its
            // own non-overlapping track.
            for (model, st) in cfg.models.iter().zip(&stats) {
                for rec in &st.timeline {
                    let track = tc.track(&format!(
                        "ring/{}/{}/c{}",
                        kind.name(),
                        model,
                        rec.client
                    ));
                    let args = [("client", ArgVal::U64(rec.client as u64))];
                    tc.block(track, rec.t0_ns, &rec.span, rec.total_ns, &args);
                }
            }
        }
        for (model, st) in cfg.models.iter().zip(&stats) {
            let (jobs, calls) = counters
                .iter()
                .find(|(m, _, _)| m == model)
                .map(|&(_, j, c)| (j, c))
                .unwrap_or((0, 0));
            let avg_batch = jobs as f64 / calls.max(1) as f64;
            let lat = st.all.total.summary();
            t.row(
                format!("{} {}", kind.name(), model),
                vec![
                    lat.p50,
                    lat.p99,
                    lat.mean,
                    st.throughput_rps,
                    avg_batch,
                    interleaves,
                ],
            );
        }
        if !drain_executor(exec) {
            anyhow::bail!("mix sweep still holds executor clones");
        }
    }
    if let Some(path) = &cfg.trace_out {
        tc.save(path)?;
        t.note(format!(
            "wrote {} timeline events to {} (load in ui.perfetto.dev)",
            tc.len(),
            path.display()
        ));
    }
    t.note("each transport cell serves every model's client group concurrently from one executor");
    t.note("avg_batch = per-model jobs / executable calls; interleaves = dispatches that switched model (per transport cell, repeated on its rows)");
    t.note("a serialized scheduler would score ~1 interleave per cell; per-model lanes + weighted round-robin score many");
    Ok(t)
}

/// The simulated twin (`accelserve mixsweep --sim`): the same mixed
/// workload at paper scale on the discrete-event plane, with the sim
/// lane model gathering batches per model lane. One row per transport
/// × paper model; clients are assigned models round-robin
/// ([`Scenario::with_model_mix`]), `avg_batch` is the lane's achieved
/// batch (jobs per executable call) and `interleaves` counts
/// executable completions that switched model.
#[allow(clippy::too_many_arguments)]
pub fn run_sim_mix(
    models: &[&'static PaperModel],
    transports: &[Transport],
    clients_per_model: usize,
    requests: usize,
    streams: usize,
    policy: BatchCfg,
    per_model: &[(String, ModelPolicy)],
    trace_out: Option<&Path>,
) -> Result<Table> {
    let mut t = Table::new(
        format!(
            "sim mix — {{{}}} × {} clients each, {} requests, {} stream(s), default {}",
            models.iter().map(|m| m.name).collect::<Vec<_>>().join(", "),
            clients_per_model,
            requests,
            streams,
            policy.label()
        ),
        &[
            "p50_ms",
            "p99_ms",
            "mean_ms",
            "thr_rps",
            "avg_batch",
            "interleaves",
        ],
    );
    let mut tc = ChromeTrace::new();
    for &tr in transports {
        let mut sc = Scenario::direct(models[0], tr)
            .with_model_mix(models.to_vec())
            .with_clients(clients_per_model * models.len())
            .with_requests(requests)
            .with_streams(streams)
            .with_batching(policy.max_batch, policy.flush_us)
            .with_lanes();
        sc.model_batch = per_model.to_vec();
        if trace_out.is_some() {
            sc = sc.with_trace();
        }
        let stats = World::run(sc);
        if trace_out.is_some() {
            export_sim_cell(&mut tc, &stats, tr, policy);
        }
        for (i, (name, agg)) in stats.per_model.iter().enumerate() {
            let lat = agg.total.summary();
            let thr = agg.n() as f64 / stats.duration_s.max(1e-9);
            let l = &stats.lane_stats[i];
            let avg_batch = l.jobs as f64 / l.calls.max(1) as f64;
            t.row(
                format!("{} {}", tr.name(), name),
                vec![
                    lat.p50,
                    lat.p99,
                    lat.mean,
                    thr,
                    avg_batch,
                    stats.interleaves as f64,
                ],
            );
        }
    }
    if let Some(path) = trace_out {
        tc.save(path)?;
        t.note(format!(
            "wrote {} timeline events to {} (load in ui.perfetto.dev)",
            tc.len(),
            path.display()
        ));
    }
    t.note("clients round-robin over the model mix; the lane model gathers batches per model under the default policy");
    t.note("avg_batch = lane jobs / executable calls; interleaves = executable completions that switched model (per transport cell)");
    t.note("per-model thr_rps counts measured requests only (warmup excluded), so it underestimates the served rate slightly");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_sweep_interleaves_and_batches_per_model() {
        // Smoke + the acceptance property: ≥2 models × ≥2 transports,
        // per-model avg batch ≥ 1 everywhere (and > 1 somewhere: the
        // deadline policy gathers concurrent clients), nonzero
        // cross-model interleaves in every cell. Bit-identity of the
        // batched outputs is pinned by tests/batching.rs.
        let cfg = MixCfg {
            clients_per_model: 3,
            requests: 8,
            warmup: 2,
            transports: vec![TransportKind::Tcp, TransportKind::Shm],
            policy: BatchCfg::deadline(4, 2000),
            ..MixCfg::default()
        };
        let t = run_mix_sweep(&cfg).unwrap();
        assert_eq!(t.rows.len(), 4, "2 transports x 2 models");
        let mut any_batched = false;
        for kind in ["tcp", "shm"] {
            for model in ["tiny_mobilenet", "tiny_resnet"] {
                let row = format!("{kind} {model}");
                for col in ["p50_ms", "p99_ms", "mean_ms", "thr_rps"] {
                    let v = t.get(&row, col).unwrap();
                    assert!(v > 0.0, "{row}/{col} = {v}");
                }
                let avg = t.get(&row, "avg_batch").unwrap();
                assert!((1.0..=4.0).contains(&avg), "{row}/avg_batch = {avg}");
                any_batched |= avg > 1.0;
                let il = t.get(&row, "interleaves").unwrap();
                assert!(il > 0.0, "{row}: models never interleaved");
            }
        }
        assert!(any_batched, "no cell achieved any batching");
    }

    #[test]
    fn mix_sweep_rejects_degenerate_model_lists() {
        let single = MixCfg {
            models: vec!["tiny_mobilenet".to_string()],
            ..MixCfg::default()
        };
        assert!(run_mix_sweep(&single).is_err());
        let dup = MixCfg {
            models: vec!["tiny_mobilenet".to_string(), "tiny_mobilenet".to_string()],
            ..MixCfg::default()
        };
        assert!(run_mix_sweep(&dup).is_err(), "duplicate models are ambiguous");
    }

    #[test]
    fn sim_mix_reports_per_model_rows() {
        let models = [
            PaperModel::by_name("MobileNetV3").unwrap(),
            PaperModel::by_name("ResNet50").unwrap(),
        ];
        let t = run_sim_mix(
            &models,
            &[Transport::Tcp, Transport::Gdr],
            4,
            60,
            2,
            BatchCfg::deadline(4, 2000),
            &[],
            None,
        )
        .unwrap();
        assert_eq!(t.rows.len(), 4);
        let mut any_batched = false;
        for tr in ["tcp", "gdr"] {
            for m in ["MobileNetV3", "ResNet50"] {
                let row = format!("{tr} {m}");
                assert!(t.get(&row, "mean_ms").unwrap() > 0.0, "{row}");
                let avg = t.get(&row, "avg_batch").unwrap();
                assert!((1.0..=4.0).contains(&avg), "{row}/avg_batch = {avg}");
                any_batched |= avg > 1.0;
            }
            let il = t.get(&format!("{tr} MobileNetV3"), "interleaves").unwrap();
            assert!(il > 0.0, "{tr}: sim mix never interleaved");
        }
        assert!(any_batched, "no sim cell achieved any batching");
    }
}
