//! Experiment harnesses: one runner per paper figure/table, shared by
//! the benches and the CLI.

pub mod figs;
pub mod table;

pub use table::Table;
