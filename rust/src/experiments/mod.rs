//! Experiment harnesses: one runner per paper figure/table (sim plane)
//! plus the live-plane transport matrix (`accelserve matrix`), the
//! transport × batch-policy sweep (`accelserve batchsweep`), the
//! transport × model-mix sweep (`accelserve mixsweep`), and the
//! span-timeline stage breakdown (`accelserve stagebreak`), shared by
//! the benches and the CLI.

pub mod batch_sweep;
pub mod figs;
pub mod mix_sweep;
pub mod shard_sweep;
pub mod slo_sweep;
pub mod stage_break;
pub mod table;
pub mod throttle_sweep;
pub mod transport_matrix;

pub use batch_sweep::{run_batch_sweep, SweepCfg};
pub use mix_sweep::{run_mix_sweep, run_sim_mix, MixCfg};
pub use shard_sweep::{run_shard_sweep, ShardCfg};
pub use slo_sweep::{run_slo_sweep, SloCfg};
pub use stage_break::{run_sim_stage_break, run_stage_break, StageBreakCfg};
pub use table::Table;
pub use throttle_sweep::{run_throttle_sweep, ThrottleCfg};
pub use transport_matrix::{run_matrix, MatrixCfg};

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::coordinator::{handle_conn, run_on, Executor, LiveStats, LoadCfg};
use crate::models::gen;
use crate::transport::{connected_pair, MsgTransport, TransportKind};

/// Reclaim and shut down a shared executor. After a failed cell,
/// per-connection server threads can still hold `Arc<Executor>` clones
/// for a brief window (the clients have hung up; each handler exits on
/// peer close) — retry the unwrap briefly instead of leaking parked
/// stream workers. Returns `false` if the executor never became
/// reclaimable (a genuinely stuck clone holder).
pub(crate) fn drain_executor(mut exec: Arc<Executor>) -> bool {
    for _ in 0..200 {
        match Arc::try_unwrap(exec) {
            Ok(e) => {
                e.shutdown();
                return true;
            }
            Err(still) => {
                exec = still;
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        }
    }
    false
}

/// Drive `clients` closed-loop clients for one model over `kind`
/// against a shared executor: each client gets a private
/// pre-connected endpoint and a per-connection server thread running
/// [`handle_conn`]. Shared by `batchsweep` (one model per cell),
/// `mixsweep` (one concurrent call per model in the mix), and
/// `stagebreak` (`spans` on: requests carry `FLAG_SPANS` and the
/// returned [`LiveStats::spans`] aggregate fills in; the latency
/// sweeps leave it off so their wire conditions stay v1-identical).
pub(crate) fn drive_model_clients(
    kind: TransportKind,
    exec: &Arc<Executor>,
    model: &str,
    clients: usize,
    requests: usize,
    warmup: usize,
    spans: bool,
) -> Result<LiveStats> {
    drive_model_clients_slo(kind, exec, model, clients, requests, warmup, spans, None, false)
}

/// [`drive_model_clients`] plus a per-request SLO budget: every request
/// carries `FLAG_DEADLINE` with `deadline_us`, and the returned
/// [`LiveStats::sheds`] counts admission-control rejections (which are
/// not client errors — the closed loops keep offering load). Used by
/// `slosweep` to push the executor into overload and by `throttlesweep`
/// to additionally opt the clients into credit pacing (`credits`:
/// requests carry `FLAG_CREDITS` and each client paces on the server's
/// hints).
#[allow(clippy::too_many_arguments)]
pub(crate) fn drive_model_clients_slo(
    kind: TransportKind,
    exec: &Arc<Executor>,
    model: &str,
    clients: usize,
    requests: usize,
    warmup: usize,
    spans: bool,
    deadline_us: Option<u64>,
    credits: bool,
) -> Result<LiveStats> {
    let payload_elems = gen::IN_H * gen::IN_W * gen::CHANNELS;
    // Request frame = 4-byte header + model name + f32 payload; sized
    // so RDMA/GDR requests stay single-chunk.
    let payload_hint = 4 + model.len() + payload_elems * 4 + 64;
    // Create every endpoint pair before spawning anything, so the
    // fallible step cannot leave half-started server threads behind.
    let mut pairs = Vec::with_capacity(clients);
    for _ in 0..clients {
        pairs.push(connected_pair(kind, payload_hint)?);
    }
    let mut slots: Vec<Option<Box<dyn MsgTransport>>> = Vec::with_capacity(clients);
    let mut servers = Vec::with_capacity(clients);
    for (c, s) in pairs {
        slots.push(Some(c));
        let e2 = exec.clone();
        servers.push(std::thread::spawn(move || handle_conn(s, &e2)));
    }
    let slots = Mutex::new(slots);
    let lc = LoadCfg {
        model: model.to_string(),
        raw: false,
        spans,
        n_clients: clients,
        requests_per_client: requests + warmup,
        priority_client: false,
        payload_elems,
        warmup,
        deadline_us,
        credits,
        timeout: None,
        pipeline: vec![],
    };
    let stats = run_on(
        |i| {
            slots
                .lock()
                .unwrap()
                .get_mut(i)
                .and_then(Option::take)
                .ok_or_else(|| anyhow!("no pre-connected endpoint for client {i}"))
        },
        &lc,
    )?;
    // Clients hung up; their server threads see the close and exit.
    for th in servers {
        th.join()
            .map_err(|_| anyhow!("experiment server thread panicked"))?;
    }
    if stats.errors > 0 || stats.req_errors > 0 {
        // A cell with failed clients or per-request server errors has
        // holes in its series; 0.0 quantiles would masquerade as
        // measurements.
        anyhow::bail!(
            "{} client(s) failed, {} request error(s)",
            stats.errors,
            stats.req_errors
        );
    }
    Ok(stats)
}
