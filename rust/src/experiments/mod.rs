//! Experiment harnesses: one runner per paper figure/table (sim plane)
//! plus the live-plane transport matrix, shared by the benches and the
//! CLI.

pub mod figs;
pub mod table;
pub mod transport_matrix;

pub use table::Table;
pub use transport_matrix::{run_matrix, MatrixCfg};
