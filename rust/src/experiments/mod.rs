//! Experiment harnesses: one runner per paper figure/table (sim plane)
//! plus the live-plane transport matrix (`accelserve matrix`) and the
//! transport × batch-policy sweep (`accelserve batchsweep`), shared by
//! the benches and the CLI.

pub mod batch_sweep;
pub mod figs;
pub mod table;
pub mod transport_matrix;

pub use batch_sweep::{run_batch_sweep, SweepCfg};
pub use table::Table;
pub use transport_matrix::{run_matrix, MatrixCfg};
