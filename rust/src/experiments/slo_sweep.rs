//! Live-plane overload sweep: goodput and tail latency per **transport
//! × offered-load factor** under per-request SLO deadlines
//! (`accelserve slosweep`) — the repo's overload/admission-control
//! experiment.
//!
//! The paper's profiling question ("where does the latency go once the
//! transport is fast?") has a degenerate answer under overload: into
//! unbounded queues, where no transport can retrieve it. This
//! experiment drives the executor 1–10× past its service capacity with
//! closed-loop clients whose requests carry a relative SLO deadline
//! (`FLAG_DEADLINE`), and measures what the deadline-aware scheduler +
//! admission control buy: requests whose deadline is already unwinnable
//! are shed at the submit edge with the distinct `Shed` wire status (a
//! cheap one-RTT failure), so the requests that *are* admitted keep a
//! bounded tail while goodput stays pinned near service capacity.
//!
//! Reading the table: `shed_pct` should rise with the load factor while
//! `p99_ms` (admitted requests only) stays flat instead of growing with
//! the queue; `good_rps` saturating means capacity is spent on winners.
//! Every cell cross-checks the client-side shed tally against the
//! executor's per-lane shed counters fetched over the wire (the stats
//! opcode), so the three views of shedding — wire status, lane
//! counters, client math — are pinned equal.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{
    fetch_stats, handle_conn, BatchCfg, Executor, SchedCfg, DEFAULT_QUEUE_CAP,
};
use crate::models::gen;
use crate::models::manifest::Manifest;
use crate::runtime::TensorBuf;
use crate::transport::{connected_pair, TransportKind};

use super::{drain_executor, drive_model_clients_slo, Table};

/// SLO-sweep configuration.
#[derive(Debug, Clone)]
pub struct SloCfg {
    /// Served model (must have artifacts in the manifest).
    pub model: String,
    /// Offered-load multiples of service capacity; each factor is one
    /// row per transport, driven by `ceil(factor × streams)` closed-loop
    /// clients.
    pub factors: Vec<f64>,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams (1 by default so overload is easy to reach).
    pub streams: usize,
    /// Per-request SLO budget in µs. `None` auto-calibrates to
    /// 2× the measured solo service time (floored at 200µs) — a tight
    /// SLO that overload must violate.
    pub deadline_us: Option<u64>,
    /// Per-lane queue bound ([`SchedCfg::queue_cap`]).
    pub queue_cap: usize,
    pub transports: Vec<TransportKind>,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for SloCfg {
    fn default() -> SloCfg {
        SloCfg {
            model: "tiny_mobilenet".to_string(),
            factors: vec![1.0, 2.0, 4.0, 8.0],
            requests: 30,
            warmup: 3,
            streams: 1,
            deadline_us: None,
            queue_cap: DEFAULT_QUEUE_CAP,
            transports: vec![TransportKind::Tcp],
            artifacts_dir: None,
        }
    }
}

/// Measure the solo (unqueued) per-request service time on a fresh
/// executor, in µs — the unit the load factors and the auto deadline
/// are expressed in. The calibration requests also prime the lane's
/// service-time counters, so admission control has an estimate from the
/// first loaded request onward. Shared with `throttlesweep`, which uses
/// the same load geometry.
pub(crate) fn calibrate_svc_us(exec: &Executor, model: &str, payload_elems: usize) -> Result<u64> {
    let reps = 5usize;
    let mut total_us = 0u64;
    for _ in 0..reps {
        let t0 = Instant::now();
        exec.infer_sync(model, false, 0, TensorBuf::F32(vec![0.5; payload_elems]))
            .with_context(|| format!("calibration request for {model}"))?;
        total_us += t0.elapsed().as_micros() as u64;
    }
    Ok((total_us / reps as u64).max(1))
}

/// Run the sweep: one fresh executor per cell (clean counters), a
/// calibration pass, then `ceil(factor × streams)` closed-loop clients
/// sending deadline-carrying requests. Renders one row per transport ×
/// factor with admitted-request latency, goodput, and the shed split.
pub fn run_slo_sweep(cfg: &SloCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let warm: Vec<String> = manifest
        .batch_sizes(&cfg.model)
        .into_iter()
        .map(|b| format!("{}_b{b}", cfg.model))
        .collect();
    if warm.is_empty() {
        anyhow::bail!(
            "model {} has no artifacts under {} — nothing to sweep",
            cfg.model,
            dir.display()
        );
    }
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();
    let payload_elems = gen::IN_H * gen::IN_W * gen::CHANNELS;

    let mut t = Table::new(
        format!(
            "slo sweep — {} under overload, {} stream(s), {} requests/client",
            cfg.model, cfg.streams, cfg.requests
        ),
        &[
            "clients", "slo_ms", "p50_ms", "p99_ms", "good_rps", "shed_pct", "shed_cap",
            "shed_ddl",
        ],
    );
    for &kind in &cfg.transports {
        for &factor in &cfg.factors {
            // Fresh executor per cell: clean lane counters, so the
            // wire-stats cross-check below is exact.
            let sched = SchedCfg {
                // Batching off: each job runs solo, so "offered load ×"
                // means exactly that many service times per second and
                // the admission estimate prices jobs, not batches.
                default: BatchCfg::none(),
                per_model: Vec::new(),
                queue_cap: cfg.queue_cap,
            };
            let exec = Arc::new(
                Executor::start_with(&dir, cfg.streams, sched, &warm_refs)
                    .with_context(|| format!("slosweep executor over {}", dir.display()))?,
            );
            let cell = run_cell(kind, &exec, cfg, factor, payload_elems, &mut t);
            if !drain_executor(exec) && cell.is_ok() {
                anyhow::bail!("slosweep still holds executor clones");
            }
            cell?;
        }
    }
    t.note("offered load = clients / streams in units of the calibrated solo service time; slo_ms = the per-request deadline");
    t.note("p50/p99 cover admitted (served) requests only — shed requests fail in one RTT and record no latency");
    t.note("good_rps counts served requests; shed_pct = sheds / (sheds + served); shed_cap = queue-cap sheds, shed_ddl = unwinnable-deadline sheds");
    t.note("every cell cross-checks client-side shed tallies against the executor's per-lane shed counters fetched via the stats opcode");
    Ok(t)
}

/// One cell: calibrate, overload, verify the three shed views agree,
/// append the row.
fn run_cell(
    kind: TransportKind,
    exec: &Arc<Executor>,
    cfg: &SloCfg,
    factor: f64,
    payload_elems: usize,
    t: &mut Table,
) -> Result<()> {
    let svc_us = calibrate_svc_us(exec, &cfg.model, payload_elems)?;
    let deadline_us = cfg.deadline_us.unwrap_or_else(|| (2 * svc_us).max(200));
    let clients = ((factor * cfg.streams as f64).ceil() as usize).max(1);
    let stats = drive_model_clients_slo(
        kind,
        exec,
        &cfg.model,
        clients,
        cfg.requests,
        cfg.warmup,
        false,
        Some(deadline_us),
        false,
    )
    .with_context(|| format!("cell {} {factor}x", kind.name()))?;

    // Cross-check: the executor's per-lane shed counters, fetched over
    // the wire exactly as an operator would (stats opcode), must agree
    // with both the in-process snapshot and the client-side tally.
    // Settle first: the last reply lands a hair before the worker banks
    // the chunk's service time.
    let local = {
        let mut prev = exec.stats();
        loop {
            std::thread::sleep(std::time::Duration::from_millis(10));
            let next = exec.stats();
            if next == prev {
                break next;
            }
            prev = next;
        }
    };
    let wire = {
        let (mut client, server) = connected_pair(kind, 4096)?;
        let e2 = exec.clone();
        let th = std::thread::spawn(move || handle_conn(server, &e2));
        let wire = fetch_stats(client.as_mut());
        drop(client);
        th.join()
            .map_err(|_| anyhow::anyhow!("stats server thread panicked"))?;
        wire?
    };
    if wire != local {
        anyhow::bail!(
            "stats opcode disagrees with the in-process snapshot:\nwire  {wire:?}\nlocal {local:?}"
        );
    }
    let lane_sheds: u64 = wire
        .lanes
        .iter()
        .map(|l| l.shed.iter().sum::<u64>())
        .sum();
    if lane_sheds != stats.sheds as u64 {
        anyhow::bail!(
            "shed accounting mismatch: lanes counted {lane_sheds}, clients saw {}",
            stats.sheds
        );
    }
    let (shed_cap, shed_ddl) = wire.lanes.iter().fold((0u64, 0u64), |(c, d), l| {
        (c + l.shed[0], d + l.shed[1])
    });

    let lat = stats.all.total.summary();
    let offered = stats.sheds + stats.served;
    let shed_pct = 100.0 * stats.sheds as f64 / (offered.max(1)) as f64;
    t.row(
        format!("{} {factor}x", kind.name()),
        vec![
            clients as f64,
            deadline_us as f64 / 1_000.0,
            lat.p50,
            lat.p99,
            stats.throughput_rps,
            shed_pct,
            shed_cap as f64,
            shed_ddl as f64,
        ],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slosweep_sheds_under_overload_with_bounded_tail() {
        // Smoke: a 1× cell and a 4× cell over TCP. At 4× the offered
        // load is four service times per service slot under a 2×-svc
        // SLO, so admission control must shed some of it, while the
        // requests it admits keep a tail bounded near the SLO instead
        // of the full queueing delay. The wire-vs-executor-vs-client
        // shed accounting equality is asserted inside run_cell for
        // every cell — a mismatch fails the sweep itself.
        let cfg = SloCfg {
            factors: vec![1.0, 4.0],
            requests: 25,
            warmup: 3,
            transports: vec![TransportKind::Tcp],
            ..SloCfg::default()
        };
        let t = run_slo_sweep(&cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
        for row in ["tcp 1x", "tcp 4x"] {
            assert!(t.get(row, "p50_ms").unwrap() > 0.0, "{row} p50");
            assert!(t.get(row, "good_rps").unwrap() > 0.0, "{row} goodput");
        }
        let slo_ms = t.get("tcp 4x", "slo_ms").unwrap();
        let shed_pct = t.get("tcp 4x", "shed_pct").unwrap();
        assert!(
            shed_pct > 0.0,
            "4x offered load under a 2x-svc SLO must shed something"
        );
        // Bounded tail for admitted requests: not the naive queueing
        // delay (~clients × svc per request, i.e. ≥ 2× the SLO at this
        // factor). Generous slack for CI-runner jitter: the bound only
        // needs to exclude unbounded-queue behaviour, which grows with
        // the whole run length.
        let p99 = t.get("tcp 4x", "p99_ms").unwrap();
        assert!(
            p99 <= slo_ms * 6.0 + 60.0,
            "admitted p99 {p99}ms not bounded near the {slo_ms}ms SLO"
        );
    }
}
