//! One runner per figure/table of the paper's evaluation (§IV–§VI).
//! Each returns a [`Table`] whose rows are the series the paper plots;
//! the benches print them and EXPERIMENTS.md records paper-vs-measured.

use crate::gpu::Sharing;
use crate::models::zoo::{PaperModel, ZOO};
use crate::net::params::Transport;
use crate::sim::world::{RunStats, Scenario, World};

use super::table::Table;

/// Transports compared in the single-client / scalability figures.
pub const TRANSPORTS: [Transport; 4] = [
    Transport::Local,
    Transport::Gdr,
    Transport::Rdma,
    Transport::Tcp,
];

/// The five proxied-connection configurations of Fig 10 / Fig 14
/// (client-to-gateway / gateway-to-server).
pub const PROXY_PAIRS: [(Transport, Transport); 5] = [
    (Transport::Rdma, Transport::Gdr),
    (Transport::Rdma, Transport::Rdma),
    (Transport::Tcp, Transport::Gdr),
    (Transport::Tcp, Transport::Rdma),
    (Transport::Tcp, Transport::Tcp),
];

/// Client counts swept in the scalability figures.
pub const CLIENT_SWEEP: [usize; 6] = [1, 2, 4, 8, 12, 16];

fn m(name: &str) -> &'static PaperModel {
    PaperModel::by_name(name).expect("model in zoo")
}

fn run(sc: Scenario) -> RunStats {
    World::run(sc)
}

// ------------------------------------------------------------------ Fig 5

/// Fig 5: single-client direct-connection total time for ResNet50,
/// across transports, with (a) raw and (b) preprocessed images.
pub fn fig5(reqs: usize) -> Table {
    let mut t = Table::new(
        "Fig 5: ResNet50 total time across mechanisms (direct, 1 client) [ms]",
        &["raw", "preprocessed"],
    );
    for tr in TRANSPORTS {
        let mut vals = Vec::new();
        for raw in [true, false] {
            let s = run(Scenario::direct(m("ResNet50"), tr)
                .with_requests(reqs)
                .with_raw(raw));
            vals.push(s.all.total.mean());
        }
        t.row(tr.name(), vals);
    }
    t.note("paper: GDR/RDMA 20.3%/11.4% less than TCP (raw), 23.2%/15.2% (preprocessed)");
    t.note("paper: GDR adds 0.27-0.53 ms over local; TCP adds 1.2-1.5 ms");
    t
}

// ------------------------------------------------------------------ Fig 6

/// Fig 6: per-stage latency breakdown for ResNet50 across mechanisms.
pub fn fig6(reqs: usize) -> Table {
    let mut t = Table::new(
        "Fig 6: ResNet50 latency breakdown (direct, 1 client) [ms]",
        &["request", "copy_h2d", "preproc", "infer", "copy_d2h", "response", "total"],
    );
    for raw in [true, false] {
        for tr in TRANSPORTS {
            let s = run(Scenario::direct(m("ResNet50"), tr)
                .with_requests(reqs)
                .with_raw(raw));
            let a = &s.all;
            t.row(
                format!("{}/{}", tr.name(), if raw { "raw" } else { "pre" }),
                vec![
                    a.request.mean(),
                    a.copy_h2d.mean(),
                    a.preproc.mean(),
                    a.infer.mean(),
                    a.copy_d2h.mean(),
                    a.response.mean(),
                    a.total.mean(),
                ],
            );
        }
    }
    t.note("paper: TCP sends raw/preproc 0.73/0.61 ms slower than GDR&RDMA;");
    t.note("paper: GDR saves extra 0.3/0.2 ms of copies vs RDMA");
    t
}

// -------------------------------------------------------------- Fig 7/8/9

/// Fig 7: offloading latency overhead vs local processing, per model.
/// Values are percentages: (offloaded - local) / local * 100.
pub fn fig7(reqs: usize, raw: bool) -> Table {
    let which = if raw { "(a) raw" } else { "(b) preprocessed" };
    let mut t = Table::new(
        format!("Fig 7{which}: latency overhead vs local [%]"),
        &["GDR", "RDMA", "TCP"],
    );
    for model in ZOO {
        let local = run(Scenario::direct(model, Transport::Local)
            .with_requests(reqs)
            .with_raw(raw))
        .all
        .total
        .mean();
        let mut vals = Vec::new();
        for tr in [Transport::Gdr, Transport::Rdma, Transport::Tcp] {
            let s = run(Scenario::direct(model, tr).with_requests(reqs).with_raw(raw));
            vals.push((s.all.total.mean() - local) / local * 100.0);
        }
        t.row(model.name, vals);
    }
    t.note("paper: MobileNetV3 >= 80.8% (raw) / 48.1% (pre) overhead;");
    t.note("paper: WideResNet101 ~4.5% / ~2%; large-I/O models highest with TCP");
    t
}

/// Fig 8: fraction of time per pipeline stage, per model x transport.
pub fn fig8(reqs: usize, raw: bool) -> Table {
    let mut t = Table::new(
        format!(
            "Fig 8: stage fractions ({} images) [% of total]",
            if raw { "raw" } else { "preprocessed" }
        ),
        &["net%", "copy%", "proc%"],
    );
    for model in ZOO {
        for tr in [Transport::Gdr, Transport::Rdma, Transport::Tcp] {
            let s = run(Scenario::direct(model, tr).with_requests(reqs).with_raw(raw));
            let (net, copy, proc) = s.all.fractions();
            t.row(
                format!("{}/{}", model.name, tr.name()),
                vec![net * 100.0, copy * 100.0, proc * 100.0],
            );
        }
    }
    t.note("paper: MobileNetV3 data movement 62/42/30% for TCP/RDMA/GDR;");
    t.note("paper: DeepLabV3 raw: TCP 60%, RDMA 32%, GDR 23% in data movement");
    t
}

/// Fig 9: CPU usage per request across models and transports [ms CPU].
pub fn fig9(reqs: usize) -> Table {
    let mut t = Table::new(
        "Fig 9: CPU usage per request (raw images) [CPU-ms]",
        &["GDR", "RDMA", "TCP"],
    );
    for model in ZOO {
        let mut vals = Vec::new();
        for tr in [Transport::Gdr, Transport::Rdma, Transport::Tcp] {
            let s = run(Scenario::direct(model, tr).with_requests(reqs));
            vals.push(s.all.cpu_us.mean() / 1_000.0);
        }
        t.row(model.name, vals);
    }
    t.note("paper: TCP highest CPU (stack per-byte work); DeepLabV3 TCP ~2x GDR;");
    t.note("paper: RDMA's copy issuing adds only a minor effect vs GDR");
    t
}

// ----------------------------------------------------------------- Fig 10

/// Fig 10: proxied connection, single client, MobileNetV3 raw.
pub fn fig10(reqs: usize) -> Table {
    let mut t = Table::new(
        "Fig 10: proxied connection, MobileNetV3 raw (1 client) [ms]",
        &["total", "std"],
    );
    for (ch, sh) in PROXY_PAIRS {
        let s = run(Scenario::proxied(m("MobileNetV3"), ch, sh).with_requests(reqs));
        t.row(
            format!("{}/{}", ch.name(), sh.name()),
            vec![s.all.total.mean(), s.all.total.std()],
        );
    }
    t.note("paper: TCP/RDMA saves 23% and TCP/GDR 57% vs TCP/TCP;");
    t.note("paper: TCP shows the highest variation; HW transport damps it");
    t
}

// ------------------------------------------------------------ Fig 11/12/13

/// Fig 11: total time vs client count (raw images), for one model.
pub fn fig11(model_name: &str, reqs: usize) -> Table {
    let cols: Vec<String> = CLIENT_SWEEP.iter().map(|c| format!("{c}cl")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        format!("Fig 11: {model_name} total time vs clients (raw) [ms]"),
        &cols_ref,
    );
    for tr in [Transport::Gdr, Transport::Rdma, Transport::Tcp] {
        let mut vals = Vec::new();
        for &n in &CLIENT_SWEEP {
            let s = run(Scenario::direct(m(model_name), tr)
                .with_requests(reqs)
                .with_clients(n));
            vals.push(s.all.total.mean());
        }
        t.row(tr.name(), vals);
    }
    t.note("paper @16 clients: GDR saves 4.7 ms (MobileNetV3) / 160 ms (DeepLabV3) vs TCP;");
    t.note("paper: RDMA's gain erodes to TCP levels as the copy engine saturates");
    t
}

/// Fig 12/13: per-stage fraction vs client count for one model+transport.
pub fn fig12_13(model_name: &str, tr: Transport, reqs: usize) -> Table {
    let cols: Vec<String> = CLIENT_SWEEP.iter().map(|c| format!("{c}cl")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        format!("Fig 12/13: {model_name}/{} stage fractions vs clients [%]", tr.name()),
        &cols_ref,
    );
    let mut net_row = Vec::new();
    let mut copy_row = Vec::new();
    let mut proc_row = Vec::new();
    for &n in &CLIENT_SWEEP {
        let s = run(Scenario::direct(m(model_name), tr)
            .with_requests(reqs)
            .with_clients(n));
        let (net, copy, proc) = s.all.fractions();
        net_row.push(net * 100.0);
        copy_row.push(copy * 100.0);
        proc_row.push(proc * 100.0);
    }
    t.row("net%", net_row);
    t.row("copy%", copy_row);
    t.row("proc%", proc_row);
    t.note("paper Fig12 (MobileNetV3): processing fraction rises 38->62% (TCP),");
    t.note("  58->72% (RDMA), 70->92% (GDR); network I/O never the bottleneck");
    t.note("paper Fig13 (DeepLabV3): copy 7->36% TCP (10-366 ms), 12->28% RDMA (9-264 ms)");
    t
}

// ----------------------------------------------------------------- Fig 14

/// Fig 14: proxied-connection scalability, MobileNetV3 raw.
pub fn fig14(reqs: usize) -> Table {
    let cols: Vec<String> = CLIENT_SWEEP.iter().map(|c| format!("{c}cl")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 14: proxied scalability, MobileNetV3 raw [ms]",
        &cols_ref,
    );
    for (ch, sh) in PROXY_PAIRS {
        let mut vals = Vec::new();
        for &n in &CLIENT_SWEEP {
            let s = run(Scenario::proxied(m("MobileNetV3"), ch, sh)
                .with_requests(reqs)
                .with_clients(n));
            vals.push(s.all.total.mean());
        }
        t.row(format!("{}/{}", ch.name(), sh.name()), vals);
    }
    t.note("paper: last-hop GDR saves 27% vs TCP/TCP, only +4% over RDMA/GDR;");
    t.note("paper: RDMA/RDMA ~ TCP/RDMA ~ TCP/TCP at scale (copy-engine bottleneck)");
    t
}

// ----------------------------------------------------------------- Fig 15

/// Fig 15(a): GDR scalability for ResNet50 with a limited stream pool.
pub fn fig15a(reqs: usize) -> Table {
    let cols: Vec<String> = CLIENT_SWEEP.iter().map(|c| format!("{c}cl")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 15(a): ResNet50/GDR total vs clients, limited streams [ms]",
        &cols_ref,
    );
    for streams in [1usize, 4, 16] {
        let mut vals = Vec::new();
        for &n in &CLIENT_SWEEP {
            let s = run(Scenario::direct(m("ResNet50"), Transport::Gdr)
                .with_requests(reqs)
                .with_clients(n)
                .with_streams(streams.min(n.max(1))));
            vals.push(s.all.total.mean());
        }
        t.row(format!("{streams} stream(s)"), vals);
    }
    t.note("paper: 1 shared stream is ~33% slower than stream-per-client at 16 clients");
    t
}

/// Fig 15(b): total latency at 16 clients vs stream-pool size.
pub fn fig15b(reqs: usize) -> Table {
    let streams = [1usize, 2, 4, 8, 16];
    let cols: Vec<String> = streams.iter().map(|s| format!("{s}str")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 15(b): ResNet50 total @16 clients vs streams [ms]",
        &cols_ref,
    );
    for tr in [Transport::Gdr, Transport::Rdma] {
        let mut vals = Vec::new();
        for &s in &streams {
            let st = run(Scenario::direct(m("ResNet50"), tr)
                .with_requests(reqs)
                .with_clients(16)
                .with_streams(s));
            vals.push(st.all.total.mean());
        }
        t.row(tr.name(), vals);
    }
    t.note("paper: latency falls with streams at a diminishing rate; GDR < RDMA");
    t
}

/// Fig 15(c): CoV of GPU processing time vs stream-pool size.
pub fn fig15c(reqs: usize) -> Table {
    let streams = [1usize, 2, 4, 8, 16];
    let cols: Vec<String> = streams.iter().map(|s| format!("{s}str")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 15(c): ResNet50 processing-time CoV @16 clients vs streams",
        &cols_ref,
    );
    for tr in [Transport::Gdr, Transport::Rdma] {
        let mut vals = Vec::new();
        for &s in &streams {
            let st = run(Scenario::direct(m("ResNet50"), tr)
                .with_requests(reqs)
                .with_clients(16)
                .with_streams(s));
            vals.push(st.all.processing.cov());
        }
        t.row(tr.name(), vals);
    }
    t.note("paper @16 streams: CoV 0.11 (GDR) vs 0.21 (RDMA) — copy/exec interference;");
    t.note("paper: limiting concurrency reduces variability for both");
    t
}

// ----------------------------------------------------------------- Fig 16

/// Fig 16: one high-priority client among normal clients, YoloV4
/// preprocessed. Rows: transport x {priority, normal}.
pub fn fig16(reqs: usize) -> Table {
    let clients = [2usize, 4, 8, 16];
    let cols: Vec<String> = clients.iter().map(|c| format!("{c}cl")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 16: YoloV4 preprocessed, priority vs normal clients [ms]",
        &cols_ref,
    );
    for tr in [Transport::Gdr, Transport::Rdma] {
        let mut prio = Vec::new();
        let mut norm = Vec::new();
        for &n in &clients {
            let s = run(Scenario::direct(m("YoloV4"), tr)
                .with_requests(reqs)
                .with_clients(n)
                .with_raw(false)
                .with_priority_client(true));
            prio.push(s.priority.total.mean());
            norm.push(s.normal.total.mean());
        }
        t.row(format!("{}/priority", tr.name()), prio);
        t.row(format!("{}/normal", tr.name()), norm);
    }
    t.note("paper: GDR priority client stays ~54 ms; under RDMA the priority");
    t.note("  client degrades to normal levels beyond 8 clients (coarse copy interleave)");
    t
}

// ----------------------------------------------------------------- Fig 17

/// Fig 17: GPU sharing methods for EfficientNetB0 (raw images).
pub fn fig17(reqs: usize) -> Table {
    let clients = [1usize, 4, 8, 16];
    let cols: Vec<String> = clients.iter().map(|c| format!("{c}cl")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 17: EfficientNetB0 sharing methods (raw) [ms]",
        &cols_ref,
    );
    for tr in [Transport::Gdr, Transport::Rdma] {
        for sharing in [Sharing::MultiStream, Sharing::MultiContext, Sharing::Mps] {
            let mut vals = Vec::new();
            for &n in &clients {
                let s = run(Scenario::direct(m("EfficientNetB0"), tr)
                    .with_requests(reqs)
                    .with_clients(n)
                    .with_sharing(sharing));
                vals.push(s.all.total.mean());
            }
            t.row(format!("{}/{}", tr.name(), sharing.name()), vals);
        }
    }
    t.note("paper: MPS always beats multi-context; GDR multi-stream ~ MPS;");
    t.note("paper: RDMA multi-stream worse than MPS (copy interleave differs across processes)");
    t
}

// ------------------------------------------------------------- Tables I-III

/// Table II: the DNN zoo.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II: DNN models (paper shapes, calibrated profiles)",
        &["GFLOPS", "req_raw_KB", "req_pre_KB", "resp_KB", "infer_ms"],
    );
    for model in ZOO {
        t.row(
            model.name,
            vec![
                model.gflops,
                model.raw_bytes() as f64 / 1024.0,
                model.preprocessed_bytes() as f64 / 1024.0,
                model.response_bytes() as f64 / 1024.0,
                model.infer_ms,
            ],
        );
    }
    t
}

/// Table III: the simulated testbed configuration.
pub fn table3() -> Table {
    let cfg = crate::gpu::GpuConfig::default();
    let mut t = Table::new(
        "Table III: simulated testbed (S1 gateway, S2 GPU server)",
        &["value"],
    );
    t.row("link_gbps", vec![crate::net::fabric::LINE_RATE_GBPS]);
    t.row("gpu_exec_engines", vec![cfg.n_engines as f64]);
    t.row("gpu_mem_gb", vec![(cfg.device_mem_bytes >> 30) as f64]);
    t.row("copy_engines", vec![2.0]);
    t.row("pcie_gbs_idle", vec![cfg.pcie_gbs]);
    t.note("paper: Dell R740/R750, Xeon-G, NVIDIA A2, ConnectX-5 25GbE");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 60; // small but stable sample for unit tests

    #[test]
    fn fig5_reproduces_ordering_and_overheads() {
        let t = fig5(N);
        for col in ["raw", "preprocessed"] {
            let local = t.get("Local", col).unwrap();
            let gdr = t.get("GDR", col).unwrap();
            let rdma = t.get("RDMA", col).unwrap();
            let tcp = t.get("TCP", col).unwrap();
            assert!(local < gdr && gdr < rdma && rdma < tcp, "{col}");
            // GDR adds 0.2-0.7 ms over local (paper: 0.27-0.53 ms).
            assert!((0.1..0.9).contains(&(gdr - local)), "{col}: {}", gdr - local);
            // TCP adds 0.8-2.2 ms over local (paper: 1.2-1.5 ms).
            assert!((0.7..2.5).contains(&(tcp - local)), "{col}: {}", tcp - local);
        }
    }

    #[test]
    fn fig7_small_models_higher_overhead() {
        let t = fig7(N, true);
        for col in ["GDR", "RDMA", "TCP"] {
            let mob = t.get("MobileNetV3", col).unwrap();
            let wide = t.get("WideResNet101", col).unwrap();
            assert!(mob > 5.0 * wide, "{col}: {mob} !>> {wide}");
        }
        // MobileNetV3/GDR raw overhead near the paper's 80.8 %.
        let g = t.get("MobileNetV3", "GDR").unwrap();
        assert!((40.0..160.0).contains(&g), "{g}");
    }

    #[test]
    fn fig16_priority_effective_only_under_gdr() {
        let t = fig16(40);
        let gdr_p = t.get("GDR/priority", "16cl").unwrap();
        let gdr_n = t.get("GDR/normal", "16cl").unwrap();
        let rdma_p = t.get("RDMA/priority", "16cl").unwrap();
        let rdma_n = t.get("RDMA/normal", "16cl").unwrap();
        assert!(gdr_p < 0.35 * gdr_n, "GDR prio {gdr_p} vs normal {gdr_n}");
        // Under RDMA the coarse copy-engine interleave erodes the
        // priority advantage (the paper's effect is stronger still:
        // priority ~ normal beyond 8 clients — see EXPERIMENTS.md):
        // the GDR priority client is insulated from client count while
        // the RDMA one degrades with it.
        let gdr_p2 = t.get("GDR/priority", "2cl").unwrap();
        let rdma_p2 = t.get("RDMA/priority", "2cl").unwrap();
        assert!(gdr_p < 1.2 * gdr_p2, "GDR prio grew {gdr_p2} -> {gdr_p}");
        assert!(rdma_p > 1.3 * rdma_p2, "RDMA prio flat {rdma_p2} -> {rdma_p}");
        assert!(rdma_p > 1.5 * gdr_p, "rdma prio {rdma_p} vs gdr prio {gdr_p}");
        let _ = (rdma_n, gdr_n);
    }

    #[test]
    fn tables_render() {
        assert!(table2().render().contains("DeepLabV3"));
        assert!(table3().render().contains("gpu_exec_engines"));
    }
}
