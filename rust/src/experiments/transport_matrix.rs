//! Live-plane transport matrix: the Fig 5/6-style per-stage latency
//! breakdown (recv / preprocess / infer / reply) measured over the
//! *real* transports — tcp, shm, rdma, gdr — on one identical
//! raw-frame workload (`accelserve matrix`).
//!
//! The compute stages are identical across transports so the experiment
//! isolates what the paper isolates: how the communication mechanism
//! moves the per-stage numbers while compute stays fixed. Since PR 2
//! the infer stage runs through the **real `Executor` + `Engine`**
//! (the `tiny_mobilenet_b1` artifact under the pure-Rust HLO
//! interpreter), not a CPU stand-in. The stage definitions:
//!
//! * **recv** — the server's blocking receive: transfer plus, for the
//!   host-copy transports, the bounce of the payload out of the
//!   transport buffer. GDR's receive hands back a registered-region
//!   view, so this stage drops the payload-sized copy.
//! * **preprocess** — folds the raw u8 frame into the model's
//!   (1,32,32,3) f32 input tensor. Work is proportional to the payload
//!   and identical for every transport (the GDR path reads the
//!   registered region in place).
//! * **infer** — `Executor::infer_sync` on `tiny_mobilenet`: queue +
//!   engine execution of the compiled HLO artifact.
//! * **reply** — serializing + sending the 1000-logit f32 result.
//!
//! `total` is the client-observed round-trip, i.e. the model-serving
//! latency of the paper's Table I.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::protocol::f32s_to_bytes;
use crate::coordinator::{BatchCfg, Executor};
use crate::metrics::stats::Series;
use crate::models::gen;
use crate::models::zoo::WorkloadData;
use crate::runtime::TensorBuf;
use crate::transport::{connected_pair, MsgTransport, RecvMsg, TransportKind};

use super::Table;

/// The model every matrix cell serves (fixed compute across rows).
const MATRIX_MODEL: &str = "tiny_mobilenet";
/// Flat model-input tensor size: (1, 32, 32, 3).
const MODEL_ELEMS: usize = gen::IN_H * gen::IN_W * gen::CHANNELS;

/// Matrix experiment configuration.
#[derive(Debug, Clone)]
pub struct MatrixCfg {
    /// Raw request payload (bytes). The acceptance workload is >= 1 MiB.
    pub payload_bytes: usize,
    /// Measured requests per transport.
    pub requests: usize,
    /// Discarded leading requests per transport.
    pub warmup: usize,
    pub transports: Vec<TransportKind>,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for MatrixCfg {
    fn default() -> MatrixCfg {
        MatrixCfg {
            payload_bytes: 1 << 20,
            requests: 160,
            warmup: 16,
            transports: TransportKind::ALL.to_vec(),
            artifacts_dir: None,
        }
    }
}

/// Server-side stage samples (ms).
#[derive(Default)]
struct StageStats {
    recv: Series,
    preproc: Series,
    infer: Series,
    reply: Series,
    server: Series,
}

/// u8 camera frame -> the model's flat (1,32,32,3) f32 input tensor;
/// reads region payloads in place (no host bounce). Every payload byte
/// is touched (payload-proportional work, like a real resize), folded
/// into the fixed-size tensor and mapped into [-0.5, 0.5].
fn preprocess(msg: &RecvMsg) -> Vec<f32> {
    fn fold(b: &[u8]) -> Vec<f32> {
        let mut acc = vec![0f32; MODEL_ELEMS];
        for (i, &x) in b.iter().enumerate() {
            acc[i % MODEL_ELEMS] += x as f32 / 255.0;
        }
        let passes = b.len().div_ceil(MODEL_ELEMS).max(1) as f32;
        for v in &mut acc {
            *v = *v / passes - 0.5;
        }
        acc
    }
    match msg {
        RecvMsg::Host(v) => fold(v),
        RecvMsg::Region(s) => s.with(fold),
    }
}

/// Serve `total` requests on one connection, recording per-stage
/// timings for the ones past `warmup`. Inference goes through the
/// shared executor (the real engine).
fn pipeline_server(
    mut t: Box<dyn MsgTransport>,
    exec: Arc<Executor>,
    total: usize,
    warmup: usize,
) -> StageStats {
    let mut stats = StageStats::default();
    for i in 0..total {
        let t0 = Instant::now();
        let msg = match t.recv_msg() {
            Ok(m) => m,
            Err(_) => break,
        };
        let t1 = Instant::now();
        let tensor = preprocess(&msg);
        drop(msg); // release the region slot before the next receive
        let t2 = Instant::now();
        let done = match exec.infer_sync(MATRIX_MODEL, false, 0, TensorBuf::F32(tensor)) {
            Ok(d) => d,
            Err(e) => {
                // Surface the engine failure: a silent break here would
                // otherwise masquerade as a client-side disconnect.
                eprintln!("matrix: infer stage failed, closing connection: {e:#}");
                break;
            }
        };
        let t3 = Instant::now();
        if t.send(&f32s_to_bytes(&done.output)).is_err() {
            break;
        }
        let t4 = Instant::now();
        if i >= warmup {
            let ms = |a: Instant, b: Instant| (b - a).as_secs_f64() * 1e3;
            stats.recv.push(ms(t0, t1));
            stats.preproc.push(ms(t1, t2));
            stats.infer.push(ms(t2, t3));
            stats.reply.push(ms(t3, t4));
            stats.server.push(ms(t0, t4));
        }
    }
    stats
}

/// One cell: closed-loop client against the pipeline server.
fn run_one(
    kind: TransportKind,
    cfg: &MatrixCfg,
    exec: &Arc<Executor>,
) -> Result<(StageStats, Series)> {
    let (mut client, server) = connected_pair(kind, cfg.payload_bytes)?;
    let total = cfg.requests + cfg.warmup;
    let warmup = cfg.warmup;
    let exec2 = exec.clone();
    let server_thread =
        std::thread::spawn(move || pipeline_server(server, exec2, total, warmup));
    let payload = WorkloadData::image(cfg.payload_bytes, 7).bytes;
    let mut totals = Series::new();
    for i in 0..total {
        let t0 = Instant::now();
        client.send(&payload).expect("send");
        let reply = client.recv().expect("recv");
        assert_eq!(
            reply.len(),
            4 * gen::NUM_CLASSES,
            "engine returns 1000 f32 logits"
        );
        if i >= cfg.warmup {
            totals.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    drop(client);
    let stats = server_thread
        .join()
        .map_err(|_| anyhow::anyhow!("matrix server thread panicked"))?;
    Ok((stats, totals))
}

/// Run the matrix and render the per-stage latency table (p50 per
/// stage; `total_ms` is the client round trip). Errors on an unusable
/// artifact directory (e.g. artifacts using opcodes outside the
/// interpreter's set) instead of panicking.
pub fn run_matrix(cfg: &MatrixCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    // Self-provision like `accelserve serve`: an explicit --artifacts
    // dir without a manifest gets the generated artifacts.
    gen::ensure_artifacts(&dir)?;
    let warm_b1 = format!("{MATRIX_MODEL}_b1");
    let exec = Arc::new(
        Executor::start(
            &dir,
            1,
            BatchCfg::none(),
            &[warm_b1.as_str(), "preprocess"],
        )
        .with_context(|| format!("matrix executor over {}", dir.display()))?,
    );
    let mut t = Table::new(
        format!(
            "transport matrix — {} KiB raw frames, {} requests, infer = {MATRIX_MODEL} on the real engine",
            cfg.payload_bytes >> 10,
            cfg.requests
        ),
        &[
            "recv_ms",
            "preproc_ms",
            "infer_ms",
            "reply_ms",
            "server_ms",
            "total_ms",
        ],
    );
    let mut failed: Option<anyhow::Error> = None;
    for &kind in &cfg.transports {
        let (st, totals) = match run_one(kind, cfg, &exec) {
            Ok(cell) => cell,
            Err(e) => {
                // Stop measuring but fall through to the executor
                // shutdown below — bailing here would leak its threads.
                failed = Some(e);
                break;
            }
        };
        t.row(
            kind.name(),
            vec![
                st.recv.summary().p50,
                st.preproc.summary().p50,
                st.infer.summary().p50,
                st.reply.summary().p50,
                st.server.summary().p50,
                totals.summary().p50,
            ],
        );
    }
    t.note("recv includes transfer + host bounce copy; GDR receives a registered-region view instead (Fig 2b)");
    t.note("preprocess folds the payload on the CPU; infer is the real Executor+Engine on tiny_mobilenet_b1 — both identical across rows, so differences are pure transport effects");
    if let (Some(tcp), Some(rdma)) = (t.get("tcp", "total_ms"), t.get("rdma", "total_ms")) {
        let ok = if rdma < tcp { "OK" } else { "VIOLATION" };
        t.note(format!("paper ordering rdma < tcp: {ok} ({rdma:.3} vs {tcp:.3} ms)"));
    }
    if let (Some(rdma), Some(gdr)) = (t.get("rdma", "total_ms"), t.get("gdr", "total_ms")) {
        let ok = if gdr <= rdma { "OK" } else { "VIOLATION" };
        t.note(format!("paper ordering gdr <= rdma: {ok} ({gdr:.3} vs {rdma:.3} ms)"));
    }
    if !super::drain_executor(exec) && failed.is_none() {
        anyhow::bail!("matrix still holds executor clones");
    }
    if let Some(e) = failed {
        return Err(e);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_all_transports() {
        // Small payload / few requests: a smoke test that every cell
        // serves through the real engine and reports positive stage
        // latencies. Ordering is asserted by
        // tests/transport_matrix_ordering.rs with a real-sized payload
        // (timing-sensitive checks live in one isolated test binary).
        let cfg = MatrixCfg {
            payload_bytes: 64 << 10,
            requests: 20,
            warmup: 4,
            transports: TransportKind::ALL.to_vec(),
            artifacts_dir: None,
        };
        let t = run_matrix(&cfg).unwrap();
        assert_eq!(t.rows.len(), 4);
        for kind in ["tcp", "shm", "rdma", "gdr"] {
            for col in ["recv_ms", "preproc_ms", "infer_ms", "total_ms"] {
                let v = t.get(kind, col).unwrap();
                assert!(v > 0.0, "{kind}/{col} = {v}");
            }
            let server = t.get(kind, "server_ms").unwrap();
            let total = t.get(kind, "total_ms").unwrap();
            assert!(total > 0.8 * server, "{kind}: total {total} vs server {server}");
        }
    }

    #[test]
    fn preprocess_output_matches_model_input() {
        let small = RecvMsg::Host(vec![255u8; 100]);
        let t = preprocess(&small);
        assert_eq!(t.len(), MODEL_ELEMS);
        assert!((t[0] - 0.5).abs() < 1e-6, "255 -> +0.5, got {}", t[0]);
        assert!((t[MODEL_ELEMS - 1] + 0.5).abs() < 1e-6, "untouched -> -0.5");
        // Folding is deterministic in the payload alone.
        let a = preprocess(&RecvMsg::Host(WorkloadData::image(9000, 3).bytes));
        let b = preprocess(&RecvMsg::Host(WorkloadData::image(9000, 3).bytes));
        assert_eq!(a, b);
    }
}
