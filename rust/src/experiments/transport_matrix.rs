//! Live-plane transport matrix: the Fig 5/6-style per-stage latency
//! breakdown (recv / preprocess / infer / reply) measured over the
//! *real* transports — tcp, shm, rdma, gdr — on one identical
//! raw-frame workload (`accelserve matrix`).
//!
//! The pipeline is self-contained (a deterministic CPU stand-in for
//! the GPU preprocess + infer stages) so the experiment isolates what
//! the paper isolates: how the communication mechanism moves the
//! per-stage numbers while compute stays fixed. The stage definitions:
//!
//! * **recv** — the server's blocking receive: transfer plus, for the
//!   host-copy transports, the bounce of the payload out of the
//!   transport buffer. GDR's receive hands back a registered-region
//!   view, so this stage drops the payload-sized copy.
//! * **preprocess** — u8 frame -> normalized f32 tensor. Identical
//!   work for every transport (the GDR path reads the registered
//!   region in place).
//! * **infer** — fixed arithmetic over the f32 tensor.
//! * **reply** — serializing + sending the (small) result.
//!
//! `total` is the client-observed round-trip, i.e. the model-serving
//! latency of the paper's Table I.

use std::time::Instant;

use crate::coordinator::protocol::f32s_to_bytes;
use crate::metrics::stats::Series;
use crate::models::zoo::WorkloadData;
use crate::transport::rdma::{rdma_pair, RingCfg};
use crate::transport::shm::shm_pair;
use crate::transport::tcp::TcpTransport;
use crate::transport::{MsgTransport, RecvMsg, TransportKind};

use super::Table;

/// Matrix experiment configuration.
#[derive(Debug, Clone)]
pub struct MatrixCfg {
    /// Raw request payload (bytes). The acceptance workload is >= 1 MiB.
    pub payload_bytes: usize,
    /// Measured requests per transport.
    pub requests: usize,
    /// Discarded leading requests per transport.
    pub warmup: usize,
    pub transports: Vec<TransportKind>,
}

impl Default for MatrixCfg {
    fn default() -> MatrixCfg {
        MatrixCfg {
            payload_bytes: 1 << 20,
            requests: 160,
            warmup: 16,
            transports: TransportKind::ALL.to_vec(),
        }
    }
}

/// Server-side stage samples (ms).
#[derive(Default)]
struct StageStats {
    recv: Series,
    preproc: Series,
    infer: Series,
    reply: Series,
    server: Series,
}

/// u8 camera frame -> normalized f32 tensor; reads region payloads in
/// place (no host bounce).
fn preprocess(msg: &RecvMsg) -> Vec<f32> {
    fn normalize(b: &[u8]) -> Vec<f32> {
        b.iter().map(|&x| x as f32 / 255.0).collect()
    }
    match msg {
        RecvMsg::Host(v) => normalize(v),
        RecvMsg::Region(s) => s.with(normalize),
    }
}

/// Deterministic stand-in inference: banded multiply-accumulate.
fn infer(x: &[f32]) -> Vec<f32> {
    const W: [f32; 8] = [0.11, 0.23, 0.31, 0.43, 0.53, 0.61, 0.71, 0.83];
    let mut acc = [0f32; 8];
    for (i, &v) in x.iter().enumerate() {
        acc[i & 7] += v * W[i & 7];
    }
    acc.to_vec()
}

/// Serve `total` requests on one connection, recording per-stage
/// timings for the ones past `warmup`.
fn pipeline_server(mut t: Box<dyn MsgTransport>, total: usize, warmup: usize) -> StageStats {
    let mut stats = StageStats::default();
    for i in 0..total {
        let t0 = Instant::now();
        let msg = match t.recv_msg() {
            Ok(m) => m,
            Err(_) => break,
        };
        let t1 = Instant::now();
        let tensor = preprocess(&msg);
        drop(msg); // release the region slot before the next receive
        let t2 = Instant::now();
        let out = infer(&tensor);
        let t3 = Instant::now();
        if t.send(&f32s_to_bytes(&out)).is_err() {
            break;
        }
        let t4 = Instant::now();
        if i >= warmup {
            let ms = |a: Instant, b: Instant| (b - a).as_secs_f64() * 1e3;
            stats.recv.push(ms(t0, t1));
            stats.preproc.push(ms(t1, t2));
            stats.infer.push(ms(t2, t3));
            stats.reply.push(ms(t3, t4));
            stats.server.push(ms(t0, t4));
        }
    }
    stats
}

/// Connected (client, server) endpoints for one matrix cell.
fn make_pair(
    kind: TransportKind,
    payload_bytes: usize,
) -> (Box<dyn MsgTransport>, Box<dyn MsgTransport>) {
    match kind {
        TransportKind::Tcp => {
            let listener = TcpTransport::listen("127.0.0.1:0").expect("bind");
            let addr = listener.local_addr().expect("addr");
            let client = TcpTransport::connect(addr).expect("connect");
            let (stream, _) = listener.accept().expect("accept");
            (Box::new(client), Box::new(TcpTransport::from_stream(stream)))
        }
        TransportKind::Shm => {
            let (c, s) = shm_pair(8);
            (Box::new(c), Box::new(s))
        }
        TransportKind::Rdma => {
            let (c, s) = rdma_pair(RingCfg::for_payload(payload_bytes), false);
            (Box::new(c), Box::new(s))
        }
        TransportKind::Gdr => {
            let (c, s) = rdma_pair(RingCfg::for_payload(payload_bytes), true);
            (Box::new(c), Box::new(s))
        }
    }
}

/// One cell: closed-loop client against the pipeline server.
fn run_one(kind: TransportKind, cfg: &MatrixCfg) -> (StageStats, Series) {
    let (mut client, server) = make_pair(kind, cfg.payload_bytes);
    let total = cfg.requests + cfg.warmup;
    let warmup = cfg.warmup;
    let server_thread = std::thread::spawn(move || pipeline_server(server, total, warmup));
    let payload = WorkloadData::image(cfg.payload_bytes, 7).bytes;
    let mut totals = Series::new();
    for i in 0..total {
        let t0 = Instant::now();
        client.send(&payload).expect("send");
        let reply = client.recv().expect("recv");
        assert_eq!(reply.len(), 32, "stand-in inference returns 8 f32s");
        if i >= cfg.warmup {
            totals.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    drop(client);
    let stats = server_thread.join().expect("server thread");
    (stats, totals)
}

/// Run the matrix and render the per-stage latency table (p50 per
/// stage; `total_ms` is the client round trip).
pub fn run_matrix(cfg: &MatrixCfg) -> Table {
    let mut t = Table::new(
        format!(
            "transport matrix — {} KiB raw frames, {} requests",
            cfg.payload_bytes >> 10,
            cfg.requests
        ),
        &[
            "recv_ms",
            "preproc_ms",
            "infer_ms",
            "reply_ms",
            "server_ms",
            "total_ms",
        ],
    );
    for &kind in &cfg.transports {
        let (mut st, mut totals) = run_one(kind, cfg);
        t.row(
            kind.name(),
            vec![
                st.recv.quantile(0.5),
                st.preproc.quantile(0.5),
                st.infer.quantile(0.5),
                st.reply.quantile(0.5),
                st.server.quantile(0.5),
                totals.quantile(0.5),
            ],
        );
    }
    t.note("recv includes transfer + host bounce copy; GDR receives a registered-region view instead (Fig 2b)");
    t.note("preprocess/infer are fixed CPU stand-ins, identical across rows: differences are pure transport effects");
    if let (Some(tcp), Some(rdma)) = (t.get("tcp", "total_ms"), t.get("rdma", "total_ms")) {
        let ok = if rdma < tcp { "OK" } else { "VIOLATION" };
        t.note(format!("paper ordering rdma < tcp: {ok} ({rdma:.3} vs {tcp:.3} ms)"));
    }
    if let (Some(rdma), Some(gdr)) = (t.get("rdma", "total_ms"), t.get("gdr", "total_ms")) {
        let ok = if gdr <= rdma { "OK" } else { "VIOLATION" };
        t.note(format!("paper ordering gdr <= rdma: {ok} ({gdr:.3} vs {rdma:.3} ms)"));
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_all_transports() {
        // Small payload / few requests: a smoke test that every cell
        // serves and reports positive stage latencies. Ordering is
        // asserted by tests/transport_matrix_ordering.rs with a
        // real-sized payload (timing-sensitive checks live in one
        // isolated test binary).
        let cfg = MatrixCfg {
            payload_bytes: 64 << 10,
            requests: 20,
            warmup: 4,
            transports: TransportKind::ALL.to_vec(),
        };
        let t = run_matrix(&cfg);
        assert_eq!(t.rows.len(), 4);
        for kind in ["tcp", "shm", "rdma", "gdr"] {
            for col in ["recv_ms", "preproc_ms", "infer_ms", "total_ms"] {
                let v = t.get(kind, col).unwrap();
                assert!(v > 0.0, "{kind}/{col} = {v}");
            }
            let server = t.get(kind, "server_ms").unwrap();
            let total = t.get(kind, "total_ms").unwrap();
            assert!(total > 0.8 * server, "{kind}: total {total} vs server {server}");
        }
    }
}
