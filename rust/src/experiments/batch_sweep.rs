//! Live-plane batch sweep: latency and throughput per **transport ×
//! batch policy** (`accelserve batchsweep`) — the repo's version of
//! the paper's batching-vs-communication tradeoff.
//!
//! The paper's central observation is that the *net* benefit of
//! RDMA/GPUDirect depends on how the serving pipeline schedules work
//! onto the accelerator: batching grows the compute per communicated
//! byte, which shrinks the fraction of the round trip the transport can
//! save. This experiment measures that interaction directly on the real
//! stack: `clients` closed-loop clients per cell drive one shared
//! [`Executor`] through a private connection each, the dynamic batcher
//! coalesces their concurrent requests onto the `_b{2,4,8}` artifacts,
//! and the table reports client-observed latency (p50/p99/mean),
//! aggregate throughput, and the mean achieved batch size
//! ([`Executor::batch_counters`]).
//!
//! Reading the table: within one transport row group, moving from `b1`
//! to a batched policy trades per-request latency for throughput;
//! across transports under a fixed policy, the latency gap between
//! `tcp` and `rdma`/`gdr` is the communication share that batching has
//! not amortized away.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{BatchCfg, Executor, LiveStats};
use crate::models::gen;
use crate::models::manifest::Manifest;
use crate::transport::TransportKind;

use super::{drain_executor, drive_model_clients, Table};

/// Batch-sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepCfg {
    /// Served model (must have `_b{N}` artifacts in the manifest).
    pub model: String,
    /// Concurrent closed-loop clients per cell — the batcher's supply
    /// of coalescable requests.
    pub clients: usize,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams. 1 (the default) makes the batching effect
    /// visible: requests queue behind the busy stream and coalesce.
    pub streams: usize,
    pub transports: Vec<TransportKind>,
    pub policies: Vec<BatchCfg>,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for SweepCfg {
    fn default() -> SweepCfg {
        SweepCfg {
            model: "tiny_mobilenet".to_string(),
            clients: 8,
            requests: 40,
            warmup: 4,
            streams: 1,
            transports: TransportKind::ALL.to_vec(),
            policies: vec![
                BatchCfg::none(),
                BatchCfg::opportunistic(8),
                BatchCfg::deadline(8, 2000),
            ],
            artifacts_dir: None,
        }
    }
}

/// One cell: `clients` private connections into one shared executor.
/// Every transport kind gets the same treatment — per-connection server
/// threads running `handle_conn`, closed-loop clients via `run_on`
/// (see [`drive_model_clients`]).
fn run_cell(kind: TransportKind, exec: &Arc<Executor>, cfg: &SweepCfg) -> Result<LiveStats> {
    // spans off: keep this sweep's wire conditions v1-identical.
    drive_model_clients(
        kind, exec, &cfg.model, cfg.clients, cfg.requests, cfg.warmup, false,
    )
}

/// Run the sweep and render one row per transport × policy with
/// client-observed latency, throughput, and the mean achieved batch.
pub fn run_batch_sweep(cfg: &SweepCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    // Warm every batch variant the sweep can reach so compilation never
    // lands inside a measured request.
    let manifest = Manifest::load(&dir)?;
    let warm: Vec<String> = manifest
        .batch_sizes(&cfg.model)
        .into_iter()
        .map(|b| format!("{}_b{b}", cfg.model))
        .collect();
    if warm.is_empty() {
        anyhow::bail!(
            "model {} has no artifacts under {} — nothing to sweep",
            cfg.model,
            dir.display()
        );
    }
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();

    let mut t = Table::new(
        format!(
            "batch sweep — {} × {} closed-loop clients, {} requests each, {} stream(s)",
            cfg.model, cfg.clients, cfg.requests, cfg.streams
        ),
        &["p50_ms", "p99_ms", "mean_ms", "thr_rps", "avg_batch"],
    );
    for &policy in &cfg.policies {
        let exec = Arc::new(
            Executor::start(&dir, cfg.streams, policy, &warm_refs)
                .with_context(|| format!("sweep executor over {}", dir.display()))?,
        );
        let mut failed: Option<anyhow::Error> = None;
        for &kind in &cfg.transports {
            let (jobs0, calls0) = exec.batch_counters();
            let stats = match run_cell(kind, &exec, cfg)
                .with_context(|| format!("cell {} {}", kind.name(), policy.label()))
            {
                Ok(s) => s,
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            };
            let (jobs1, calls1) = exec.batch_counters();
            let avg_batch = (jobs1 - jobs0) as f64 / (calls1 - calls0).max(1) as f64;
            let lat = stats.all.total.summary();
            t.row(
                format!("{} {}", kind.name(), policy.label()),
                vec![lat.p50, lat.p99, lat.mean, stats.throughput_rps, avg_batch],
            );
        }
        // Shut the scheduler + workers down before propagating any
        // cell error — bailing first would park those threads forever.
        // On the happy path every server thread was joined in
        // run_cell; after an aborted cell a handler can hold a clone
        // for a moment longer, which drain_executor rides out.
        if !drain_executor(exec) && failed.is_none() {
            anyhow::bail!("sweep still holds executor clones");
        }
        if let Some(e) = failed {
            return Err(e);
        }
    }
    t.note("b1 = no batching; bN = opportunistic coalescing up to N; bN@Dus = hold the batch head up to D µs for peers");
    t.note("avg_batch = jobs / executable calls over the whole cell (warm-up included, so ramp-up biases it slightly low vs the steady state the latency columns measure)");
    t.note("the tcp-vs-rdma/gdr latency gap under a fixed policy is the communication share batching has not amortized (paper §V)");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_all_cells() {
        // Smoke: every transport × policy cell serves through the real
        // engine and reports positive latency/throughput and a sane
        // achieved batch (in [1, max_batch]). Coalescing determinism is
        // asserted by tests/batching.rs; this checks the harness.
        let cfg = SweepCfg {
            clients: 3,
            requests: 6,
            warmup: 2,
            transports: vec![TransportKind::Tcp, TransportKind::Shm],
            policies: vec![BatchCfg::none(), BatchCfg::deadline(4, 500)],
            ..SweepCfg::default()
        };
        let t = run_batch_sweep(&cfg).unwrap();
        assert_eq!(t.rows.len(), 4);
        for policy in ["b1", "b4@500us"] {
            for kind in ["tcp", "shm"] {
                let row = format!("{kind} {policy}");
                for col in ["p50_ms", "p99_ms", "mean_ms", "thr_rps"] {
                    let v = t.get(&row, col).unwrap();
                    assert!(v > 0.0, "{row}/{col} = {v}");
                }
                let avg = t.get(&row, "avg_batch").unwrap();
                assert!((1.0..=4.0).contains(&avg), "{row}/avg_batch = {avg}");
                if policy == "b1" {
                    assert!((avg - 1.0).abs() < 1e-9, "unbatched cell fused jobs");
                }
            }
        }
    }
}
