//! Live-plane backpressure sweep: **credits off vs on** per transport ×
//! offered-load factor (`accelserve throttlesweep`) — the repo's
//! client-throttling experiment.
//!
//! `slosweep` showed what admission control buys once overload has
//! already arrived at the server: unwinnable requests fail in one RTT
//! instead of rotting in a queue. But every shed still costs a wire
//! round-trip and a submit-edge evaluation — the server is paying to
//! say no. This sweep measures the next step: the credit/pacing hints
//! the server piggybacks on every response when the client opts in
//! (`FLAG_CREDITS`, the status-5 envelope), which move the waiting to
//! the *client* so overload never reaches the submit edge at all.
//!
//! Each factor runs twice under identical geometry — closed-loop
//! clients with a tight (2× solo service time) SLO deadline — once with
//! credits off (pure admission control, the `slosweep` condition) and
//! once with each client pacing on the server's hints. Reading the
//! table: at overload (`4x` and up) `shed_pct` should collapse in the
//! `on` rows while `good_rps` holds — the same requests get served, the
//! refusals just stop being manufactured. Every cell keeps the
//! three-way shed-accounting cross-check (wire status vs lane counters
//! vs client tally) from `slosweep`.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{
    fetch_stats, handle_conn, BatchCfg, Executor, SchedCfg, DEFAULT_QUEUE_CAP,
};
use crate::models::gen;
use crate::models::manifest::Manifest;
use crate::transport::{connected_pair, TransportKind};

use super::slo_sweep::calibrate_svc_us;
use super::{drain_executor, drive_model_clients_slo, Table};

/// Throttle-sweep configuration (same load geometry as
/// [`super::SloCfg`]; each factor is run once per credits mode).
#[derive(Debug, Clone)]
pub struct ThrottleCfg {
    /// Served model (must have artifacts in the manifest).
    pub model: String,
    /// Offered-load multiples of service capacity; each factor yields
    /// two rows per transport — credits `off` and `on`.
    pub factors: Vec<f64>,
    /// Measured requests per client.
    pub requests: usize,
    /// Discarded leading requests per client.
    pub warmup: usize,
    /// Execution streams (1 by default so overload is easy to reach).
    pub streams: usize,
    /// Per-request SLO budget in µs. `None` auto-calibrates to
    /// 2× the measured solo service time (floored at 200µs).
    pub deadline_us: Option<u64>,
    /// Per-lane queue bound ([`SchedCfg::queue_cap`]).
    pub queue_cap: usize,
    pub transports: Vec<TransportKind>,
    /// Artifact directory; `None` generates into a per-process temp dir.
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for ThrottleCfg {
    fn default() -> ThrottleCfg {
        ThrottleCfg {
            model: "tiny_mobilenet".to_string(),
            factors: vec![2.0, 4.0, 8.0],
            requests: 30,
            warmup: 3,
            streams: 1,
            deadline_us: None,
            queue_cap: DEFAULT_QUEUE_CAP,
            transports: vec![TransportKind::Tcp],
            artifacts_dir: None,
        }
    }
}

/// Run the sweep: per transport × factor × credits mode, a fresh
/// executor (clean counters), a calibration pass, then `ceil(factor ×
/// streams)` closed-loop deadline-carrying clients — paced by server
/// hints in the `on` rows.
pub fn run_throttle_sweep(cfg: &ThrottleCfg) -> Result<Table> {
    let dir: PathBuf = match &cfg.artifacts_dir {
        Some(d) => d.clone(),
        None => gen::ensure_test_artifacts().to_path_buf(),
    };
    gen::ensure_artifacts(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let warm: Vec<String> = manifest
        .batch_sizes(&cfg.model)
        .into_iter()
        .map(|b| format!("{}_b{b}", cfg.model))
        .collect();
    if warm.is_empty() {
        anyhow::bail!(
            "model {} has no artifacts under {} — nothing to sweep",
            cfg.model,
            dir.display()
        );
    }
    let warm_refs: Vec<&str> = warm.iter().map(String::as_str).collect();
    let payload_elems = gen::IN_H * gen::IN_W * gen::CHANNELS;

    let mut t = Table::new(
        format!(
            "throttle sweep — {} credits off vs on, {} stream(s), {} requests/client",
            cfg.model, cfg.streams, cfg.requests
        ),
        &["clients", "slo_ms", "p50_ms", "p99_ms", "good_rps", "shed_pct"],
    );
    for &kind in &cfg.transports {
        for &factor in &cfg.factors {
            for credits in [false, true] {
                let sched = SchedCfg {
                    // Batching off, as in slosweep: "offered load ×"
                    // means exactly that many service times per second.
                    default: BatchCfg::none(),
                    per_model: Vec::new(),
                    queue_cap: cfg.queue_cap,
                };
                let exec = Arc::new(
                    Executor::start_with(&dir, cfg.streams, sched, &warm_refs).with_context(
                        || format!("throttlesweep executor over {}", dir.display()),
                    )?,
                );
                let cell = run_cell(kind, &exec, cfg, factor, credits, payload_elems, &mut t);
                if !drain_executor(exec) && cell.is_ok() {
                    anyhow::bail!("throttlesweep still holds executor clones");
                }
                cell?;
            }
        }
    }
    t.note("each factor runs twice under identical geometry: `off` = admission control only (the slosweep condition), `on` = clients pace on the server's credit hints (FLAG_CREDITS)");
    t.note("shed_pct collapsing in the `on` rows while good_rps holds is the point: the waiting moved to the client, so the server stops paying round-trips to say no");
    t.note("every cell cross-checks client-side shed tallies against the executor's per-lane shed counters fetched via the stats opcode");
    Ok(t)
}

/// One cell: calibrate, overload (paced or not), verify the three shed
/// views agree, append the row.
fn run_cell(
    kind: TransportKind,
    exec: &Arc<Executor>,
    cfg: &ThrottleCfg,
    factor: f64,
    credits: bool,
    payload_elems: usize,
    t: &mut Table,
) -> Result<()> {
    let svc_us = calibrate_svc_us(exec, &cfg.model, payload_elems)?;
    let deadline_us = cfg.deadline_us.unwrap_or_else(|| (2 * svc_us).max(200));
    let clients = ((factor * cfg.streams as f64).ceil() as usize).max(1);
    let mode = if credits { "on" } else { "off" };
    let stats = drive_model_clients_slo(
        kind,
        exec,
        &cfg.model,
        clients,
        cfg.requests,
        cfg.warmup,
        false,
        Some(deadline_us),
        credits,
    )
    .with_context(|| format!("cell {} {factor}x {mode}", kind.name()))?;

    // Same three-way cross-check as slosweep: wire stats == in-process
    // snapshot, lane shed counters == client-side tally. Settle first.
    let local = {
        let mut prev = exec.stats();
        loop {
            std::thread::sleep(std::time::Duration::from_millis(10));
            let next = exec.stats();
            if next == prev {
                break next;
            }
            prev = next;
        }
    };
    let wire = {
        let (mut client, server) = connected_pair(kind, 4096)?;
        let e2 = exec.clone();
        let th = std::thread::spawn(move || handle_conn(server, &e2));
        let wire = fetch_stats(client.as_mut());
        drop(client);
        th.join()
            .map_err(|_| anyhow::anyhow!("stats server thread panicked"))?;
        wire?
    };
    if wire != local {
        anyhow::bail!(
            "stats opcode disagrees with the in-process snapshot:\nwire  {wire:?}\nlocal {local:?}"
        );
    }
    let lane_sheds: u64 = wire.lanes.iter().map(|l| l.shed.iter().sum::<u64>()).sum();
    if lane_sheds != stats.sheds as u64 {
        anyhow::bail!(
            "shed accounting mismatch: lanes counted {lane_sheds}, clients saw {}",
            stats.sheds
        );
    }

    let lat = stats.all.total.summary();
    let offered = stats.sheds + stats.served;
    let shed_pct = 100.0 * stats.sheds as f64 / (offered.max(1)) as f64;
    t.row(
        format!("{} {factor}x {mode}", kind.name()),
        vec![
            clients as f64,
            deadline_us as f64 / 1_000.0,
            lat.p50,
            lat.p99,
            stats.throughput_rps,
            shed_pct,
        ],
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn credits_cut_sheds_at_overload_without_losing_goodput() {
        // Smoke: one 4× factor over TCP, credits off vs on. Off is the
        // slosweep condition — four closed loops against one stream
        // under a 2×-svc SLO must shed (admission wait = est × (ahead +
        // 1) exceeds the deadline as soon as anyone is ahead). On, each
        // client paces on the hints, so depth stays near the stream
        // count and most requests that would have been refused are
        // simply sent later — strictly fewer sheds. Goodput holds
        // because the server was saturated either way; the tolerance
        // absorbs CI-runner jitter.
        let cfg = ThrottleCfg {
            factors: vec![4.0],
            requests: 25,
            warmup: 3,
            transports: vec![TransportKind::Tcp],
            ..ThrottleCfg::default()
        };
        let t = run_throttle_sweep(&cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
        let shed_off = t.get("tcp 4x off", "shed_pct").unwrap();
        let shed_on = t.get("tcp 4x on", "shed_pct").unwrap();
        assert!(
            shed_off > 0.0,
            "4x offered load without pacing must shed something"
        );
        assert!(
            shed_on < shed_off,
            "credit pacing must strictly cut sheds: on {shed_on}% vs off {shed_off}%"
        );
        let good_off = t.get("tcp 4x off", "good_rps").unwrap();
        let good_on = t.get("tcp 4x on", "good_rps").unwrap();
        assert!(
            good_on >= good_off * 0.7,
            "pacing should not cost goodput: on {good_on} rps vs off {good_off} rps"
        );
    }
}
