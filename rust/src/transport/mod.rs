//! Live-plane transports: message-oriented, zero-serialization (raw
//! tensor bytes, like the paper's ZeroMQ/RDMA choice in §III-A).

pub mod shm;
pub mod tcp;

use anyhow::Result;

/// A blocking, message-oriented bidirectional transport.
pub trait MsgTransport: Send {
    /// Send one message (framing is the transport's concern).
    fn send(&mut self, payload: &[u8]) -> Result<()>;
    /// Receive one message, blocking.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Mechanism name for metrics/labels.
    fn kind(&self) -> &'static str;
}
