//! Live-plane transports: message-oriented, zero-serialization (raw
//! tensor bytes, like the paper's ZeroMQ/RDMA choice in §III-A).
//!
//! # The transport matrix
//!
//! The live serving plane speaks one [`MsgTransport`] trait over four
//! mechanisms, mirroring the paper's experimental axis (§III-C):
//!
//! | kind   | module  | data path                                                |
//! |--------|---------|----------------------------------------------------------|
//! | `tcp`  | [`tcp`] | length-prefixed frames over loopback/network sockets      |
//! | `shm`  | [`shm`] | bounded shared-memory message queue (ZeroMQ `ipc://`-like)|
//! | `rdma` | [`rdma`]| verbs-style one-sided writes into pre-registered MR rings; the receiver still bounces the payload into a host buffer |
//! | `gdr`  | [`rdma`]| same wire path as `rdma`, but the registered ring stands for GPU device memory: [`MsgTransport::recv_msg`] returns a [`RecvMsg::Region`] view and the host bounce copy disappears |
//!
//! Servers, clients and the gateway are transport-generic: they are
//! built from an [`Acceptor`] (listener side) or a connector closure
//! (dialer side), so the same coordinator code serves any cell of the
//! matrix — see `coordinator::{serve_on, run_on, gateway_on}`. The
//! per-stage latency effect of each mechanism is measured by
//! `experiments::transport_matrix` (`accelserve matrix`).

pub mod rdma;
pub mod shm;
pub mod tcp;

use anyhow::{Context, Result};

use crate::rdmasim::RegionSlice;

/// Hard cap on a single message, shared by all transports (64 MiB
/// covers tiny_segnet_b8 responses).
pub const MAX_MSG: usize = 64 << 20;

/// One received message: either copied to a host buffer (the classic
/// path) or still resident in a registered region (the GDR path).
#[derive(Debug)]
pub enum RecvMsg {
    /// Payload copied into host memory.
    Host(Vec<u8>),
    /// Zero-copy view into the transport's registered receive region
    /// (device-staging memory in GDR mode). Valid until the next `recv`
    /// on the same transport — see [`RegionSlice`].
    Region(RegionSlice),
}

impl RecvMsg {
    pub fn len(&self) -> usize {
        match self {
            RecvMsg::Host(v) => v.len(),
            RecvMsg::Region(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize to host bytes (copies for the `Region` arm).
    pub fn into_vec(self) -> Vec<u8> {
        match self {
            RecvMsg::Host(v) => v,
            RecvMsg::Region(s) => s.to_vec(),
        }
    }
}

/// A blocking, message-oriented bidirectional transport.
pub trait MsgTransport: Send {
    /// Send one message (framing is the transport's concern).
    fn send(&mut self, payload: &[u8]) -> Result<()>;
    /// Receive one message into a host buffer, blocking.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Receive one message, letting zero-copy-capable transports hand
    /// back a registered-region view instead of a host copy. The
    /// default just wraps [`MsgTransport::recv`].
    fn recv_msg(&mut self) -> Result<RecvMsg> {
        Ok(RecvMsg::Host(self.recv()?))
    }
    /// Monotonic instant at which the *last received* message was
    /// complete at the transport boundary (ring slot / queue / socket),
    /// before any host bounce copy out of it — the live analogue of an
    /// RDMA WR timestamp, used as the base of a request's trace span.
    /// `None` when the transport does not track it (the server then
    /// falls back to the post-receive clock, folding the bounce into
    /// transport time).
    fn recv_boundary(&self) -> Option<std::time::Instant> {
        None
    }
    /// Mechanism name for metrics/labels.
    fn kind(&self) -> &'static str;
    /// A handle that, invoked from another thread, unblocks anyone
    /// parked in [`MsgTransport::recv`] on this transport by closing
    /// it (subsequent operations error). `None` when the transport
    /// cannot be interrupted cross-thread — a server `stop()` then
    /// leaves that connection's handler to exit on peer close. Used by
    /// `coordinator::{ServeLoop, GatewayLoop}` so stopping a server
    /// actually stops its per-connection threads.
    fn shutdown_hook(&self) -> Option<Box<dyn FnOnce() + Send>> {
        None
    }
}

impl<T: MsgTransport + ?Sized> MsgTransport for Box<T> {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        (**self).send(payload)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        (**self).recv()
    }

    fn recv_msg(&mut self) -> Result<RecvMsg> {
        (**self).recv_msg()
    }

    fn recv_boundary(&self) -> Option<std::time::Instant> {
        (**self).recv_boundary()
    }

    fn kind(&self) -> &'static str {
        (**self).kind()
    }

    fn shutdown_hook(&self) -> Option<Box<dyn FnOnce() + Send>> {
        (**self).shutdown_hook()
    }
}

/// Listener half of a transport: the server accept loop polls it.
pub trait Acceptor: Send + 'static {
    type Conn: MsgTransport + 'static;
    /// Non-blocking accept: `Ok(Some)` is a new connection, `Ok(None)`
    /// means nothing pending (the loop sleeps briefly), `Err` is fatal.
    fn poll_accept(&mut self) -> Result<Option<Self::Conn>>;
}

/// Which live-plane transport to use: the knob `config/scenario.rs`
/// and the CLI expose (`--transport`, `"live_transport"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransportKind {
    Tcp,
    Shm,
    Rdma,
    Gdr,
}

impl TransportKind {
    pub const ALL: [TransportKind; 4] = [
        TransportKind::Tcp,
        TransportKind::Shm,
        TransportKind::Rdma,
        TransportKind::Gdr,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Tcp => "tcp",
            TransportKind::Shm => "shm",
            TransportKind::Rdma => "rdma",
            TransportKind::Gdr => "gdr",
        }
    }

    pub fn by_name(s: &str) -> Option<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Some(TransportKind::Tcp),
            "shm" => Some(TransportKind::Shm),
            "rdma" => Some(TransportKind::Rdma),
            "gdr" | "gpudirect" => Some(TransportKind::Gdr),
            _ => None,
        }
    }

    /// Does this transport's receive path skip the host bounce copy?
    pub fn zero_copy_recv(self) -> bool {
        matches!(self, TransportKind::Gdr)
    }
}

/// An in-process connected `(client, server)` endpoint pair over
/// `kind` — the one-call way to get any cell of the transport matrix,
/// used by the experiment harnesses (`experiments::transport_matrix`,
/// `experiments::batch_sweep`). `payload_hint` sizes the RDMA/GDR
/// receive rings so a typical request stays single-chunk (and therefore
/// zero-copy eligible in GDR mode).
pub fn connected_pair(
    kind: TransportKind,
    payload_hint: usize,
) -> Result<(Box<dyn MsgTransport>, Box<dyn MsgTransport>)> {
    use crate::transport::rdma::{rdma_pair, RingCfg};
    use crate::transport::shm::shm_pair;
    use crate::transport::tcp::TcpTransport;
    Ok(match kind {
        TransportKind::Tcp => {
            let listener = TcpTransport::listen("127.0.0.1:0").context("tcp bind")?;
            let addr = listener.local_addr().context("tcp local addr")?;
            let client = TcpTransport::connect(addr).context("tcp connect")?;
            let (stream, _) = listener.accept().context("tcp accept")?;
            (Box::new(client), Box::new(TcpTransport::from_stream(stream)))
        }
        TransportKind::Shm => {
            let (c, s) = shm_pair(8);
            (Box::new(c), Box::new(s))
        }
        TransportKind::Rdma => {
            let (c, s) = rdma_pair(RingCfg::for_payload(payload_hint), false);
            (Box::new(c), Box::new(s))
        }
        TransportKind::Gdr => {
            let (c, s) = rdma_pair(RingCfg::for_payload(payload_hint), true);
            (Box::new(c), Box::new(s))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in TransportKind::ALL {
            assert_eq!(TransportKind::by_name(k.name()), Some(k));
        }
        assert_eq!(TransportKind::by_name("GPUDirect"), Some(TransportKind::Gdr));
        assert_eq!(TransportKind::by_name("warp"), None);
        assert!(TransportKind::Gdr.zero_copy_recv());
        assert!(!TransportKind::Rdma.zero_copy_recv());
    }

    #[test]
    fn recv_msg_materializes() {
        let m = RecvMsg::Host(vec![1, 2, 3]);
        assert_eq!(m.len(), 3);
        assert!(!m.is_empty());
        assert_eq!(m.into_vec(), vec![1, 2, 3]);
    }
}
