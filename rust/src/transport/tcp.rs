//! TCP transport: length-prefixed frames over `std::net::TcpStream`,
//! Nagle disabled. No payload serialization — raw tensor bytes, making
//! latency comparable with the verbs transport (the paper's reason for
//! choosing ZeroMQ over HTTP/GRPC, §III-A).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::{Acceptor, MsgTransport};

/// Hard cap on a single frame (the shared transport-wide message cap).
pub const MAX_FRAME: usize = super::MAX_MSG;

/// One framed TCP connection.
pub struct TcpTransport {
    stream: TcpStream,
    /// When the last frame finished arriving (trace-span base; the
    /// kernel's socket-buffer copies are invisible, so this coincides
    /// with the receive returning).
    last_boundary: Option<std::time::Instant>,
}

impl TcpTransport {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr).context("tcp connect")?;
        stream.set_nodelay(true).ok();
        Ok(TcpTransport {
            stream,
            last_boundary: None,
        })
    }

    /// Connect with an optional timeout applied to the connect itself
    /// and to every subsequent read/write. `None` behaves exactly like
    /// [`TcpTransport::connect`] (block forever). With a timeout, a
    /// peer that accepts but never replies surfaces as a `recv` error
    /// instead of wedging the calling thread.
    pub fn connect_timed(addr: impl ToSocketAddrs, timeout: Option<Duration>) -> Result<TcpTransport> {
        let Some(d) = timeout else {
            return TcpTransport::connect(addr);
        };
        // connect_timeout wants a resolved SocketAddr; try each in turn.
        let mut last: Option<std::io::Error> = None;
        let addrs = addr.to_socket_addrs().context("resolve addr")?;
        let stream = addrs
            .into_iter()
            .find_map(|a| match TcpStream::connect_timeout(&a, d) {
                Ok(s) => Some(s),
                Err(e) => {
                    last = Some(e);
                    None
                }
            })
            .ok_or_else(|| match last {
                Some(e) => anyhow::anyhow!("tcp connect (timed): {e}"),
                None => anyhow::anyhow!("no socket address resolved"),
            })?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(d)).context("read timeout")?;
        stream.set_write_timeout(Some(d)).context("write timeout")?;
        Ok(TcpTransport {
            stream,
            last_boundary: None,
        })
    }

    pub fn from_stream(stream: TcpStream) -> TcpTransport {
        stream.set_nodelay(true).ok();
        TcpTransport {
            stream,
            last_boundary: None,
        }
    }

    /// Bind a listener on an ephemeral (or given) port.
    pub fn listen(addr: &str) -> Result<TcpListener> {
        TcpListener::bind(addr).with_context(|| format!("bind {addr}"))
    }
}

impl MsgTransport for TcpTransport {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        if payload.len() > MAX_FRAME {
            bail!("frame too large: {}", payload.len());
        }
        let len = (payload.len() as u32).to_le_bytes();
        self.stream.write_all(&len)?;
        self.stream.write_all(payload)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).context("frame header")?;
        let n = u32::from_le_bytes(len) as usize;
        if n > MAX_FRAME {
            bail!("oversized frame: {n}");
        }
        let mut buf = vec![0u8; n];
        self.stream.read_exact(&mut buf).context("frame body")?;
        self.last_boundary = Some(std::time::Instant::now());
        Ok(buf)
    }

    fn recv_boundary(&self) -> Option<std::time::Instant> {
        self.last_boundary
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn shutdown_hook(&self) -> Option<Box<dyn FnOnce() + Send>> {
        // A cloned handle shares the underlying socket, so shutting it
        // down errors out a concurrent blocking `read_exact` in `recv`.
        let stream = self.stream.try_clone().ok()?;
        Some(Box::new(move || {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }))
    }
}

/// Non-blocking accept wrapper plugging a `TcpListener` into the
/// transport-generic server loop (`coordinator::serve_on`).
pub struct TcpAcceptor {
    listener: TcpListener,
}

impl TcpAcceptor {
    /// Takes ownership of a bound listener and switches it to
    /// non-blocking accepts.
    pub fn new(listener: TcpListener) -> Result<TcpAcceptor> {
        listener
            .set_nonblocking(true)
            .context("listener nonblocking")?;
        Ok(TcpAcceptor { listener })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("listener addr")
    }
}

impl Acceptor for TcpAcceptor {
    type Conn = TcpTransport;

    fn poll_accept(&mut self) -> Result<Option<TcpTransport>> {
        match self.listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nonblocking(false).ok();
                Ok(Some(TcpTransport::from_stream(stream)))
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn frames_roundtrip() {
        let listener = TcpTransport::listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(s);
            for _ in 0..3 {
                let msg = t.recv().unwrap();
                let echoed: Vec<u8> = msg.iter().rev().copied().collect();
                t.send(&echoed).unwrap();
            }
        });
        let mut c = TcpTransport::connect(addr).unwrap();
        for size in [0usize, 5, 100_000] {
            let msg: Vec<u8> = (0..size).map(|i| (i % 251) as u8).collect();
            c.send(&msg).unwrap();
            let back = c.recv().unwrap();
            let want: Vec<u8> = msg.iter().rev().copied().collect();
            assert_eq!(back, want, "size {size}");
        }
        server.join().unwrap();
    }

    #[test]
    fn shutdown_hook_unblocks_parked_recv() {
        let listener = TcpTransport::listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpTransport::connect(addr).unwrap();
        let (s, _) = listener.accept().unwrap();
        let mut srv = TcpTransport::from_stream(s);
        let hook = srv.shutdown_hook().expect("tcp is interruptible");
        let reader = thread::spawn(move || srv.recv());
        // Let the reader park in read_exact before firing the hook.
        thread::sleep(Duration::from_millis(50));
        hook();
        let res = reader.join().unwrap();
        assert!(res.is_err(), "shutdown must error the parked recv");
        // The shutdown is visible to the peer as a close, not a hang.
        assert!(client.recv().is_err());
    }

    #[test]
    fn rejects_oversized_send() {
        let listener = TcpTransport::listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _keep = thread::spawn(move || listener.accept().map(|_| ()).ok());
        let mut c = TcpTransport::connect(addr).unwrap();
        let huge = vec![0u8; MAX_FRAME + 1];
        assert!(c.send(&huge).is_err());
    }
}
