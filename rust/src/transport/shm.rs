//! SHM transport: a bounded shared-memory message queue per direction,
//! modeling an intra-host IPC transport (ZeroMQ `ipc://`): the sender
//! copies the message into shared memory, the receiver copies it out —
//! one hop cheaper than TCP (no protocol stack), but without the
//! registered-buffer semantics of the verbs path in `transport::rdma`.
//!
//! The queue is bounded (`depth` messages), so a fast producer blocks
//! instead of ballooning memory — the flow-control analogue of a full
//! socket buffer.

use std::sync::mpsc;

use anyhow::{anyhow, bail, Result};

use super::{MsgTransport, MAX_MSG};

/// One endpoint of a bidirectional shared-memory connection.
pub struct ShmTransport {
    tx: mpsc::SyncSender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
    /// When the last message was taken off the shared queue
    /// (trace-span base; the copy-out is not modeled, so this
    /// coincides with the receive returning).
    last_boundary: Option<std::time::Instant>,
}

/// Create a connected pair whose per-direction queues hold up to
/// `depth` in-flight messages.
pub fn shm_pair(depth: usize) -> (ShmTransport, ShmTransport) {
    let depth = depth.max(1);
    let (a_tx, b_rx) = mpsc::sync_channel(depth);
    let (b_tx, a_rx) = mpsc::sync_channel(depth);
    (
        ShmTransport {
            tx: a_tx,
            rx: a_rx,
            last_boundary: None,
        },
        ShmTransport {
            tx: b_tx,
            rx: b_rx,
            last_boundary: None,
        },
    )
}

impl MsgTransport for ShmTransport {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        if payload.len() > MAX_MSG {
            bail!("message too large: {} bytes", payload.len());
        }
        self.tx
            .send(payload.to_vec())
            .map_err(|_| anyhow!("peer disconnected"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let msg = self.rx.recv().map_err(|_| anyhow!("peer disconnected"))?;
        self.last_boundary = Some(std::time::Instant::now());
        Ok(msg)
    }

    fn recv_boundary(&self) -> Option<std::time::Instant> {
        self.last_boundary
    }

    fn kind(&self) -> &'static str {
        "shm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn shm_roundtrip() {
        let (mut c, mut s) = shm_pair(4);
        let server = thread::spawn(move || {
            for _ in 0..10 {
                let req = s.recv().unwrap();
                let resp: Vec<u8> = req.iter().map(|b| b ^ 0xFF).collect();
                s.send(&resp).unwrap();
            }
        });
        for i in 0..10usize {
            let msg = vec![i as u8; 100 * (i + 1)];
            c.send(&msg).unwrap();
            let back = c.recv().unwrap();
            assert_eq!(back.len(), msg.len());
            assert!(back.iter().all(|&b| b == (i as u8) ^ 0xFF));
        }
        server.join().unwrap();
    }

    #[test]
    fn close_surfaces_on_recv() {
        let (c, mut s) = shm_pair(4);
        drop(c);
        assert!(s.recv().is_err());
    }

    #[test]
    fn oversized_message_rejected() {
        let (mut c, _s) = shm_pair(1);
        assert!(c.send(&vec![0u8; MAX_MSG + 1]).is_err());
    }

    #[test]
    fn kind_is_shm() {
        let (c, _s) = shm_pair(1);
        assert_eq!(c.kind(), "shm");
    }
}
