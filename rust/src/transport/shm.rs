//! SHM-verbs transport: the `MsgTransport` face of the rdmasim layer.
//!
//! Messages are RDMA_WRITEs into the peer's pre-registered region
//! followed by a work completion — one buffer per direction, sized at
//! connection setup exactly as the paper's per-client pinned buffers
//! (§III-A; the memory-overhead limitation of §VII falls out of this:
//! buffers are reserved per client for the connection's lifetime).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::rdmasim::qp::WR_ID_CLOSE;
use crate::rdmasim::{connect_pair, MemoryRegion, QueuePair};

use super::MsgTransport;

/// One endpoint of a verbs-style connection.
pub struct ShmTransport {
    qp: QueuePair,
    /// GDR mode: the target region stands for GPU device memory, so the
    /// receiving server reads payloads with no staging copy.
    pub gdr: bool,
    next_wr: u64,
}

/// Create a connected client/server pair with `buf_len`-byte regions.
pub fn shm_pair(buf_len: usize, gdr: bool) -> (ShmTransport, ShmTransport) {
    let client_mr = Arc::new(MemoryRegion::register(buf_len));
    let server_mr = Arc::new(MemoryRegion::register(buf_len));
    let (cq, sq) = connect_pair(client_mr, server_mr, 64);
    (
        ShmTransport {
            qp: cq,
            gdr,
            next_wr: 0,
        },
        ShmTransport {
            qp: sq,
            gdr,
            next_wr: 0,
        },
    )
}

impl MsgTransport for ShmTransport {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        if payload.len() + 8 > self.qp.peer_mr().len() {
            bail!(
                "message {}B exceeds registered region {}B",
                payload.len(),
                self.qp.peer_mr().len()
            );
        }
        // Length goes in-band at the region head via a silent write; the
        // payload write carries the single completion (one wakeup per
        // message — RDMA_WRITE + RDMA_WRITE_WITH_IMM pattern).
        let wr = self.next_wr;
        self.next_wr += 1;
        let len = (payload.len() as u64).to_le_bytes();
        self.qp
            .post_write_silent(&len, 0)
            .map_err(|e| anyhow!("post len: {e}"))?;
        self.qp
            .post_write(payload, 8, wr)
            .map_err(|e| anyhow!("post payload: {e}"))?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        // One completion per message; its byte count is authoritative.
        // A close sentinel means the peer tore the QP down.
        let wc = self.qp.cq().poll_blocking();
        if wc.wr_id == WR_ID_CLOSE {
            bail!("peer disconnected");
        }
        Ok(self.qp.local_mr().read(8, wc.byte_len))
    }

    fn kind(&self) -> &'static str {
        if self.gdr {
            "gdr"
        } else {
            "rdma"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn shm_roundtrip() {
        let (mut c, mut s) = shm_pair(1 << 16, true);
        let server = thread::spawn(move || {
            for _ in 0..10 {
                let req = s.recv().unwrap();
                let resp: Vec<u8> = req.iter().map(|b| b ^ 0xFF).collect();
                s.send(&resp).unwrap();
            }
        });
        for i in 0..10usize {
            let msg = vec![i as u8; 100 * (i + 1)];
            c.send(&msg).unwrap();
            let back = c.recv().unwrap();
            assert_eq!(back.len(), msg.len());
            assert!(back.iter().all(|&b| b == (i as u8) ^ 0xFF));
        }
        server.join().unwrap();
    }

    #[test]
    fn oversized_message_rejected() {
        let (mut c, _s) = shm_pair(128, false);
        assert!(c.send(&[0u8; 121]).is_err());
        assert!(c.send(&[0u8; 120]).is_ok());
    }

    #[test]
    fn kind_reflects_gdr() {
        let (c, _s) = shm_pair(64, true);
        assert_eq!(c.kind(), "gdr");
        let (r, _s) = shm_pair(64, false);
        assert_eq!(r.kind(), "rdma");
    }
}
