//! RDMA transport: the `MsgTransport` face of the verbs-style
//! `rdmasim` layer, structured exactly as the paper's RDMA data plane
//! (§III-A): each endpoint pre-registers a receive ring of fixed-size
//! slots inside a pinned [`MemoryRegion`]; a send is one-sided
//! `RDMA_WRITE`s into the peer's ring plus one work completion per
//! chunk; the receiver blocks on its completion queue.
//!
//! # Framing
//!
//! A message occupies one or more ring slots. Every slot reserves its
//! first 8 bytes for an in-band header written with a *silent* write
//! (no completion); the header of a message's first chunk carries the
//! total payload length. Payload bytes start at slot offset 8, so a
//! slot carries up to `slot_bytes - 8` payload bytes and larger
//! messages are chunked across consecutive slots (wrapping the ring).
//!
//! # Flow control
//!
//! Slot reuse is governed by credits, the way real verbs applications
//! do it (e.g. HERD's RDMA-written counters): after consuming a chunk
//! the receiver RDMA-writes its cumulative consumed-chunk count into a
//! reserved credit cell at offset 0 of the *sender's* region. A sender
//! with `slots` unacknowledged chunks spins on its own credit cell
//! before touching the next slot, so a fast producer can never
//! overwrite unconsumed data.
//!
//! # GDR mode
//!
//! In GDR mode the registered ring stands for GPU device memory (the
//! paper's point: GDR makes device memory a first-class RDMA target).
//! `recv_msg` then returns a [`RecvMsg::Region`] view instead of
//! copying the payload to a host buffer — the credit for that slot is
//! withheld until the *next* receive call, so the view stays valid
//! while the executor stages it directly into the GPU (request-at-a-
//! time per connection, the paper's per-client buffer discipline).
//! Multi-slot messages always fall back to a host copy.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::rdmasim::qp::WR_ID_CLOSE;
use crate::rdmasim::{connect_pair, MemoryRegion, QueuePair, RegionSlice};

use super::{Acceptor, MsgTransport, RecvMsg, MAX_MSG};

/// Bytes reserved at the head of each region for the credit cell.
const RING_HDR: usize = 8;
/// Bytes reserved at the head of each slot for the in-band header.
const SLOT_HDR: usize = 8;

/// Receive-ring geometry, fixed at connection setup (the paper's
/// per-client pinned buffers, §III-A / §VII).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingCfg {
    /// Ring slots per direction (>= 2).
    pub slots: usize,
    /// Bytes per slot, including the 8-byte slot header.
    pub slot_bytes: usize,
}

impl Default for RingCfg {
    fn default() -> RingCfg {
        RingCfg {
            slots: 8,
            slot_bytes: 256 << 10,
        }
    }
}

impl RingCfg {
    /// A ring whose slots hold `payload` bytes in a single chunk (the
    /// zero-copy fast path requires single-chunk messages).
    pub fn for_payload(payload: usize) -> RingCfg {
        RingCfg {
            slots: 4,
            slot_bytes: payload + SLOT_HDR + 64,
        }
    }

    /// Payload bytes one ring slot carries (one chunk): messages up to
    /// this size are single-chunk (and zero-copy eligible in GDR mode);
    /// one byte more forces multi-chunk framing.
    pub fn chunk_capacity(&self) -> usize {
        self.slot_bytes - SLOT_HDR
    }

    fn region_len(&self) -> usize {
        RING_HDR + self.slots * self.slot_bytes
    }
}

/// One endpoint of a verbs-style connection.
pub struct RdmaTransport {
    qp: QueuePair,
    gdr: bool,
    slots: u64,
    slot_bytes: usize,
    /// Chunks posted to the peer's ring.
    sent_chunks: u64,
    /// Chunks consumed from our ring (published to the peer's view of
    /// our credit cell).
    recv_chunks: u64,
    /// A zero-copy slice is outstanding; its credit is returned at the
    /// next receive call.
    pending_credit: bool,
    /// When the last received message was complete in the ring, before
    /// any bounce copy out of it (trace-span base, §III-B WR stamps).
    last_boundary: Option<Instant>,
}

/// Create a connected pair with `cfg` rings per direction. `gdr`
/// selects the zero-copy receive path on both endpoints.
pub fn rdma_pair(cfg: RingCfg, gdr: bool) -> (RdmaTransport, RdmaTransport) {
    assert!(cfg.slots >= 2, "ring needs at least 2 slots");
    assert!(cfg.slot_bytes > SLOT_HDR, "slot too small for its header");
    let a_mr = std::sync::Arc::new(MemoryRegion::register(cfg.region_len()));
    let b_mr = std::sync::Arc::new(MemoryRegion::register(cfg.region_len()));
    // One completion per in-flight chunk (credit-bounded at `slots`)
    // plus headroom for the close sentinel.
    let (a_qp, b_qp) = connect_pair(a_mr, b_mr, cfg.slots + 2);
    let mk = |qp| RdmaTransport {
        qp,
        gdr,
        slots: cfg.slots as u64,
        slot_bytes: cfg.slot_bytes,
        sent_chunks: 0,
        recv_chunks: 0,
        pending_credit: false,
        last_boundary: None,
    };
    (mk(a_qp), mk(b_qp))
}

impl RdmaTransport {
    fn payload_capacity(&self) -> usize {
        self.slot_bytes - SLOT_HDR
    }

    /// Byte offset of slot `chunk_seq % slots` in a region.
    fn slot_off(&self, chunk_seq: u64) -> usize {
        RING_HDR + (chunk_seq % self.slots) as usize * self.slot_bytes
    }

    /// The peer's cumulative consumed count for chunks we sent (the
    /// peer RDMA-writes it into our region's credit cell).
    fn peer_consumed(&self) -> u64 {
        let b = self.qp.local_mr().read(0, 8);
        u64::from_le_bytes(b.try_into().expect("8-byte credit cell"))
    }

    /// Block until the next slot may be written (credit available).
    /// Surfaces a queued teardown sentinel promptly instead of spinning
    /// out the stall timeout against a peer that already hung up.
    fn wait_credit(&self) -> Result<()> {
        let mut spins = 0u64;
        let mut started: Option<Instant> = None;
        while self.sent_chunks - self.peer_consumed() >= self.slots {
            spins += 1;
            if spins < 256 {
                std::hint::spin_loop();
            } else {
                if self.qp.cq().contains(WR_ID_CLOSE) {
                    bail!("peer disconnected");
                }
                std::thread::sleep(Duration::from_micros(20));
                let t0 = *started.get_or_insert_with(Instant::now);
                if t0.elapsed() > Duration::from_secs(10) {
                    bail!("rdma ring stalled: no credit from peer for 10s");
                }
            }
        }
        Ok(())
    }

    /// Publish our consumed-chunk count into the peer's credit cell.
    fn bump_credit(&mut self) {
        self.recv_chunks += 1;
        let b = self.recv_chunks.to_le_bytes();
        // 8 bytes at offset 0 always fit; a failure is unreachable.
        let _ = self.qp.post_write_silent(&b, 0);
    }

    fn flush_pending_credit(&mut self) {
        if self.pending_credit {
            self.pending_credit = false;
            self.bump_credit();
        }
    }

    /// Next data completion, surfacing peer teardown as an error.
    fn next_chunk(&mut self) -> Result<crate::rdmasim::WorkCompletion> {
        let wc = self.qp.cq().poll_blocking();
        if wc.wr_id == WR_ID_CLOSE {
            bail!("peer disconnected");
        }
        Ok(wc)
    }

    /// Receive one message. `zero_copy` selects the GDR region view for
    /// single-chunk messages; host copies otherwise.
    fn recv_msg_impl(&mut self, zero_copy: bool) -> Result<RecvMsg> {
        self.flush_pending_credit();
        let wc = self.next_chunk()?;
        let slot = self.slot_off(wc.wr_id);
        let hdr = self.qp.local_mr().read(slot, SLOT_HDR);
        let total = u64::from_le_bytes(hdr.try_into().expect("8-byte slot header")) as usize;
        if total > MAX_MSG {
            bail!("oversized message: {total} bytes");
        }
        if total <= self.payload_capacity() {
            debug_assert_eq!(wc.byte_len, total, "single-chunk length mismatch");
            // Single chunk: the whole message is resident in the ring
            // right now — stamp the boundary before any bounce copy, so
            // the copy-out cost is visible to the trace (rdma pays it,
            // gdr does not).
            self.last_boundary = Some(Instant::now());
            if zero_copy && self.gdr {
                let slice =
                    RegionSlice::new(self.qp.local_mr().clone(), slot + SLOT_HDR, total);
                self.pending_credit = true;
                return Ok(RecvMsg::Region(slice));
            }
            let buf = self.qp.local_mr().read(slot + SLOT_HDR, total);
            self.bump_credit();
            return Ok(RecvMsg::Host(buf));
        }
        // Multi-chunk reassembly (always a host buffer).
        let mut buf = Vec::with_capacity(total);
        buf.extend_from_slice(&self.qp.local_mr().read(slot + SLOT_HDR, wc.byte_len));
        self.bump_credit();
        while buf.len() < total {
            let wc = self.next_chunk()?;
            let slot = self.slot_off(wc.wr_id);
            buf.extend_from_slice(&self.qp.local_mr().read(slot + SLOT_HDR, wc.byte_len));
            self.bump_credit();
        }
        debug_assert_eq!(buf.len(), total, "reassembled length mismatch");
        // Multi-chunk: the bounce copies interleave with the chunk
        // completions, so the earliest honest boundary is reassembly
        // completion (trace shows no separate bounce for chunked
        // messages; the experiment rings are sized to stay single-chunk).
        self.last_boundary = Some(Instant::now());
        Ok(RecvMsg::Host(buf))
    }
}

impl MsgTransport for RdmaTransport {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        if payload.len() > MAX_MSG {
            bail!("message too large: {} bytes", payload.len());
        }
        let cap = self.payload_capacity();
        let total = payload.len();
        let mut off = 0usize;
        let mut first = true;
        loop {
            self.wait_credit()?;
            let slot = self.slot_off(self.sent_chunks);
            if first {
                self.qp
                    .post_write_silent(&(total as u64).to_le_bytes(), slot)
                    .map_err(|e| anyhow!("post message header: {e}"))?;
            }
            let take = cap.min(total - off);
            self.qp
                .post_write(&payload[off..off + take], slot + SLOT_HDR, self.sent_chunks)
                .map_err(|e| anyhow!("post chunk: {e}"))?;
            self.sent_chunks += 1;
            off += take;
            first = false;
            if off >= total {
                return Ok(());
            }
        }
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        Ok(self.recv_msg_impl(false)?.into_vec())
    }

    fn recv_msg(&mut self) -> Result<RecvMsg> {
        self.recv_msg_impl(true)
    }

    fn recv_boundary(&self) -> Option<Instant> {
        self.last_boundary
    }

    fn kind(&self) -> &'static str {
        if self.gdr {
            "gdr"
        } else {
            "rdma"
        }
    }
}

/// Dialer half of an in-process RDMA "fabric": `connect` fabricates a
/// ring pair and hands the passive endpoint to the listener, the
/// loopback analogue of a QP connection handshake. Shareable across
/// threads (the sender is mutex-guarded so the connector is `Sync`
/// regardless of toolchain vintage).
pub struct RdmaConnector {
    tx: std::sync::Mutex<mpsc::Sender<RdmaTransport>>,
    cfg: RingCfg,
    gdr: bool,
}

impl Clone for RdmaConnector {
    fn clone(&self) -> RdmaConnector {
        RdmaConnector {
            tx: std::sync::Mutex::new(self.tx.lock().expect("connector poisoned").clone()),
            cfg: self.cfg,
            gdr: self.gdr,
        }
    }
}

impl RdmaConnector {
    pub fn connect(&self) -> Result<RdmaTransport> {
        let (active, passive) = rdma_pair(self.cfg, self.gdr);
        self.tx
            .lock()
            .expect("connector poisoned")
            .send(passive)
            .map_err(|_| anyhow!("rdma listener is gone"))?;
        Ok(active)
    }
}

/// Listener half: plug into `coordinator::serve_on`/`gateway_on`.
pub struct RdmaListener {
    rx: mpsc::Receiver<RdmaTransport>,
}

/// An in-process fabric endpoint pair (connector, listener).
pub fn rdma_fabric(cfg: RingCfg, gdr: bool) -> (RdmaConnector, RdmaListener) {
    let (tx, rx) = mpsc::channel();
    (
        RdmaConnector {
            tx: std::sync::Mutex::new(tx),
            cfg,
            gdr,
        },
        RdmaListener { rx },
    )
}

impl Acceptor for RdmaListener {
    type Conn = RdmaTransport;

    fn poll_accept(&mut self) -> Result<Option<RdmaTransport>> {
        match self.rx.recv_timeout(Duration::from_millis(2)) {
            Ok(conn) => Ok(Some(conn)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            // All connectors dropped: nothing more will arrive, but the
            // server owns shutdown via its stop flag.
            Err(mpsc::RecvTimeoutError::Disconnected) => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn small_ring() -> RingCfg {
        RingCfg {
            slots: 4,
            slot_bytes: 64 + SLOT_HDR,
        }
    }

    #[test]
    fn roundtrip_single_chunk() {
        let (mut c, mut s) = rdma_pair(RingCfg::default(), false);
        let server = thread::spawn(move || {
            for _ in 0..10 {
                let req = s.recv().unwrap();
                let resp: Vec<u8> = req.iter().map(|b| b ^ 0xFF).collect();
                s.send(&resp).unwrap();
            }
        });
        for i in 0..10usize {
            let msg = vec![i as u8; 100 * (i + 1)];
            c.send(&msg).unwrap();
            let back = c.recv().unwrap();
            assert_eq!(back.len(), msg.len());
            assert!(back.iter().all(|&b| b == (i as u8) ^ 0xFF));
        }
        server.join().unwrap();
    }

    #[test]
    fn chunked_message_wraps_ring() {
        // 1000-byte messages over 64-byte slots: 16 chunks across a
        // 4-slot ring, exercising credit-gated wraparound.
        let (mut c, mut s) = rdma_pair(small_ring(), false);
        let server = thread::spawn(move || {
            for _ in 0..5 {
                let req = s.recv().unwrap();
                s.send(&req).unwrap();
            }
        });
        for round in 0..5u8 {
            let msg: Vec<u8> = (0..1000).map(|i| (i as u8).wrapping_add(round)).collect();
            c.send(&msg).unwrap();
            assert_eq!(c.recv().unwrap(), msg);
        }
        server.join().unwrap();
    }

    #[test]
    fn gdr_recv_msg_is_region_view() {
        let (mut c, mut s) = rdma_pair(RingCfg::default(), true);
        c.send(b"on-device payload").unwrap();
        match s.recv_msg().unwrap() {
            RecvMsg::Region(slice) => {
                assert_eq!(slice.len(), 17);
                slice.with(|b| assert_eq!(b, b"on-device payload"));
            }
            RecvMsg::Host(_) => panic!("gdr single-chunk recv must be zero-copy"),
        }
        // Non-GDR endpoints always bounce to host.
        let (mut c2, mut s2) = rdma_pair(RingCfg::default(), false);
        c2.send(b"host payload").unwrap();
        assert!(matches!(s2.recv_msg().unwrap(), RecvMsg::Host(_)));
        drop(c);
    }

    #[test]
    fn gdr_region_valid_until_next_recv() {
        let (mut c, mut s) = rdma_pair(small_ring(), true);
        for _ in 0..3 {
            c.send(b"alpha").unwrap();
            c.send(b"beta!").unwrap();
            let first = match s.recv_msg().unwrap() {
                RecvMsg::Region(r) => r,
                RecvMsg::Host(_) => panic!("expected region"),
            };
            // The withheld credit keeps `first` stable while the second
            // message is already queued.
            assert_eq!(first.to_vec(), b"alpha");
            let second = s.recv_msg().unwrap().into_vec();
            assert_eq!(second, b"beta!");
        }
    }

    #[test]
    fn close_surfaces_on_recv() {
        let (c, mut s) = rdma_pair(RingCfg::default(), false);
        drop(c);
        assert!(s.recv().is_err());
    }

    #[test]
    fn fabric_connects_through_listener() {
        let (connector, mut listener) = rdma_fabric(RingCfg::default(), true);
        assert!(listener.poll_accept().unwrap().is_none());
        let mut active = connector.connect().unwrap();
        let mut passive = listener.poll_accept().unwrap().expect("pending conn");
        active.send(b"hi").unwrap();
        assert_eq!(passive.recv().unwrap(), b"hi");
        passive.send(b"yo").unwrap();
        assert_eq!(active.recv().unwrap(), b"yo");
        assert_eq!(active.kind(), "gdr");
    }

    #[test]
    fn recv_boundary_tracks_last_message() {
        let (mut c, mut s) = rdma_pair(RingCfg::default(), false);
        assert!(s.recv_boundary().is_none(), "no message received yet");
        c.send(b"one").unwrap();
        let before = Instant::now();
        s.recv().unwrap();
        let b1 = s.recv_boundary().expect("boundary after recv");
        assert!(b1 >= before && b1 <= Instant::now());
        c.send(b"two").unwrap();
        s.recv().unwrap();
        assert!(s.recv_boundary().unwrap() >= b1, "boundary must advance");
    }

    #[test]
    fn kind_reflects_mode() {
        let (c, _s) = rdma_pair(RingCfg::default(), true);
        assert_eq!(c.kind(), "gdr");
        let (r, _s) = rdma_pair(RingCfg::default(), false);
        assert_eq!(r.kind(), "rdma");
    }
}
