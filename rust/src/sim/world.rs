//! The serving-pipeline world: composes the fabric model, the GPU
//! simulator and closed-loop clients into the paper's model-serving
//! pipeline (Fig 3), for both direct and proxied connection modes.
//!
//! Pipeline per request (Fig 2/3):
//!
//! ```text
//!   client --(request hop[s])--> server
//!     [H2D copy]            (TCP/RDMA only)
//!     preprocessing          (raw-input mode only)
//!     inference
//!     [D2H copy]            (TCP/RDMA only)
//!   server --(response hop[s])--> client
//! ```
//!
//! Each stage duration is recorded exactly as the paper measures it:
//! by bracketing timestamps, so queueing (copy-engine queues, stream
//! slots, link serialization) lands in the stage where it occurred.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

use crate::coordinator::{BatchCfg, SealReason, N_SEAL_REASONS};
use crate::gpu::{CopyDir, GpuConfig, GpuEv, GpuNotify, GpuSim, JobSpec, KernelSpec, Sharing};
use crate::metrics::stats::{ReqRecord, StageAgg};
use crate::models::zoo::{PaperModel, KERNEL_GAP_US};
use crate::net::fabric::{Fabric, TransferKind};
use crate::net::params::{Transport, PROXY_PARAMS};
use crate::sim::rng::Rng;
use crate::sim::time::Ns;

/// One experiment configuration (§III-C experimental scenarios).
#[derive(Debug, Clone)]
pub struct Scenario {
    /// The served model (every client, unless `model_mix` is set).
    pub model: &'static PaperModel,
    /// Multi-model mix: when non-empty, client `c` serves
    /// `model_mix[c % model_mix.len()]` instead of `model` — the
    /// simulated twin of the live plane's continuous multi-model
    /// batching workload (`accelserve mixsweep`).
    pub model_mix: Vec<&'static PaperModel>,
    /// Gateway-to-server (or direct client-to-server) transport.
    pub transport: Transport,
    /// Proxied mode: the client-to-gateway hop transport. `None` = direct.
    pub client_hop: Option<Transport>,
    /// Closed-loop client count.
    pub n_clients: usize,
    /// Requests each client sends back-to-back.
    pub requests_per_client: usize,
    /// Clients submit raw camera frames (server preprocesses on GPU).
    pub raw_input: bool,
    /// GPU sharing mode (multi-stream / multi-context / MPS, Fig 17).
    pub sharing: Sharing,
    /// Stream/context pool size. 0 = one per client.
    pub n_streams: usize,
    /// Client 0 runs at high CUDA stream priority (Fig 16).
    pub priority_client: bool,
    /// Deterministic RNG seed (same seed → bit-identical run).
    pub seed: u64,
    /// Leading fraction of each client's requests dropped from stats.
    pub warmup_frac: f64,
    /// Which *live-plane* transport a runner should use when replaying
    /// this scenario against the real coordinator (`accelserve matrix
    /// --config`). The sim plane itself models `transport` above and
    /// ignores this knob.
    pub live_transport: Option<crate::transport::TransportKind>,
    /// Dynamic batching: largest batch a model lane may coalesce (1
    /// disables). Configures the live coordinator (`accelserve serve` /
    /// `batchsweep --config`) and, with [`Scenario::lanes`] on, the
    /// sim's lane model too; a lane-less sim run models per-request
    /// execution and ignores it.
    pub max_batch: usize,
    /// Flush deadline (µs): how long a batch head may wait for peers
    /// before the scheduler seals a partial batch (both planes, like
    /// `max_batch`).
    pub flush_us: u64,
    /// Per-model batching overrides (the scenario `model_batch` key):
    /// each model lane's policy and weighted-round-robin share in the
    /// continuous scheduler (both planes, like `max_batch`).
    pub model_batch: Vec<(String, crate::coordinator::ModelPolicy)>,
    /// Live-plane routing tier: how many coordinator backends sit
    /// behind the gateway (`accelserve shardsweep`). 1 = no sharding.
    /// Like the other live knobs, the sim plane ignores it.
    pub backends: usize,
    /// Live-plane placement policy for the routing tier; `None` uses
    /// the router's default (consistent hash).
    pub placement: Option<crate::coordinator::Placement>,
    /// Live-plane pipeline chain: stage models after `model` (the
    /// `FLAG_PIPELINE` request form run by the routing gateway). Empty
    /// = single-stage requests.
    pub pipeline: Vec<String>,
    /// Model the executor's per-model lanes in the sim plane: requests
    /// queue per model, gather into batches under `max_batch` /
    /// `flush_us` / `model_batch`, and sealed batches dispatch WRR+EDF
    /// onto the stream pool — filling the lane-queue / gather-wait /
    /// dispatch-wait stages the per-request pipeline leaves zero. Off
    /// by default, which keeps every lane-less run bit-identical to
    /// earlier sims. `Local` transport bypasses the lanes either way
    /// (the on-device lower bound has no scheduler in front of it).
    pub lanes: bool,
    /// Record per-request timelines ([`RunStats::timeline`]) and
    /// per-batch windows ([`RunStats::batches`]) for Chrome-trace
    /// export. Off by default (the vectors stay empty).
    pub trace: bool,
}

impl Scenario {
    /// Single-client direct-connection baseline for `model`/`transport`.
    pub fn direct(model: &'static PaperModel, transport: Transport) -> Scenario {
        Scenario {
            model,
            model_mix: Vec::new(),
            transport,
            client_hop: None,
            n_clients: 1,
            requests_per_client: 1000,
            raw_input: true,
            sharing: Sharing::MultiStream,
            n_streams: 0,
            priority_client: false,
            seed: 1,
            warmup_frac: 0.05,
            live_transport: None,
            max_batch: 1,
            flush_us: 0,
            model_batch: Vec::new(),
            backends: 1,
            placement: None,
            pipeline: Vec::new(),
            lanes: false,
            trace: false,
        }
    }

    /// Proxied mode: `client_hop` to the gateway, `server_hop` onwards.
    pub fn proxied(
        model: &'static PaperModel,
        client_hop: Transport,
        server_hop: Transport,
    ) -> Scenario {
        Scenario {
            client_hop: Some(client_hop),
            ..Scenario::direct(model, server_hop)
        }
    }

    /// Set the number of closed-loop clients.
    pub fn with_clients(mut self, n: usize) -> Scenario {
        self.n_clients = n;
        self
    }

    /// Set the per-client request count.
    pub fn with_requests(mut self, n: usize) -> Scenario {
        self.requests_per_client = n;
        self
    }

    /// Toggle raw (server-preprocessed) vs preprocessed inputs.
    pub fn with_raw(mut self, raw: bool) -> Scenario {
        self.raw_input = raw;
        self
    }

    /// Set the GPU sharing mode (Fig 17).
    pub fn with_sharing(mut self, s: Sharing) -> Scenario {
        self.sharing = s;
        self
    }

    /// Set the stream/context pool size (0 = one per client).
    pub fn with_streams(mut self, n: usize) -> Scenario {
        self.n_streams = n;
        self
    }

    /// Give client 0 high stream priority (Fig 16).
    pub fn with_priority_client(mut self, p: bool) -> Scenario {
        self.priority_client = p;
        self
    }

    /// Set the deterministic RNG seed.
    pub fn with_seed(mut self, s: u64) -> Scenario {
        self.seed = s;
        self
    }

    /// Batching policy (see `max_batch` / `flush_us`; modeled by the
    /// sim when [`Scenario::lanes`] is on, live-plane config otherwise).
    pub fn with_batching(mut self, max_batch: usize, flush_us: u64) -> Scenario {
        self.max_batch = max_batch.max(1);
        self.flush_us = flush_us;
        self
    }

    /// Turn on the sim-plane lane model (see [`Scenario::lanes`]).
    pub fn with_lanes(mut self) -> Scenario {
        self.lanes = true;
        self
    }

    /// Record timelines/batches for export (see [`Scenario::trace`]).
    pub fn with_trace(mut self) -> Scenario {
        self.trace = true;
        self
    }

    /// Multi-model workload: clients are assigned models round-robin
    /// from `models` (client `c` serves `models[c % models.len()]`).
    /// An empty list reverts to the single-model `model`.
    pub fn with_model_mix(mut self, models: Vec<&'static PaperModel>) -> Scenario {
        self.model_mix = models;
        self
    }

    /// The effective per-client model list: `model_mix` when set,
    /// otherwise the single `model`.
    pub fn mix(&self) -> Vec<&'static PaperModel> {
        if self.model_mix.is_empty() {
            vec![self.model]
        } else {
            self.model_mix.clone()
        }
    }

    fn effective_streams(&self) -> usize {
        if self.n_streams == 0 {
            self.n_clients
        } else {
            self.n_streams
        }
    }

    /// Do the two proxy hops require protocol translation at the gateway?
    /// (TCP <-> verbs are different wire protocols; RDMA->GDR is the same
    /// verbs protocol targeting different memory.)
    fn translated(&self) -> bool {
        match self.client_hop {
            None => false,
            Some(ch) => {
                let verbs =
                    |t: Transport| matches!(t, Transport::Rdma | Transport::Gdr);
                verbs(ch) != verbs(self.transport)
            }
        }
    }
}

/// Aggregated outcome of one scenario run.
#[derive(Debug, Default)]
pub struct RunStats {
    /// All measured requests.
    pub all: StageAgg,
    /// Only the high-priority client's requests (Fig 16).
    pub priority: StageAgg,
    /// Only normal clients' requests.
    pub normal: StageAgg,
    /// Makespan of the measured portion, seconds.
    pub duration_s: f64,
    /// Served requests/second across all clients.
    pub throughput_rps: f64,
    /// Execution-engine utilization in [0, 1].
    pub gpu_util: f64,
    /// Copy-engine busy seconds (both engines).
    pub copy_busy_s: f64,
    /// Events processed (simulator throughput metric for §Perf).
    pub events: u64,
    /// Per-model aggregates `(model name, stats)` — one entry per
    /// *distinct* model of [`Scenario::mix`], first-occurrence order
    /// (listing a model twice in the mix weights its traffic, it does
    /// not split its stats). For a single-model scenario this is one
    /// entry equal to `all`.
    pub per_model: Vec<(String, StageAgg)>,
    /// Inference completions whose model differed from the previous
    /// completion — the sim twin of the live executor's cross-model
    /// interleave counter (nonzero = models were served concurrently,
    /// not phase-by-phase).
    pub interleaves: u64,
    /// Per-lane scheduler counters, parallel to `per_model` (empty when
    /// [`Scenario::lanes`] is off) — the sim twin of the live
    /// executor's `LaneStats`.
    pub lane_stats: Vec<SimLaneStats>,
    /// Measured requests in completion order with their full stage
    /// records, for Chrome-trace export ([`Scenario::trace`] on).
    pub timeline: Vec<SimSpan>,
    /// Executed batches in completion order ([`Scenario::trace`] on):
    /// the gather/seal/dispatch windows behind the per-request stages.
    pub batches: Vec<SimBatch>,
}

/// One sim lane's counters: jobs executed, executable calls issued
/// (`jobs / calls` = mean achieved batch) and sealed-batch counts by
/// [`SealReason`]. The `Blocked`/`Slo` slots stay zero — the sim's
/// uniform-shape, SLO-less traffic never seals for those reasons.
#[derive(Debug, Clone)]
pub struct SimLaneStats {
    pub model: String,
    pub jobs: u64,
    pub calls: u64,
    pub sealed: [u64; N_SEAL_REASONS],
}

/// One measured request's placement on the sim clock plus its stage
/// record — everything the timeline exporter needs.
#[derive(Debug, Clone)]
pub struct SimSpan {
    pub client: usize,
    pub model: String,
    pub t_sent: Ns,
    pub rec: ReqRecord,
}

/// One executed batch: which lane/stream ran it, how many requests it
/// fused, and the scheduler window timestamps.
#[derive(Debug, Clone)]
pub struct SimBatch {
    pub model: String,
    pub stream: usize,
    pub size: usize,
    /// When the gather window over the batch head opened.
    pub gather_open: Ns,
    pub seal: Ns,
    pub dispatch: Ns,
    pub done: Ns,
    pub reason: SealReason,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Client issues its next request.
    Send { client: usize },
    /// Request arrived at the gateway (proxied mode).
    ReqAtGw { req: usize },
    /// Request fully arrived at the GPU server.
    ReqAtServer { req: usize },
    /// GPU-internal event.
    Gpu(GpuEv),
    /// Response arrived back at the gateway (proxied mode).
    RespAtGw { req: usize },
    /// Response arrived at the client: request complete.
    RespAtClient { req: usize },
    /// A lane's gather-window flush deadline expired (lane model only).
    /// Stale timers (the window already sealed) carry an old `epoch`
    /// and are ignored.
    LaneFlush { lane: usize, epoch: u64 },
}

#[derive(Debug, Clone, Copy, Default)]
struct Req {
    client: usize,
    measured: bool,
    t_sent: Ns,
    t_at_server: Ns,
    /// Lane-model stamps (all zero when [`Scenario::lanes`] is off):
    /// first gather consideration, batch seal, stream dispatch.
    t_gather: Ns,
    t_seal: Ns,
    t_dispatch: Ns,
    gathered: bool,
    t_h2d_done: Ns,
    t_preproc_done: Ns,
    t_infer_done: Ns,
    t_d2h_done: Ns,
    cpu_us: f64,
}

/// One simulated model lane (the sim twin of the live executor's lane):
/// a FIFO of waiting requests, an open gather window over the head
/// group, and a one-deep sealed slot — sealed work waits here for a
/// stream, which is exactly the live `dispatch-wait` stage.
struct SimLane {
    cfg: BatchCfg,
    weight: u32,
    credits: u32,
    q: VecDeque<usize>,
    /// When the current gather window opened (sealed slot empty only).
    window_open: Option<Ns>,
    /// The head's flush deadline (enqueue + `flush_us`), if any.
    window_deadline: Option<Ns>,
    /// Bumped at each seal; stale [`Ev::LaneFlush`] timers no-op.
    epoch: u64,
    sealed: Option<SealedBatch>,
    jobs: u64,
    calls: u64,
    sealed_counts: [u64; N_SEAL_REASONS],
}

/// A sealed batch parked in its lane, waiting for a free stream.
struct SealedBatch {
    members: Vec<usize>,
    reason: SealReason,
    /// The head's flush deadline, the EDF key once expired.
    deadline: Option<Ns>,
    gather_open: Ns,
    t_seal: Ns,
}

/// A dispatched batch executing on the GPU, keyed by its leader
/// request (the member whose id rides the GPU events).
struct InFlight {
    lane: usize,
    stream: usize,
    members: Vec<usize>,
    gather_open: Ns,
    t_seal: Ns,
    t_dispatch: Ns,
    reason: SealReason,
}

struct HeapEntry {
    t: Ns,
    seq: u64,
    ev: Ev,
}

impl PartialEq for HeapEntry {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (self.t, self.seq).cmp(&(o.t, o.seq))
    }
}

/// The discrete-event serving world. Construct with a `Scenario`, call
/// [`World::run`].
pub struct World {
    sc: Scenario,
    now: Ns,
    seq: u64,
    heap: BinaryHeap<Reverse<HeapEntry>>,
    rng: Rng,
    fabric: Fabric,
    gpu: GpuSim,
    reqs: Vec<Req>,
    sent_per_client: Vec<usize>,
    /// Per mix-position index into `models` ([`Scenario::mix`] with
    /// duplicates collapsed); client `c` serves position `c %
    /// mix_assign.len()`, so listing a model twice weights its traffic
    /// without splitting its stats.
    mix_assign: Vec<usize>,
    /// Distinct models of the mix, first-occurrence order.
    models: Vec<&'static PaperModel>,
    /// Shared per-model GPU job shapes, parallel to `models` (perf:
    /// one allocation per model total).
    job_specs: Vec<Arc<JobSpec>>,
    /// Model index of the last completed inference (cross-model
    /// interleave accounting).
    last_infer_model: Option<usize>,
    /// Per-model lanes, parallel to `models` (empty when the lane
    /// model is off).
    lanes: Vec<SimLane>,
    /// WRR cursor over `lanes` (stays on a lane until its credits run
    /// out, mirroring the live scheduler).
    wrr_cursor: usize,
    /// Free stream slots (lane model only; initialized in reverse so
    /// `pop()` hands out the lowest id first).
    free_streams: Vec<usize>,
    /// Executing batches by leader request id (lane model only).
    in_flight: HashMap<usize, InFlight>,
    /// Memoized batched job specs by (model index, batch size).
    batch_specs: HashMap<(usize, usize), Arc<JobSpec>>,
    stats: RunStats,
    events: u64,
}

impl World {
    /// Build the world for one scenario (call [`World::run`] to drive
    /// it; this seeds the RNG, the GPU model and the per-model specs).
    pub fn new(sc: Scenario) -> World {
        let gpu = GpuSim::new(
            GpuConfig::default(),
            sc.sharing,
            sc.effective_streams(),
            sc.seed,
        );
        // Collapse duplicate mix entries (a duplicated model weights
        // its traffic share) onto one stats/spec slot per model.
        let mut models: Vec<&'static PaperModel> = Vec::new();
        let mut mix_assign = Vec::new();
        for m in sc.mix() {
            let idx = models
                .iter()
                .position(|d| d.name == m.name)
                .unwrap_or_else(|| {
                    models.push(m);
                    models.len() - 1
                });
            mix_assign.push(idx);
        }
        let job_specs = models
            .iter()
            .map(|m| Arc::new(Self::build_job_spec(&sc, m)))
            .collect();
        let mut stats = RunStats::default();
        for m in &models {
            stats.per_model.push((m.name.to_string(), StageAgg::new()));
        }
        let lanes = if sc.lanes {
            models
                .iter()
                .map(|m| {
                    // Per-model policy override first, scenario default
                    // otherwise — same resolution as the live executor.
                    let (cfg, weight) = sc
                        .model_batch
                        .iter()
                        .find(|(name, _)| name == m.name)
                        .map(|(_, p)| (p.cfg, p.weight))
                        .unwrap_or((
                            BatchCfg {
                                max_batch: sc.max_batch.max(1),
                                flush_us: sc.flush_us,
                            },
                            1,
                        ));
                    SimLane {
                        cfg,
                        weight: weight.max(1),
                        credits: weight.max(1),
                        q: VecDeque::new(),
                        window_open: None,
                        window_deadline: None,
                        epoch: 0,
                        sealed: None,
                        jobs: 0,
                        calls: 0,
                        sealed_counts: [0; N_SEAL_REASONS],
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        let free_streams: Vec<usize> = (0..sc.effective_streams()).rev().collect();
        World {
            mix_assign,
            models,
            job_specs,
            last_infer_model: None,
            lanes,
            wrr_cursor: 0,
            free_streams,
            in_flight: HashMap::new(),
            batch_specs: HashMap::new(),
            rng: Rng::new(sc.seed),
            gpu,
            now: Ns::ZERO,
            seq: 0,
            heap: BinaryHeap::new(),
            fabric: Fabric::new(),
            reqs: Vec::new(),
            sent_per_client: vec![0; sc.n_clients],
            stats,
            events: 0,
            sc,
        }
    }

    /// Index into `models` (and `per_model` / `job_specs`) for
    /// `client`'s model.
    fn model_idx(&self, client: usize) -> usize {
        self.mix_assign[client % self.mix_assign.len()]
    }

    /// The model `req` runs (clients are pinned to one model each).
    fn model_of(&self, req: usize) -> &'static PaperModel {
        self.models[self.model_idx(self.reqs[req].client)]
    }

    /// Run the scenario to completion and aggregate the Table I metrics.
    pub fn run(sc: Scenario) -> RunStats {
        let mut w = World::new(sc);
        w.start();
        w.event_loop();
        w.finish()
    }

    fn start(&mut self) {
        for c in 0..self.sc.n_clients {
            // Small start stagger to desynchronize the closed loops.
            let jitter = Ns::from_us(self.rng.uniform(0.0, 200.0));
            self.push(jitter, Ev::Send { client: c });
        }
    }

    fn push(&mut self, t: Ns, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(HeapEntry {
            t,
            seq: self.seq,
            ev,
        }));
    }

    fn pump_gpu(&mut self) {
        for (t, ev) in self.gpu.drain() {
            self.push(t, Ev::Gpu(ev));
        }
    }

    fn event_loop(&mut self) {
        while let Some(Reverse(HeapEntry { t, ev, .. })) = self.heap.pop() {
            debug_assert!(t >= self.now, "causality violation");
            self.now = t;
            self.events += 1;
            self.handle(ev);
            self.pump_gpu();
        }
    }

    fn prio_of(&self, client: usize) -> i32 {
        if self.sc.priority_client && client == 0 {
            10
        } else {
            0
        }
    }

    fn build_job_spec(sc: &Scenario, m: &PaperModel) -> JobSpec {
        let mut kernels = Vec::new();
        let mut boundary = 0;
        if sc.raw_input {
            for _ in 0..m.preproc_kernels() {
                kernels.push(KernelSpec {
                    // Resize/normalize saturate the device (bandwidth-bound).
                    blocks: 20,
                    block_us: m.preproc_block_time_us(),
                });
            }
            boundary = kernels.len();
        }
        for _ in 0..m.n_kernels {
            kernels.push(KernelSpec {
                blocks: m.blocks_per_kernel(),
                block_us: m.block_time_us(),
            });
        }
        JobSpec {
            kernels,
            preproc_boundary: boundary,
            gap_us: KERNEL_GAP_US,
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Send { client } => self.on_send(client),
            Ev::ReqAtGw { req } => self.on_req_at_gw(req),
            Ev::ReqAtServer { req } => self.on_req_at_server(req),
            Ev::Gpu(gev) => {
                let notifies = self.gpu.handle(self.now, gev);
                for n in notifies {
                    self.on_gpu_notify(n);
                }
            }
            Ev::RespAtGw { req } => self.on_resp_at_gw(req),
            Ev::RespAtClient { req } => self.on_resp_at_client(req),
            Ev::LaneFlush { lane, epoch } => {
                if self.lanes[lane].epoch == epoch {
                    self.lane_service(lane);
                    self.lane_dispatch();
                }
            }
        }
    }

    fn on_send(&mut self, client: usize) {
        let idx = self.sent_per_client[client];
        if idx >= self.sc.requests_per_client {
            return; // this client is done
        }
        self.sent_per_client[client] = idx + 1;
        let warmup = (self.sc.requests_per_client as f64 * self.sc.warmup_frac) as usize;
        let req = self.reqs.len();
        self.reqs.push(Req {
            client,
            measured: idx >= warmup,
            t_sent: self.now,
            ..Default::default()
        });

        let m = self.models[self.model_idx(client)];
        let bytes = m.request_bytes(self.sc.raw_input);
        match (self.sc.transport, self.sc.client_hop) {
            (Transport::Local, _) => {
                // On-device: no transport, no copies (lower bound).
                self.reqs[req].t_at_server = self.now;
                self.reqs[req].t_h2d_done = self.now;
                let prio = self.prio_of(client);
                let spec = self.job_specs[self.model_idx(client)].clone();
                self.gpu.submit_job(self.now, req, prio, spec);
            }
            (_, None) => {
                // Direct connection: client -> server on the fabric.
                let p = self.sc.transport.params();
                let done =
                    self.fabric
                        .transfer(TransferKind::Request, bytes, p, self.now, &mut self.rng);
                self.reqs[req].cpu_us += 2.0 * p.cpu_us(bytes); // send + recv sides
                self.push(done, Ev::ReqAtServer { req });
            }
            (_, Some(ch)) => {
                // Proxied: first hop to the gateway.
                let p = ch.params();
                let done =
                    self.fabric
                        .transfer(TransferKind::ProxyIn, bytes, p, self.now, &mut self.rng);
                self.reqs[req].cpu_us += 2.0 * p.cpu_us(bytes);
                self.push(done, Ev::ReqAtGw { req });
            }
        }
    }

    fn on_req_at_gw(&mut self, req: usize) {
        // Gateway residence (forwarding decision + optional protocol
        // translation), then the gateway -> server hop.
        let m = self.model_of(req);
        let bytes = m.request_bytes(self.sc.raw_input);
        let res = PROXY_PARAMS.residence_us(bytes, self.sc.translated());
        self.reqs[req].cpu_us += res; // gateway CPU is busy for residence
        let t = self.now + Ns::from_us(res);
        let p = self.sc.transport.params();
        let done = self
            .fabric
            .transfer(TransferKind::Request, bytes, p, t, &mut self.rng);
        self.reqs[req].cpu_us += 2.0 * p.cpu_us(bytes);
        self.push(done, Ev::ReqAtServer { req });
    }

    fn on_req_at_server(&mut self, req: usize) {
        self.reqs[req].t_at_server = self.now;
        if self.sc.lanes {
            self.lane_enqueue(req);
            self.lane_dispatch();
            return;
        }
        let m = self.model_of(req);
        if self.sc.transport.needs_gpu_copies() {
            // Fig 2(a) steps 3: stage into GPU memory via the copy engine.
            let bytes = m.request_bytes(self.sc.raw_input);
            self.gpu.submit_copy(self.now, req, CopyDir::H2D, bytes);
            self.reqs[req].cpu_us += 5.0; // cudaMemcpyAsync issue
        } else {
            // GDR: payload already in GPU memory (Fig 2(b)).
            self.reqs[req].t_h2d_done = self.now;
            self.submit_job(req);
        }
    }

    fn submit_job(&mut self, req: usize) {
        let client = self.reqs[req].client;
        let prio = self.prio_of(client);
        let spec = self.job_specs[self.model_idx(client)].clone();
        self.gpu.submit_job(self.now, req, prio, spec);
    }

    // ------------------------------------------------------ lane model

    /// Queue `req` into its model's lane (priority requests queue ahead
    /// of normal ones, stable among peers — the live lane's
    /// priority-ordered insertion), then service the lane.
    fn lane_enqueue(&mut self, req: usize) {
        let lane = self.model_idx(self.reqs[req].client);
        let prio = self.prio_of(self.reqs[req].client);
        let pos = if prio > 0 {
            self.lanes[lane]
                .q
                .iter()
                .position(|&r| self.prio_of(self.reqs[r].client) < prio)
                .unwrap_or(self.lanes[lane].q.len())
        } else {
            self.lanes[lane].q.len()
        };
        self.lanes[lane].q.insert(pos, req);
        self.lane_service(lane);
    }

    /// Open/refresh the lane's gather window and seal when a seal
    /// condition holds — the sim twin of the live executor's
    /// `try_seal`. The window only forms while the sealed slot is
    /// empty (the scheduler considers one head group at a time), and
    /// the head's flush deadline counts from its *enqueue*, so a head
    /// that already waited out its flush seals on first consideration.
    fn lane_service(&mut self, lane: usize) {
        if self.lanes[lane].sealed.is_some() || self.lanes[lane].q.is_empty() {
            return;
        }
        let cap = self.lanes[lane].cfg.max_batch.max(1);
        let flush = self.lanes[lane].cfg.flush_us;
        if self.lanes[lane].window_open.is_none() {
            let head = self.lanes[lane].q[0];
            let deadline = if flush > 0 {
                Some(self.reqs[head].t_at_server + Ns::from_us(flush as f64))
            } else {
                None
            };
            self.lanes[lane].window_open = Some(self.now);
            self.lanes[lane].window_deadline = deadline;
            if let Some(d) = deadline {
                if d > self.now {
                    let epoch = self.lanes[lane].epoch;
                    self.push(d, Ev::LaneFlush { lane, epoch });
                }
            }
        }
        // Everything the head group would take is "in gather" now:
        // lane-queue ends (first consideration), gather-wait begins.
        let gathering: Vec<usize> = self.lanes[lane].q.iter().take(cap).copied().collect();
        for r in gathering {
            if !self.reqs[r].gathered {
                self.reqs[r].gathered = true;
                self.reqs[r].t_gather = self.now;
            }
        }
        let qlen = self.lanes[lane].q.len();
        let reason = if qlen >= cap {
            // Live taxonomy: a cap-1 policy seals "single" (unbatchable
            // head), a wider cap that filled seals "full".
            if cap == 1 {
                SealReason::Single
            } else {
                SealReason::Full
            }
        } else if flush == 0 {
            SealReason::Opportunistic
        } else if self.lanes[lane].window_deadline.is_some_and(|d| self.now >= d) {
            SealReason::Deadline
        } else {
            return; // the flush timer (or the next enqueue) re-checks
        };
        let take = cap.min(qlen);
        let mut members = Vec::with_capacity(take);
        for _ in 0..take {
            members.push(self.lanes[lane].q.pop_front().expect("take <= qlen"));
        }
        for &r in &members {
            self.reqs[r].t_seal = self.now;
        }
        let gather_open = self.lanes[lane].window_open.take().expect("window open");
        let deadline = self.lanes[lane].window_deadline.take();
        self.lanes[lane].sealed = Some(SealedBatch {
            members,
            reason,
            deadline,
            gather_open,
            t_seal: self.now,
        });
        self.lanes[lane].sealed_counts[reason as usize] += 1;
        self.lanes[lane].epoch += 1;
    }

    /// Pick the lane whose sealed batch dispatches next: EDF over
    /// sealed batches whose flush deadline already expired (late work
    /// drains earliest-deadline-first), then weighted round-robin with
    /// two credit passes — the live scheduler's pick order.
    fn pick_lane(&mut self) -> Option<usize> {
        let n = self.lanes.len();
        let edf = (0..n)
            .filter_map(|i| {
                self.lanes[i]
                    .sealed
                    .as_ref()
                    .and_then(|s| s.deadline)
                    .filter(|&d| self.now >= d)
                    .map(|d| (d, i))
            })
            .min();
        if let Some((_, i)) = edf {
            return Some(i);
        }
        for pass in 0..2 {
            for k in 0..n {
                let i = (self.wrr_cursor + k) % n;
                if self.lanes[i].sealed.is_some() && self.lanes[i].credits > 0 {
                    self.wrr_cursor = i;
                    return Some(i);
                }
            }
            if pass == 0 {
                for l in &mut self.lanes {
                    l.credits = l.weight.max(1);
                }
            }
        }
        None
    }

    /// Dispatch sealed batches onto free streams until one side runs
    /// out. Each dispatch immediately re-services its lane, so the
    /// next head group starts gathering (one-sealed-ahead, exactly the
    /// window the live `dispatch-wait` stage measures).
    fn lane_dispatch(&mut self) {
        while !self.free_streams.is_empty() {
            let Some(lane) = self.pick_lane() else { return };
            let stream = self.free_streams.pop().expect("checked non-empty");
            let sealed = self.lanes[lane].sealed.take().expect("picked lane sealed");
            let size = sealed.members.len();
            self.lanes[lane].jobs += size as u64;
            self.lanes[lane].calls += 1;
            // EDF picks of expired-deadline work ride free, like the
            // live scheduler's deadline lanes; WRR picks pay a credit.
            let expired = sealed.deadline.is_some_and(|d| self.now >= d);
            if !expired && self.lanes[lane].credits > 0 {
                self.lanes[lane].credits -= 1;
                if self.lanes[lane].credits == 0 {
                    self.wrr_cursor = (lane + 1) % self.lanes.len();
                }
            }
            for &r in &sealed.members {
                self.reqs[r].t_dispatch = self.now;
            }
            let leader = sealed.members[0];
            let members = sealed.members.clone();
            self.in_flight.insert(
                leader,
                InFlight {
                    lane,
                    stream,
                    members: sealed.members,
                    gather_open: sealed.gather_open,
                    t_seal: sealed.t_seal,
                    t_dispatch: self.now,
                    reason: sealed.reason,
                },
            );
            self.lane_service(lane);
            // Start the batch: one fused staging copy on copy
            // transports (batched rows move together), GDR goes
            // straight to compute.
            if self.sc.transport.needs_gpu_copies() {
                let bytes = self.model_of(leader).request_bytes(self.sc.raw_input) * size as u64;
                self.gpu.submit_copy(self.now, leader, CopyDir::H2D, bytes);
                self.reqs[leader].cpu_us += 5.0;
            } else {
                for &r in &members {
                    self.reqs[r].t_h2d_done = self.now;
                }
                self.submit_batch_job(leader);
            }
        }
    }

    /// Submit the fused GPU job for the batch led by `leader` (batch
    /// priority = highest member priority, like the live chunk).
    fn submit_batch_job(&mut self, leader: usize) {
        let (lane, members) = {
            let fl = &self.in_flight[&leader];
            (fl.lane, fl.members.clone())
        };
        let prio = members
            .iter()
            .map(|&r| self.prio_of(self.reqs[r].client))
            .max()
            .unwrap_or(0);
        let spec = self.batch_spec(lane, members.len());
        self.gpu.submit_job(self.now, leader, prio, spec);
    }

    /// Shared job spec for a `size`-batch of lane `lane`'s model:
    /// kernel block counts scale with the batch rows (the fused `_bN`
    /// executable's shape), memoized per (model, size).
    fn batch_spec(&mut self, lane: usize, size: usize) -> Arc<JobSpec> {
        if size == 1 {
            return self.job_specs[lane].clone();
        }
        if let Some(s) = self.batch_specs.get(&(lane, size)) {
            return s.clone();
        }
        let mut spec = Self::build_job_spec(&self.sc, self.models[lane]);
        for k in &mut spec.kernels {
            k.blocks *= size as u32;
        }
        let spec = Arc::new(spec);
        self.batch_specs.insert((lane, size), spec.clone());
        spec
    }

    /// A batch finished its last device stage: stamp every member,
    /// send the responses, record the batch, free the stream and let
    /// the scheduler run again.
    fn finish_batch(&mut self, leader: usize) {
        let fl = self.in_flight.remove(&leader).expect("batch in flight");
        for &r in &fl.members {
            self.reqs[r].t_d2h_done = self.now;
            self.send_response(r);
        }
        if self.sc.trace {
            self.stats.batches.push(SimBatch {
                model: self.models[fl.lane].name.to_string(),
                stream: fl.stream,
                size: fl.members.len(),
                gather_open: fl.gather_open,
                seal: fl.t_seal,
                dispatch: fl.t_dispatch,
                done: self.now,
                reason: fl.reason,
            });
        }
        self.free_streams.push(fl.stream);
        self.lane_dispatch();
    }

    fn on_gpu_notify(&mut self, n: GpuNotify) {
        match n {
            GpuNotify::CopyDone { req, dir: CopyDir::H2D } => {
                if self.in_flight.contains_key(&req) {
                    // Batch leader: the fused staging copy landed.
                    let members = self.in_flight[&req].members.clone();
                    for &r in &members {
                        self.reqs[r].t_h2d_done = self.now;
                    }
                    self.submit_batch_job(req);
                } else {
                    self.reqs[req].t_h2d_done = self.now;
                    self.submit_job(req);
                }
            }
            GpuNotify::PreprocDone { req } => {
                if self.in_flight.contains_key(&req) {
                    let members = self.in_flight[&req].members.clone();
                    for &r in &members {
                        self.reqs[r].t_preproc_done = self.now;
                    }
                } else {
                    self.reqs[req].t_preproc_done = self.now;
                }
            }
            GpuNotify::InferDone { req } => {
                if self.in_flight.contains_key(&req) {
                    let (lane, members) = {
                        let fl = &self.in_flight[&req];
                        (fl.lane, fl.members.clone())
                    };
                    // One interleave per executable call, like the live
                    // counter (lanes are parallel to models).
                    if self.last_infer_model.is_some_and(|last| last != lane) {
                        self.stats.interleaves += 1;
                    }
                    self.last_infer_model = Some(lane);
                    for &r in &members {
                        self.reqs[r].t_infer_done = self.now;
                        if !self.sc.raw_input {
                            self.reqs[r].t_preproc_done = self.reqs[r].t_h2d_done;
                        }
                    }
                    if self.sc.transport.needs_gpu_copies() {
                        let bytes = self.model_of(req).response_bytes() * members.len() as u64;
                        self.gpu.submit_copy(self.now, req, CopyDir::D2H, bytes);
                        self.reqs[req].cpu_us += 5.0;
                    } else {
                        self.finish_batch(req);
                    }
                    return;
                }
                self.reqs[req].t_infer_done = self.now;
                let midx = self.model_idx(self.reqs[req].client);
                if self.last_infer_model.is_some_and(|last| last != midx) {
                    self.stats.interleaves += 1;
                }
                self.last_infer_model = Some(midx);
                if !self.sc.raw_input {
                    self.reqs[req].t_preproc_done = self.reqs[req].t_h2d_done;
                }
                if self.sc.transport.needs_gpu_copies() {
                    let bytes = self.model_of(req).response_bytes();
                    self.gpu.submit_copy(self.now, req, CopyDir::D2H, bytes);
                    self.reqs[req].cpu_us += 5.0;
                } else {
                    self.reqs[req].t_d2h_done = self.now;
                    self.send_response(req);
                }
            }
            GpuNotify::CopyDone { req, dir: CopyDir::D2H } => {
                if self.in_flight.contains_key(&req) {
                    self.finish_batch(req);
                } else {
                    self.reqs[req].t_d2h_done = self.now;
                    self.send_response(req);
                }
            }
        }
    }

    fn send_response(&mut self, req: usize) {
        let bytes = self.model_of(req).response_bytes();
        if self.sc.transport == Transport::Local {
            self.push(self.now, Ev::RespAtClient { req });
            return;
        }
        let p = self.sc.transport.params();
        let done = self
            .fabric
            .transfer(TransferKind::Response, bytes, p, self.now, &mut self.rng);
        self.reqs[req].cpu_us += 2.0 * p.cpu_us(bytes);
        if self.sc.client_hop.is_some() {
            self.push(done, Ev::RespAtGw { req });
        } else {
            self.push(done, Ev::RespAtClient { req });
        }
    }

    fn on_resp_at_gw(&mut self, req: usize) {
        let bytes = self.sc.model.response_bytes();
        let res = PROXY_PARAMS.residence_us(bytes, self.sc.translated());
        self.reqs[req].cpu_us += res;
        let t = self.now + Ns::from_us(res);
        let ch = self.sc.client_hop.expect("resp at gw without proxy");
        let p = ch.params();
        let done = self
            .fabric
            .transfer(TransferKind::ProxyOut, bytes, p, t, &mut self.rng);
        self.reqs[req].cpu_us += 2.0 * p.cpu_us(bytes);
        self.push(done, Ev::RespAtClient { req });
    }

    fn on_resp_at_client(&mut self, req: usize) {
        let r = self.reqs[req];
        let total = self.now - r.t_sent;
        // Busy-poll / event-loop CPU while the request is outstanding
        // (client thread + server worker thread, §III-B cpu-usage).
        let poll_cpu = 0.9 * total.as_us();
        let rec = ReqRecord {
            client: r.client,
            total,
            request: r.t_at_server.saturating_sub(r.t_sent),
            response: self.now.saturating_sub(r.t_d2h_done),
            lane_queue: r.t_gather.saturating_sub(r.t_at_server),
            gather_wait: r.t_seal.saturating_sub(r.t_gather),
            dispatch_wait: r.t_dispatch.saturating_sub(r.t_seal),
            copy_h2d: r.t_h2d_done.saturating_sub(r.t_dispatch.max(r.t_at_server)),
            copy_d2h: r.t_d2h_done.saturating_sub(r.t_infer_done),
            preproc: r.t_preproc_done.saturating_sub(r.t_h2d_done),
            infer: if self.sc.raw_input {
                r.t_infer_done.saturating_sub(r.t_preproc_done)
            } else {
                r.t_infer_done.saturating_sub(r.t_h2d_done)
            },
            cpu_us: r.cpu_us + poll_cpu,
            priority: self.sc.priority_client && r.client == 0,
        };
        if r.measured {
            self.stats.all.push(&rec);
            let midx = self.model_idx(r.client);
            self.stats.per_model[midx].1.push(&rec);
            if rec.priority {
                self.stats.priority.push(&rec);
            } else {
                self.stats.normal.push(&rec);
            }
            if self.sc.trace {
                self.stats.timeline.push(SimSpan {
                    client: r.client,
                    model: self.models[midx].name.to_string(),
                    t_sent: r.t_sent,
                    rec,
                });
            }
        }
        // Closed loop: next request immediately.
        self.push(self.now, Ev::Send { client: r.client });
    }

    fn finish(mut self) -> RunStats {
        let dur = self.now.as_secs().max(1e-9);
        let served: usize = self.sent_per_client.iter().sum();
        self.stats.duration_s = dur;
        self.stats.throughput_rps = served as f64 / dur;
        self.stats.gpu_util = self.gpu.engine_busy_ns as f64
            / (self.now.0.max(1) as f64 * self.gpu.cfg.n_engines as f64);
        self.stats.copy_busy_s = self.gpu.copy_busy_ns() as f64 / 1e9;
        self.stats.events = self.events;
        for (lane, l) in self.lanes.iter().enumerate() {
            self.stats.lane_stats.push(SimLaneStats {
                model: self.models[lane].name.to_string(),
                jobs: l.jobs,
                calls: l.calls,
                sealed: l.sealed_counts,
            });
        }
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::PaperModel;

    fn model(name: &str) -> &'static PaperModel {
        PaperModel::by_name(name).unwrap()
    }

    fn quick(sc: Scenario) -> RunStats {
        World::run(sc.with_requests(120))
    }

    #[test]
    fn local_has_no_data_movement() {
        let s = quick(Scenario::direct(model("ResNet50"), Transport::Local));
        assert!(s.all.n() > 0);
        assert_eq!(s.all.request.mean(), 0.0);
        assert_eq!(s.all.response.mean(), 0.0);
        assert_eq!(s.all.copy_mean(), 0.0);
        assert!(s.all.infer.mean() > 0.0);
    }

    #[test]
    fn fig5_ordering_single_client() {
        // Paper Fig 5: Local < GDR < RDMA < TCP for ResNet50.
        let mut totals = Vec::new();
        for t in [Transport::Local, Transport::Gdr, Transport::Rdma, Transport::Tcp] {
            let s = quick(Scenario::direct(model("ResNet50"), t));
            totals.push((t.name(), s.all.total.mean()));
        }
        for w in totals.windows(2) {
            assert!(
                w[0].1 < w[1].1,
                "expected {} < {} but {:?}",
                w[0].0,
                w[1].0,
                totals
            );
        }
    }

    #[test]
    fn gdr_has_no_copies_rdma_does() {
        let g = quick(Scenario::direct(model("ResNet50"), Transport::Gdr));
        let r = quick(Scenario::direct(model("ResNet50"), Transport::Rdma));
        assert_eq!(g.all.copy_mean(), 0.0);
        assert!(r.all.copy_mean() > 0.0);
    }

    #[test]
    fn stage_sum_matches_total() {
        // Invariant: the stage decomposition covers the whole latency.
        for t in [Transport::Gdr, Transport::Rdma, Transport::Tcp] {
            let s = quick(Scenario::direct(model("MobileNetV3"), t));
            let sum = s.all.request.mean()
                + s.all.copy_mean()
                + s.all.preproc.mean()
                + s.all.infer.mean()
                + s.all.response.mean();
            let total = s.all.total.mean();
            assert!(
                (sum - total).abs() / total < 0.02,
                "{}: stages {sum} vs total {total}",
                t.name()
            );
        }
    }

    #[test]
    fn all_requests_complete() {
        let sc = Scenario::direct(model("MobileNetV3"), Transport::Rdma)
            .with_clients(4)
            .with_requests(50);
        let warmup = (50.0 * sc.warmup_frac) as usize;
        let s = World::run(sc);
        assert_eq!(s.all.n(), 4 * (50 - warmup));
    }

    #[test]
    fn copy_bottleneck_grows_with_clients() {
        // §V: the copy engine becomes the bottleneck with concurrency —
        // copy-time fraction must grow sharply for RDMA on DeepLabV3.
        let one = World::run(
            Scenario::direct(model("DeepLabV3_ResNet50"), Transport::Rdma).with_requests(40),
        );
        let many = World::run(
            Scenario::direct(model("DeepLabV3_ResNet50"), Transport::Rdma)
                .with_clients(16)
                .with_requests(40),
        );
        let f1 = one.all.copy_mean() / one.all.total.mean();
        let f16 = many.all.copy_mean() / many.all.total.mean();
        assert!(f16 > 2.0 * f1, "copy fraction {f1} -> {f16}");
    }

    #[test]
    fn proxied_slower_than_direct() {
        let d = quick(Scenario::direct(model("MobileNetV3"), Transport::Gdr));
        let p = quick(Scenario::proxied(
            model("MobileNetV3"),
            Transport::Rdma,
            Transport::Gdr,
        ));
        assert!(p.all.total.mean() > d.all.total.mean());
    }

    #[test]
    fn fig10_proxied_ordering() {
        // TCP/TCP must be the slowest proxied pair; RDMA/GDR the fastest.
        let pairs = [
            (Transport::Rdma, Transport::Gdr),
            (Transport::Tcp, Transport::Gdr),
            (Transport::Tcp, Transport::Tcp),
        ];
        let mut res = Vec::new();
        for (ch, sh) in pairs {
            let s = quick(Scenario::proxied(model("MobileNetV3"), ch, sh));
            res.push(s.all.total.mean());
        }
        assert!(res[0] < res[2], "RDMA/GDR {} !< TCP/TCP {}", res[0], res[2]);
        assert!(res[1] < res[2], "TCP/GDR {} !< TCP/TCP {}", res[1], res[2]);
    }

    #[test]
    fn priority_client_protected_under_gdr() {
        let s = World::run(
            Scenario::direct(model("YoloV4"), Transport::Gdr)
                .with_clients(8)
                .with_requests(40)
                .with_raw(false)
                .with_priority_client(true),
        );
        assert!(s.priority.n() > 0 && s.normal.n() > 0);
        assert!(
            s.priority.total.mean() < 0.5 * s.normal.total.mean(),
            "priority {} vs normal {}",
            s.priority.total.mean(),
            s.normal.total.mean()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = quick(Scenario::direct(model("ResNet50"), Transport::Tcp).with_seed(7));
        let b = quick(Scenario::direct(model("ResNet50"), Transport::Tcp).with_seed(7));
        assert_eq!(a.all.total.mean(), b.all.total.mean());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn model_mix_serves_both_models_concurrently() {
        // Two models, four clients each: every model collects its own
        // measured requests, the mix interleaves on the stream pool
        // (nonzero cross-model interleaves), and the heavier model's
        // per-model latency exceeds the lighter one's.
        let s = World::run(
            Scenario::direct(model("MobileNetV3"), Transport::Gdr)
                .with_model_mix(vec![model("MobileNetV3"), model("ResNet50")])
                .with_clients(8)
                .with_requests(40),
        );
        assert_eq!(s.per_model.len(), 2);
        let (m_name, m_agg) = &s.per_model[0];
        let (r_name, r_agg) = &s.per_model[1];
        assert_eq!(m_name, "MobileNetV3");
        assert_eq!(r_name, "ResNet50");
        assert!(m_agg.n() > 0 && r_agg.n() > 0);
        assert_eq!(m_agg.n() + r_agg.n(), s.all.n());
        assert!(
            r_agg.total.mean() > m_agg.total.mean(),
            "ResNet50 ({}) should be slower than MobileNetV3 ({})",
            r_agg.total.mean(),
            m_agg.total.mean()
        );
        assert!(s.interleaves > 0, "mixed models never interleaved");
    }

    #[test]
    fn duplicate_mix_entries_weight_traffic_without_splitting_stats() {
        // ["R", "R", "M"] weights ResNet50 2:1 — its stats land in ONE
        // entry (not two half-entries), and same-model back-to-back
        // completions do not count as interleaves.
        let s = World::run(
            Scenario::direct(model("ResNet50"), Transport::Gdr)
                .with_model_mix(vec![
                    model("ResNet50"),
                    model("ResNet50"),
                    model("MobileNetV3"),
                ])
                .with_clients(6)
                .with_requests(30),
        );
        assert_eq!(s.per_model.len(), 2, "duplicates must collapse");
        let (r_name, r_agg) = &s.per_model[0];
        let (m_name, m_agg) = &s.per_model[1];
        assert_eq!(r_name, "ResNet50");
        assert_eq!(m_name, "MobileNetV3");
        assert_eq!(r_agg.n() + m_agg.n(), s.all.n());
        // 4 of 6 clients serve ResNet50 under the 2:1 mix.
        assert_eq!(r_agg.n(), 2 * m_agg.n());
    }

    #[test]
    fn single_model_scenario_has_one_per_model_entry() {
        let s = quick(Scenario::direct(model("ResNet50"), Transport::Tcp));
        assert_eq!(s.per_model.len(), 1);
        assert_eq!(s.per_model[0].0, "ResNet50");
        assert_eq!(s.per_model[0].1.n(), s.all.n());
        assert_eq!(s.interleaves, 0, "one model cannot interleave");
    }

    #[test]
    fn gpu_util_bounded() {
        let s = World::run(
            Scenario::direct(model("WideResNet101"), Transport::Gdr)
                .with_clients(16)
                .with_requests(30),
        );
        assert!(s.gpu_util > 0.3, "util {}", s.gpu_util);
        assert!(s.gpu_util <= 1.01, "util {}", s.gpu_util);
    }

    #[test]
    fn lane_model_b1_noop_matches_classic_run() {
        // With max_batch 1, no flush window and ample streams the lane
        // model adds zero residence and consumes no extra randomness:
        // the run must be bit-identical to the lane-less pipeline.
        let base = Scenario::direct(model("ResNet50"), Transport::Tcp)
            .with_clients(3)
            .with_requests(60)
            .with_seed(9);
        let classic = World::run(base.clone());
        let laned = World::run(base.with_lanes());
        assert_eq!(classic.all.total.mean(), laned.all.total.mean());
        assert_eq!(classic.events, laned.events);
        assert_eq!(laned.all.lane_queue.mean(), 0.0);
        assert_eq!(laned.all.gather_wait.mean(), 0.0);
        assert_eq!(laned.all.dispatch_wait.mean(), 0.0);
        assert_eq!(laned.lane_stats.len(), 1);
        assert_eq!(laned.lane_stats[0].jobs, laned.lane_stats[0].calls);
    }

    #[test]
    fn lane_columns_fill_under_contention() {
        // Four clients share one stream under batch-1: requests wait in
        // the lane (queue) and sealed heads wait for the stream
        // (dispatch), and the nine stages still partition the total.
        let s = World::run(
            Scenario::direct(model("ResNet50"), Transport::Tcp)
                .with_clients(4)
                .with_streams(1)
                .with_requests(40)
                .with_lanes(),
        );
        assert!(s.all.lane_queue.mean() > 0.0, "no lane-queue residence");
        assert!(s.all.dispatch_wait.mean() > 0.0, "no dispatch residence");
        let sum = s.all.request.mean()
            + s.all.lane_queue.mean()
            + s.all.gather_wait.mean()
            + s.all.dispatch_wait.mean()
            + s.all.copy_mean()
            + s.all.preproc.mean()
            + s.all.infer.mean()
            + s.all.response.mean();
        let total = s.all.total.mean();
        assert!(
            (sum - total).abs() / total < 1e-6,
            "stages {sum} vs total {total}"
        );
    }

    #[test]
    fn lane_batches_gather_under_flush_policy() {
        // Four clients, one stream, batch-4 with a 2 ms flush window:
        // heads wait for peers (gather-wait), multi-request batches
        // execute (jobs > calls) and the trace records every batch.
        let s = World::run(
            Scenario::direct(model("ResNet50"), Transport::Tcp)
                .with_clients(4)
                .with_streams(1)
                .with_requests(40)
                .with_batching(4, 2000)
                .with_lanes()
                .with_trace(),
        );
        assert!(s.all.gather_wait.mean() > 0.0, "no gather residence");
        let l = &s.lane_stats[0];
        assert!(l.jobs > l.calls, "{} jobs / {} calls", l.jobs, l.calls);
        assert!(l.sealed[SealReason::Full as usize] > 0, "no full seals");
        assert_eq!(l.sealed.iter().sum::<u64>(), l.calls);
        assert_eq!(s.timeline.len(), s.all.n());
        let batched: u64 = s.batches.iter().map(|b| b.size as u64).sum();
        assert_eq!(batched, l.jobs);
        for b in &s.batches {
            assert!(b.gather_open <= b.seal && b.seal <= b.dispatch);
            assert!(b.dispatch <= b.done, "batch windows out of order");
        }
    }
}
