//! Discrete-event simulation core: deterministic time, PRNG, and the
//! serving-pipeline world that composes the GPU and fabric models.

pub mod rng;
pub mod time;
pub mod world;

pub use rng::Rng;
pub use time::Ns;
pub use world::{RunStats, Scenario, World};
