//! Simulation time: integer nanoseconds since scenario start.
//!
//! All simulator arithmetic is done on `Ns` (u64 nanoseconds) to keep the
//! event queue totally ordered and deterministic; conversion helpers to
//! f64 micro/milliseconds exist only at the metrics boundary.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in (or duration of) simulated time, in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Ns(pub u64);

impl Ns {
    pub const ZERO: Ns = Ns(0);

    #[inline]
    pub fn from_us(us: f64) -> Ns {
        debug_assert!(us >= 0.0, "negative duration: {us}");
        Ns((us * 1_000.0).round() as u64)
    }

    #[inline]
    pub fn from_ms(ms: f64) -> Ns {
        Ns::from_us(ms * 1_000.0)
    }

    #[inline]
    pub fn as_us(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    #[inline]
    pub fn as_ms(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    #[inline]
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1_000_000_000.0
    }

    /// Saturating subtraction (durations never go negative).
    #[inline]
    pub fn saturating_sub(self, rhs: Ns) -> Ns {
        Ns(self.0.saturating_sub(rhs.0))
    }

    #[inline]
    pub fn max(self, rhs: Ns) -> Ns {
        Ns(self.0.max(rhs.0))
    }
}

impl Add for Ns {
    type Output = Ns;
    #[inline]
    fn add(self, rhs: Ns) -> Ns {
        Ns(self.0 + rhs.0)
    }
}

impl AddAssign for Ns {
    #[inline]
    fn add_assign(&mut self, rhs: Ns) {
        self.0 += rhs.0;
    }
}

impl Sub for Ns {
    type Output = Ns;
    #[inline]
    fn sub(self, rhs: Ns) -> Ns {
        debug_assert!(self.0 >= rhs.0, "time went backwards: {self} - {rhs}");
        Ns(self.0 - rhs.0)
    }
}

impl fmt::Display for Ns {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}ms", self.as_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(Ns::from_us(1.5).0, 1_500);
        assert_eq!(Ns::from_ms(2.0).0, 2_000_000);
        assert!((Ns(2_500_000).as_ms() - 2.5).abs() < 1e-12);
        assert!((Ns(1_500).as_us() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn arithmetic() {
        assert_eq!(Ns(5) + Ns(7), Ns(12));
        assert_eq!(Ns(7) - Ns(5), Ns(2));
        assert_eq!(Ns(5).saturating_sub(Ns(7)), Ns::ZERO);
        assert_eq!(Ns(5).max(Ns(7)), Ns(7));
    }

    #[test]
    fn ordering_is_total() {
        let mut v = vec![Ns(3), Ns(1), Ns(2)];
        v.sort();
        assert_eq!(v, vec![Ns(1), Ns(2), Ns(3)]);
    }
}
