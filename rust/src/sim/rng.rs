//! Deterministic PRNG for the simulator (and the mini property-test
//! framework in `testutil`).
//!
//! SplitMix64: tiny, fast, passes BigCrush for our purposes, and keeps
//! every simulation run exactly reproducible from a seed — a requirement
//! for the figure benches (the paper's runs are averaged over 1000
//! closed-loop requests; ours must be re-runnable bit-for-bit).

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Multiplicative noise factor: lognormal-ish with coefficient of
    /// variation ~`cov`, clamped to stay positive and bounded. Mean ~1.
    pub fn noise(&mut self, cov: f64) -> f64 {
        if cov <= 0.0 {
            return 1.0;
        }
        (1.0 + cov * self.normal()).clamp(0.25, 4.0)
    }

    /// Fork an independent stream (for per-client RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn noise_is_positive_and_near_one() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let f = r.noise(0.1);
            assert!(f > 0.0 && f <= 4.0);
            sum += f;
        }
        assert!((sum / 10_000.0 - 1.0).abs() < 0.02);
        assert_eq!(r.noise(0.0), 1.0);
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(9);
        let mut b = a.fork();
        let mut c = a.fork();
        assert_ne!(b.next_u64(), c.next_u64());
    }
}
