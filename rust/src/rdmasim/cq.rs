//! Completion queues: where work completions (WC) land after the
//! "NIC" finishes a one-sided write. The paper's client blocks on WC
//! events for its request and the corresponding response (§III-A).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One work completion event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkCompletion {
    /// Caller-chosen work-request id (correlates request/response).
    pub wr_id: u64,
    /// Payload length of the completed write.
    pub byte_len: usize,
    /// Offset within the target MR that was written.
    pub offset: usize,
}

/// A multi-producer completion queue with blocking poll.
#[derive(Debug, Default)]
pub struct CompletionQueue {
    q: Mutex<VecDeque<WorkCompletion>>,
    cv: Condvar,
    capacity: usize,
}

impl CompletionQueue {
    pub fn with_capacity(capacity: usize) -> CompletionQueue {
        CompletionQueue {
            q: Mutex::new(VecDeque::with_capacity(capacity)),
            cv: Condvar::new(),
            capacity,
        }
    }

    /// Push a completion (the "NIC" side). Returns false when the CQ
    /// overflows — a fatal connection error on real hardware.
    pub fn push(&self, wc: WorkCompletion) -> bool {
        let mut q = self.q.lock().expect("cq poisoned");
        if self.capacity > 0 && q.len() >= self.capacity {
            return false;
        }
        q.push_back(wc);
        self.cv.notify_one();
        true
    }

    /// Non-blocking poll.
    pub fn poll(&self) -> Option<WorkCompletion> {
        self.q.lock().expect("cq poisoned").pop_front()
    }

    /// Blocking poll (busy clients in the paper block on WC events).
    pub fn poll_blocking(&self) -> WorkCompletion {
        let mut q = self.q.lock().expect("cq poisoned");
        loop {
            if let Some(wc) = q.pop_front() {
                return wc;
            }
            q = self.cv.wait(q).expect("cq poisoned");
        }
    }

    /// Blocking poll with timeout; None on expiry.
    pub fn poll_timeout(&self, dur: Duration) -> Option<WorkCompletion> {
        let deadline = std::time::Instant::now() + dur;
        let mut q = self.q.lock().expect("cq poisoned");
        loop {
            if let Some(wc) = q.pop_front() {
                return Some(wc);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self
                .cv
                .wait_timeout(q, deadline - now)
                .expect("cq poisoned");
            q = guard;
            if res.timed_out() && q.is_empty() {
                return None;
            }
        }
    }

    /// Scan for a queued completion with `wr_id` without consuming
    /// anything (e.g. spotting a teardown sentinel from a send path
    /// that must not steal the receive path's completions).
    pub fn contains(&self, wr_id: u64) -> bool {
        self.q
            .lock()
            .expect("cq poisoned")
            .iter()
            .any(|wc| wc.wr_id == wr_id)
    }

    pub fn len(&self) -> usize {
        self.q.lock().expect("cq poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn wc(id: u64) -> WorkCompletion {
        WorkCompletion {
            wr_id: id,
            byte_len: 0,
            offset: 0,
        }
    }

    #[test]
    fn fifo_order() {
        let cq = CompletionQueue::with_capacity(8);
        for i in 0..5 {
            assert!(cq.push(wc(i)));
        }
        for i in 0..5 {
            assert_eq!(cq.poll().unwrap().wr_id, i);
        }
        assert!(cq.poll().is_none());
    }

    #[test]
    fn capacity_overflow_detected() {
        let cq = CompletionQueue::with_capacity(2);
        assert!(cq.push(wc(1)));
        assert!(cq.push(wc(2)));
        assert!(!cq.push(wc(3)), "overflow must be reported");
        assert_eq!(cq.len(), 2);
    }

    #[test]
    fn blocking_poll_wakes_on_push() {
        let cq = Arc::new(CompletionQueue::with_capacity(4));
        let cq2 = cq.clone();
        let h = std::thread::spawn(move || cq2.poll_blocking().wr_id);
        std::thread::sleep(Duration::from_millis(20));
        cq.push(wc(99));
        assert_eq!(h.join().unwrap(), 99);
    }

    #[test]
    fn poll_timeout_expires() {
        let cq = CompletionQueue::with_capacity(4);
        let t0 = std::time::Instant::now();
        assert!(cq.poll_timeout(Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        cq.push(wc(1));
        assert_eq!(
            cq.poll_timeout(Duration::from_millis(30)).unwrap().wr_id,
            1
        );
    }
}
