//! A verbs-like RDMA software layer (live plane).
//!
//! The paper's client/server are written against the RDMA verbs model:
//! pre-registered pinned memory regions, queue pairs, one-sided
//! RDMA_WRITE work requests, and completion queues polled for work
//! completions (§III-A, ref [16]). Real RNICs don't exist in this
//! environment, so this module implements the *programming model* over
//! intra-host shared memory rings: the coordinator code is structured
//! exactly as the paper's C++ is, and the latency semantics (zero-copy
//! into a registered buffer + completion event; no per-byte CPU work on
//! the passive side) are preserved.
//!
//! ```text
//!   MemoryRegion    -- register(len) -> pinned buffer with an rkey
//!   QueuePair       -- connect two endpoints; post_write() moves bytes
//!                      into the remote MR and pushes a WC on both CQs
//!   CompletionQueue -- poll() / poll_blocking() for WCs
//! ```

pub mod cq;
pub mod mr;
pub mod qp;

pub use cq::{CompletionQueue, WorkCompletion};
pub use mr::{MemoryRegion, RegionSlice};
pub use qp::{connect_pair, QueuePair};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn end_to_end_write_and_completion() {
        // Client writes a request into the server's MR; server sees the
        // WC, writes a response back into the client's MR.
        let client_mr = Arc::new(MemoryRegion::register(1024));
        let server_mr = Arc::new(MemoryRegion::register(1024));
        let (cli, srv) = connect_pair(client_mr.clone(), server_mr.clone(), 16);

        let req = b"offload: classify frame 7";
        cli.post_write(req, 0, 0xCAFE).unwrap();
        let wc = srv.cq().poll_blocking();
        assert_eq!(wc.wr_id, 0xCAFE);
        assert_eq!(wc.byte_len, req.len());
        assert_eq!(&server_mr.read(0, req.len())[..], req);

        srv.post_write(b"label=42", 0, 0xBEEF).unwrap();
        let wc2 = cli.cq().poll_blocking();
        assert_eq!(wc2.wr_id, 0xBEEF);
        assert_eq!(&client_mr.read(0, 8)[..], b"label=42");
    }

    #[test]
    fn writes_respect_mr_bounds() {
        let a = Arc::new(MemoryRegion::register(64));
        let b = Arc::new(MemoryRegion::register(64));
        let (cli, _srv) = connect_pair(a, b, 4);
        assert!(cli.post_write(&[0u8; 65], 0, 1).is_err());
        assert!(cli.post_write(&[0u8; 32], 40, 2).is_err());
        assert!(cli.post_write(&[0u8; 32], 32, 3).is_ok());
    }

    #[test]
    fn completions_fifo_and_exactly_once() {
        let a = Arc::new(MemoryRegion::register(4096));
        let b = Arc::new(MemoryRegion::register(4096));
        let (cli, srv) = connect_pair(a, b, 64);
        for i in 0..50u64 {
            cli.post_write(&i.to_le_bytes(), (i as usize % 8) * 8, i).unwrap();
        }
        for i in 0..50u64 {
            let wc = srv.cq().poll_blocking();
            assert_eq!(wc.wr_id, i, "FIFO order violated");
        }
        assert!(srv.cq().poll().is_none(), "phantom completion");
    }

    #[test]
    fn cross_thread_request_response_loop() {
        let client_mr = Arc::new(MemoryRegion::register(256));
        let server_mr = Arc::new(MemoryRegion::register(256));
        let (cli, srv) = connect_pair(client_mr.clone(), server_mr.clone(), 32);

        let server = std::thread::spawn(move || {
            for _ in 0..100 {
                let wc = srv.cq().poll_blocking();
                let n = wc.byte_len;
                let data = srv.remote_mr().read(0, n);
                // "process" = increment every byte
                let resp: Vec<u8> = data.iter().map(|b| b.wrapping_add(1)).collect();
                srv.post_write(&resp, 0, wc.wr_id).unwrap();
            }
        });

        for i in 0..100u64 {
            let payload = [i as u8; 16];
            cli.post_write(&payload, 0, i).unwrap();
            let wc = cli.cq().poll_blocking();
            assert_eq!(wc.wr_id, i);
            let got = client_mr.read(0, 16);
            assert!(got.iter().all(|&b| b == (i as u8).wrapping_add(1)));
        }
        server.join().unwrap();
    }
}
