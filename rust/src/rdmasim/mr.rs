//! Registered memory regions: the pinned buffers RDMA peers expose.
//!
//! A region is a fixed-size byte buffer a remote QP may write into
//! ("RDMA target memory", §II-B). In GDR mode the same abstraction
//! stands for GPU device memory (the paper's point is precisely that
//! GDR makes device memory a first-class RDMA target).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

static NEXT_RKEY: AtomicU32 = AtomicU32::new(1);

/// A registered (conceptually pinned) memory region.
#[derive(Debug)]
pub struct MemoryRegion {
    buf: Mutex<Vec<u8>>,
    len: usize,
    rkey: u32,
}

impl MemoryRegion {
    /// Register a region of `len` bytes (zero-initialized).
    pub fn register(len: usize) -> MemoryRegion {
        MemoryRegion {
            buf: Mutex::new(vec![0u8; len]),
            len,
            rkey: NEXT_RKEY.fetch_add(1, Ordering::Relaxed),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The remote key peers use to address this region.
    pub fn rkey(&self) -> u32 {
        self.rkey
    }

    /// DMA write into the region. Errors on out-of-bounds access —
    /// mirroring an RNIC's protection-domain check.
    pub fn write(&self, offset: usize, data: &[u8]) -> Result<(), MrError> {
        if offset + data.len() > self.len {
            return Err(MrError::OutOfBounds {
                offset,
                len: data.len(),
                region: self.len,
            });
        }
        let mut buf = self.buf.lock().expect("mr poisoned");
        buf[offset..offset + data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Read a slice of the region (the local owner's view).
    pub fn read(&self, offset: usize, len: usize) -> Vec<u8> {
        let buf = self.buf.lock().expect("mr poisoned");
        buf[offset..offset + len].to_vec()
    }

    /// Run `f` over the region contents without copying out.
    pub fn with<R>(&self, offset: usize, len: usize, f: impl FnOnce(&[u8]) -> R) -> R {
        let buf = self.buf.lock().expect("mr poisoned");
        f(&buf[offset..offset + len])
    }
}

/// A borrowed window into a registered region: the zero-copy handle the
/// GDR receive path hands downstream (the payload stays in the
/// registered — conceptually device — memory; consumers read it in
/// place instead of bouncing it through a host buffer).
///
/// The underlying ring slot may be reused by the peer once the
/// transport has returned its flow-control credit, so a slice is only
/// valid until the next `recv` on the owning transport — the same
/// reuse discipline as the paper's per-client pinned buffers (§VII).
#[derive(Debug, Clone)]
pub struct RegionSlice {
    mr: Arc<MemoryRegion>,
    offset: usize,
    len: usize,
}

impl RegionSlice {
    /// Window `[offset, offset + len)` of `mr`. Panics when out of
    /// bounds — the transport computes offsets from its own ring math.
    pub fn new(mr: Arc<MemoryRegion>, offset: usize, len: usize) -> RegionSlice {
        assert!(offset + len <= mr.len(), "region slice out of bounds");
        RegionSlice { mr, offset, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Narrow the window (relative to this slice's start).
    pub fn sub(&self, offset: usize, len: usize) -> RegionSlice {
        assert!(offset + len <= self.len, "sub-slice out of bounds");
        RegionSlice {
            mr: self.mr.clone(),
            offset: self.offset + offset,
            len,
        }
    }

    /// Run `f` over the window without copying out.
    pub fn with<R>(&self, f: impl FnOnce(&[u8]) -> R) -> R {
        self.mr.with(self.offset, self.len, f)
    }

    /// Copy the window out to a host buffer (the bounce the GDR path
    /// exists to avoid; used by fallbacks and tests).
    pub fn to_vec(&self) -> Vec<u8> {
        self.mr.read(self.offset, self.len)
    }
}

/// MR access violations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MrError {
    OutOfBounds {
        offset: usize,
        len: usize,
        region: usize,
    },
}

impl std::fmt::Display for MrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MrError::OutOfBounds { offset, len, region } => write!(
                f,
                "RDMA access out of bounds: [{offset}, {}) beyond region {region}",
                offset + len
            ),
        }
    }
}

impl std::error::Error for MrError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rkeys_unique() {
        let a = MemoryRegion::register(8);
        let b = MemoryRegion::register(8);
        assert_ne!(a.rkey(), b.rkey());
    }

    #[test]
    fn write_read_roundtrip() {
        let mr = MemoryRegion::register(32);
        mr.write(4, b"hello").unwrap();
        assert_eq!(mr.read(4, 5), b"hello");
        mr.with(4, 5, |s| assert_eq!(s, b"hello"));
    }

    #[test]
    fn region_slice_windows() {
        let mr = Arc::new(MemoryRegion::register(64));
        mr.write(8, b"abcdefgh").unwrap();
        let s = RegionSlice::new(mr.clone(), 8, 8);
        assert_eq!(s.len(), 8);
        assert_eq!(s.to_vec(), b"abcdefgh");
        let inner = s.sub(2, 3);
        assert_eq!(inner.to_vec(), b"cde");
        inner.with(|b| assert_eq!(b, b"cde"));
        assert!(!s.is_empty());
        assert!(s.sub(8, 0).is_empty());
    }

    #[test]
    fn bounds_enforced() {
        let mr = MemoryRegion::register(8);
        assert!(mr.write(0, &[0; 9]).is_err());
        assert!(mr.write(8, &[0; 1]).is_err());
        assert!(mr.write(7, &[0; 1]).is_ok());
    }
}
