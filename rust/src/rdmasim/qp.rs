//! Queue pairs: connected endpoints supporting one-sided RDMA_WRITE.
//!
//! `connect_pair` mirrors the paper's connection setup (§III-A): both
//! sides allocate buffers (MRs), create send/receive queues and a CQ,
//! and exchange metadata. After that, `post_write` is the only data-path
//! operation — a zero-copy write into the peer's registered region plus
//! a completion on both CQs (send completion locally, write notification
//! remotely, as with RDMA_WRITE_WITH_IMM).

use std::sync::Arc;

use super::cq::{CompletionQueue, WorkCompletion};
use super::mr::{MemoryRegion, MrError};

/// Sentinel wr_id signalling a graceful disconnect (QP teardown event).
pub const WR_ID_CLOSE: u64 = u64::MAX;

/// One endpoint of a connected queue pair.
pub struct QueuePair {
    /// The peer's registered region this endpoint writes into.
    remote_mr: Arc<MemoryRegion>,
    /// Our own region the peer writes into (kept for convenience).
    local_mr: Arc<MemoryRegion>,
    /// Our completion queue (receives remote-write notifications).
    local_cq: Arc<CompletionQueue>,
    /// Peer's CQ (we push write notifications there).
    remote_cq: Arc<CompletionQueue>,
}

/// Create a connected pair of endpoints.
///
/// `a_mr` is endpoint A's local region (B writes into it), `b_mr` is
/// endpoint B's. `cq_depth` bounds both completion queues.
pub fn connect_pair(
    a_mr: Arc<MemoryRegion>,
    b_mr: Arc<MemoryRegion>,
    cq_depth: usize,
) -> (QueuePair, QueuePair) {
    let a_cq = Arc::new(CompletionQueue::with_capacity(cq_depth));
    let b_cq = Arc::new(CompletionQueue::with_capacity(cq_depth));
    let a = QueuePair {
        remote_mr: b_mr.clone(),
        local_mr: a_mr.clone(),
        local_cq: a_cq.clone(),
        remote_cq: b_cq.clone(),
    };
    let b = QueuePair {
        remote_mr: a_mr,
        local_mr: b_mr,
        local_cq: b_cq,
        remote_cq: a_cq,
    };
    (a, b)
}

/// QP errors.
#[derive(Debug)]
pub enum QpError {
    Mr(MrError),
    CqOverflow,
}

impl From<MrError> for QpError {
    fn from(e: MrError) -> Self {
        QpError::Mr(e)
    }
}

impl std::fmt::Display for QpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QpError::Mr(e) => write!(f, "{e}"),
            QpError::CqOverflow => write!(f, "completion queue overflow"),
        }
    }
}

impl std::error::Error for QpError {}

impl QueuePair {
    /// One-sided write of `data` into the peer's MR at `offset`;
    /// notifies the peer's CQ with `wr_id`.
    pub fn post_write(&self, data: &[u8], offset: usize, wr_id: u64) -> Result<(), QpError> {
        self.remote_mr.write(offset, data)?;
        let ok = self.remote_cq.push(WorkCompletion {
            wr_id,
            byte_len: data.len(),
            offset,
        });
        if ok {
            Ok(())
        } else {
            Err(QpError::CqOverflow)
        }
    }

    /// This endpoint's completion queue.
    pub fn cq(&self) -> &CompletionQueue {
        &self.local_cq
    }

    /// The region the peer writes into (our receive buffer).
    pub fn local_mr(&self) -> &Arc<MemoryRegion> {
        &self.local_mr
    }

    /// The peer's region (our write target). For a server endpoint this
    /// is where request payloads land from its own perspective — named
    /// from the writer's side.
    pub fn remote_mr(&self) -> &Arc<MemoryRegion> {
        &self.local_mr
    }

    /// The region this endpoint writes into on the peer.
    pub fn peer_mr(&self) -> &Arc<MemoryRegion> {
        &self.remote_mr
    }

    /// One-sided write with *no* completion (RDMA_WRITE without
    /// immediate): used for in-band headers so the peer wakes once per
    /// message instead of once per write.
    pub fn post_write_silent(&self, data: &[u8], offset: usize) -> Result<(), QpError> {
        self.remote_mr.write(offset, data)?;
        Ok(())
    }

    /// Signal a graceful disconnect to the peer (its next poll observes
    /// `WR_ID_CLOSE`, like a QP-error completion on teardown).
    pub fn post_close(&self) {
        let _ = self.remote_cq.push(WorkCompletion {
            wr_id: WR_ID_CLOSE,
            byte_len: 0,
            offset: 0,
        });
    }
}

impl Drop for QueuePair {
    fn drop(&mut self) {
        self.post_close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cq_overflow_propagates() {
        let a = Arc::new(MemoryRegion::register(64));
        let b = Arc::new(MemoryRegion::register(64));
        let (cli, _srv) = connect_pair(a, b, 2);
        assert!(cli.post_write(b"x", 0, 1).is_ok());
        assert!(cli.post_write(b"x", 0, 2).is_ok());
        assert!(matches!(
            cli.post_write(b"x", 0, 3),
            Err(QpError::CqOverflow)
        ));
    }

    #[test]
    fn both_directions_work() {
        let a = Arc::new(MemoryRegion::register(64));
        let b = Arc::new(MemoryRegion::register(64));
        let (qa, qb) = connect_pair(a.clone(), b.clone(), 8);
        qa.post_write(b"to-b", 0, 1).unwrap();
        qb.post_write(b"to-a", 0, 2).unwrap();
        assert_eq!(qb.cq().poll_blocking().wr_id, 1);
        assert_eq!(qa.cq().poll_blocking().wr_id, 2);
        assert_eq!(b.read(0, 4), b"to-b");
        assert_eq!(a.read(0, 4), b"to-a");
    }
}
