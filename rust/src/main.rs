//! accelserve CLI: the launcher for both planes.
//!
//! ```text
//! accelserve gen-artifacts --out-dir artifacts                   # offline AOT artifacts
//! accelserve serve   --addr 0.0.0.0:7007 --streams 4 --batch 8 --flush-us 2000 \
//!                    --model-batch tiny_resnet=8@2000            # per-model lane override
//! accelserve gateway --addr 0.0.0.0:7008 --upstream host:7007    # live proxy
//! accelserve client  --addr host:7007 --model tiny_resnet -n 100 -c 4 \
//!                    --deadline-us 5000 --timeout-ms 2000 --credits # SLO + hang guard + pacing
//! accelserve stats   --addr host:7007                            # per-lane executor counters
//! accelserve metrics --addr host:7007 [--watch 2] [--prom-out m.prom] # Prometheus exposition
//! accelserve matrix  --payload-kb 1024 --requests 160            # live transport matrix
//! accelserve batchsweep --clients 8 --policies 1,8,8@2000        # transport x batch policy
//! accelserve mixsweep --models tiny_mobilenet,tiny_resnet        # transport x model mix
//! accelserve stagebreak --policies 1,8@2000 [--pct 99] [--sim]   # per-stage span breakdown
//! accelserve traceexport --out trace.json [--sim]                # Chrome trace timeline (Perfetto)
//! accelserve slosweep --factors 1,2,4,8 [--deadline-us 5000]     # overload x SLO shedding
//! accelserve throttlesweep --factors 2,4,8                       # credit backpressure off vs on
//! accelserve gateway --addr :7008 --backend h1:7007 --backend h2:7007 \
//!                    --policy least-loaded                        # multi-backend routing tier
//! accelserve shardsweep --backends 1,2 --placements hash,least-loaded # scaling x placement
//! accelserve sim     --model ResNet50 --transport gdr -c 16 -n 300
//! accelserve fig     --which 5 [--requests 300] [--csv]          # regen a figure
//! accelserve tables  --which 2|3                                 # paper tables
//! ```

use std::sync::Arc;

use accelserve::coordinator::{
    fetch_metrics, fetch_stats, gateway_tcp, gateway_tcp_multi, run_tcp, serve_tcp, BatchCfg,
    Executor, LoadCfg, ModelPolicy, Placement, RouterCfg, SchedCfg, SEAL_REASON_NAMES,
    SHED_REASON_NAMES,
};
use accelserve::experiments::figs;
use accelserve::gpu::Sharing;
use accelserve::metrics::stats::Stat;
use accelserve::models::zoo::PaperModel;
use accelserve::net::params::Transport;
use accelserve::sim::world::{Scenario, World};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("gen-artifacts") => cmd_gen_artifacts(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("gateway") => cmd_gateway(&args[1..]),
        Some("client") => cmd_client(&args[1..]),
        Some("stats") => cmd_stats(&args[1..]),
        Some("metrics") => cmd_metrics(&args[1..]),
        Some("matrix") => cmd_matrix(&args[1..]),
        Some("batchsweep") => cmd_batchsweep(&args[1..]),
        Some("mixsweep") => cmd_mixsweep(&args[1..]),
        Some("stagebreak") => cmd_stagebreak(&args[1..]),
        Some("traceexport") => cmd_traceexport(&args[1..]),
        Some("slosweep") => cmd_slosweep(&args[1..]),
        Some("throttlesweep") => cmd_throttlesweep(&args[1..]),
        Some("shardsweep") => cmd_shardsweep(&args[1..]),
        Some("sim") => cmd_sim(&args[1..]),
        Some("fig") => cmd_fig(&args[1..]),
        Some("tables") => cmd_tables(&args[1..]),
        _ => {
            eprintln!("{}", HELP);
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "accelserve — model serving with hardware-accelerated communication
subcommands: gen-artifacts | serve | gateway | client | stats | metrics | matrix | batchsweep | mixsweep | stagebreak | traceexport | slosweep | throttlesweep | shardsweep | sim | fig | tables (see README.md and docs/EXPERIMENTS.md)";

/// Generate the serving artifacts (HLO text + manifest.json) offline —
/// no Python/JAX required (the rust twin of `make artifacts`).
fn cmd_gen_artifacts(a: &[String]) -> i32 {
    let dir = flag_or(a, "--out-dir", "artifacts");
    match accelserve::models::gen::write_artifacts(dir) {
        Ok(n) => {
            println!("wrote {n} artifacts + manifest.json to {dir}/");
            0
        }
        Err(e) => {
            eprintln!("gen-artifacts: {e:#}");
            1
        }
    }
}

/// Tiny flag parser: --key value pairs.
fn flag<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn flag_or<'a>(args: &'a [String], key: &str, default: &'a str) -> &'a str {
    flag(args, key).unwrap_or(default)
}

/// All values of a repeatable `--key value` flag, in order.
fn flags_all<'a>(args: &'a [String], key: &str) -> Vec<&'a str> {
    args.iter()
        .enumerate()
        .filter(|(_, a)| *a == key)
        .filter_map(|(i, _)| args.get(i + 1))
        .map(String::as_str)
        .collect()
}

/// Parse every `--model-batch model=SPEC` occurrence (shared by
/// `serve` and `mixsweep`).
fn parse_model_batch(args: &[String]) -> Result<Vec<(String, ModelPolicy)>, String> {
    let mut out = Vec::new();
    for spec in flags_all(args, "--model-batch") {
        match ModelPolicy::parse_entry(spec) {
            Some(e) => out.push(e),
            None => {
                return Err(format!(
                    "bad --model-batch {spec:?} (want model=N, model=N@FLUSH_US, \
                     optionally *WEIGHT — e.g. tiny_resnet=8@2000 or tiny_mobilenet=4*2)"
                ))
            }
        }
    }
    Ok(out)
}

/// Warn once per misconfigured lane whose policy sets a flush deadline
/// without a batch to gather (`max_batch` <= 1) — the executor would
/// otherwise silently run b1 while the operator believes deadline
/// batching is on. Shared by `mixsweep`'s default and per-model
/// policies (the `batchsweep --config` path has its own copy of the
/// default-policy case).
fn warn_unbatched_flush(cmd: &str, default: &BatchCfg, per_model: &[(String, ModelPolicy)]) {
    if default.flush_us > 0 && default.max_batch <= 1 {
        eprintln!(
            "{cmd}: default policy sets flush_us but not max_batch > 1 — \
             the flush deadline has nothing to batch; unlisted lanes run b1"
        );
    }
    let mut seen: Vec<&str> = Vec::new();
    for (model, p) in per_model {
        if seen.contains(&model.as_str()) {
            continue; // one warning per lane; first entry wins (policy_for)
        }
        seen.push(model);
        if p.cfg.flush_us > 0 && p.cfg.max_batch <= 1 {
            eprintln!(
                "{cmd}: lane {model} sets flush_us but not max_batch > 1 — \
                 the flush deadline has nothing to batch; this lane runs b1"
            );
        }
    }
}

/// Parse a comma-separated `--transports` list (shared by `matrix` and
/// `batchsweep`).
fn parse_transports(list: &str) -> Result<Vec<accelserve::transport::TransportKind>, String> {
    let mut kinds = Vec::new();
    for name in list.split(',') {
        match accelserve::transport::TransportKind::by_name(name) {
            Some(k) => kinds.push(k),
            None => return Err(format!("unknown transport {name} (tcp|shm|rdma|gdr)")),
        }
    }
    Ok(kinds)
}

/// Live transport matrix: per-stage latency over tcp/shm/rdma/gdr.
fn cmd_matrix(a: &[String]) -> i32 {
    let mut cfg = accelserve::experiments::MatrixCfg::default();
    // A scenario file sets the baseline workload (payload size from the
    // model's raw frame, transport from "live_transport"); explicit
    // flags below override it.
    if let Some(path) = flag(a, "--config") {
        match accelserve::config::load_scenario(path) {
            Ok(sc) => {
                cfg.payload_bytes = sc.model.request_bytes(sc.raw_input) as usize;
                if let Some(lt) = sc.live_transport {
                    cfg.transports = vec![lt];
                }
            }
            Err(e) => {
                eprintln!("config: {e:#}");
                return 2;
            }
        }
    }
    if let Some(kb) = flag(a, "--payload-kb").and_then(|v| v.parse::<usize>().ok()) {
        cfg.payload_bytes = kb.max(1) << 10;
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let csv = a.iter().any(|x| x == "--csv");
    let t = match accelserve::experiments::run_matrix(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("matrix: {e:#}");
            return 1;
        }
    };
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Transport × batch-policy sweep: the batching-vs-communication
/// tradeoff on the live stack (`accelserve batchsweep`).
fn cmd_batchsweep(a: &[String]) -> i32 {
    let mut cfg = accelserve::experiments::SweepCfg::default();
    // A scenario file sets the baseline (clients, requests, pinned
    // transport, batching policy); explicit flags below override it.
    if let Some(path) = flag(a, "--config") {
        match accelserve::config::load_scenario(path) {
            Ok(sc) => {
                cfg.clients = sc.n_clients;
                cfg.requests = sc.requests_per_client;
                cfg.warmup =
                    (sc.requests_per_client as f64 * sc.warmup_frac) as usize;
                if let Some(lt) = sc.live_transport {
                    cfg.transports = vec![lt];
                }
                // A config pins the policy axis: the scenario's policy
                // against the unbatched baseline when batching is on,
                // just the baseline when the scenario leaves it off
                // (max_batch defaults to 1) — never the default grid,
                // which would sweep policies the file didn't ask for.
                cfg.policies = if sc.max_batch > 1 {
                    vec![
                        BatchCfg::none(),
                        BatchCfg {
                            max_batch: sc.max_batch,
                            flush_us: sc.flush_us,
                        },
                    ]
                } else {
                    if sc.flush_us > 0 {
                        eprintln!(
                            "batchsweep: scenario sets flush_us but not max_batch > 1 — \
                             the flush deadline has nothing to batch; sweeping b1 only"
                        );
                    }
                    vec![BatchCfg::none()]
                };
            }
            Err(e) => {
                eprintln!("config: {e:#}");
                return 2;
            }
        }
    }
    if let Some(m) = flag(a, "--model") {
        cfg.model = m.to_string();
    }
    if let Some(n) = flag(a, "--clients").and_then(|v| v.parse::<usize>().ok()) {
        cfg.clients = n.max(1);
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
        cfg.streams = n.max(1);
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Some(list) = flag(a, "--policies") {
        let mut policies = Vec::new();
        for spec in list.split(',') {
            match BatchCfg::parse(spec) {
                Some(p) => policies.push(p),
                None => {
                    eprintln!(
                        "bad batch policy {spec:?} (want N, or N@FLUSH_US like 8@2000)"
                    );
                    return 2;
                }
            }
        }
        cfg.policies = policies;
    }
    let csv = a.iter().any(|x| x == "--csv");
    let t = match accelserve::experiments::run_batch_sweep(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("batchsweep: {e:#}");
            return 1;
        }
    };
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Transport × model-mix sweep: continuous multi-model batching on the
/// live stack, or the paper-scale simulated twin with `--sim`
/// (`accelserve mixsweep`).
fn cmd_mixsweep(a: &[String]) -> i32 {
    let csv = a.iter().any(|x| x == "--csv");
    if a.iter().any(|x| x == "--sim") {
        // Simulated twin: paper models over the modeled fabric. A
        // scenario file sets the baseline (its "model_mix", sim
        // "transport", clients, requests); explicit flags override it.
        let mut models: Vec<&'static PaperModel> = Vec::new();
        let mut transports: Vec<Transport> = vec![Transport::Tcp, Transport::Rdma, Transport::Gdr];
        let mut clients = 4usize;
        let mut requests = 200usize;
        let mut streams = 0usize;
        let mut policy = BatchCfg::none();
        let mut per_model: Vec<(String, ModelPolicy)> = Vec::new();
        if let Some(path) = flag(a, "--config") {
            match accelserve::config::load_scenario(path) {
                Ok(sc) => {
                    models = if sc.model_mix.is_empty() {
                        vec![sc.model]
                    } else {
                        sc.model_mix.clone()
                    };
                    transports = vec![sc.transport];
                    requests = sc.requests_per_client;
                    // The scenario's client count is the total across
                    // the mix; run_sim_mix takes clients per model.
                    clients = (sc.n_clients / models.len().max(1)).max(1);
                    policy = BatchCfg {
                        max_batch: sc.max_batch.max(1),
                        flush_us: sc.flush_us,
                    };
                    per_model = sc.model_batch.clone();
                }
                Err(e) => {
                    eprintln!("config: {e:#}");
                    return 2;
                }
            }
        }
        if let Some(names) = flag(a, "--models") {
            models.clear();
            for n in names.split(',') {
                match PaperModel::by_name(n) {
                    Some(m) => models.push(m),
                    None => {
                        eprintln!("unknown paper model {n}; see `accelserve tables --which 2`");
                        return 2;
                    }
                }
            }
        } else if models.is_empty() {
            models = vec![
                PaperModel::by_name("MobileNetV3").expect("zoo model"),
                PaperModel::by_name("ResNet50").expect("zoo model"),
            ];
        }
        if let Some(list) = flag(a, "--transports") {
            transports.clear();
            for n in list.split(',') {
                match Transport::by_name(n) {
                    Some(t) => transports.push(t),
                    None => {
                        eprintln!("unknown sim transport {n} (local|tcp|rdma|gdr)");
                        return 2;
                    }
                }
            }
        }
        if let Some(n) = flag(a, "--clients").and_then(|v| v.parse::<usize>().ok()) {
            clients = n.max(1);
        }
        if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
            requests = n.max(1);
        }
        // 0 streams = one per client (ample); smaller counts create the
        // contention that makes the lane model's batching visible.
        if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
            streams = n;
        }
        if let Some(spec) = flag(a, "--policy") {
            match BatchCfg::parse(spec) {
                Some(p) => policy = p,
                None => {
                    eprintln!("bad --policy {spec:?} (want N, or N@FLUSH_US like 8@2000)");
                    return 2;
                }
            }
        }
        match parse_model_batch(a) {
            Ok(pm) if pm.is_empty() => {}
            Ok(pm) => per_model = pm,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
        warn_unbatched_flush("mixsweep", &policy, &per_model);
        let trace_out = flag(a, "--trace-out").map(std::path::PathBuf::from);
        let t = match accelserve::experiments::run_sim_mix(
            &models,
            &transports,
            clients,
            requests,
            streams,
            policy,
            &per_model,
            trace_out.as_deref(),
        ) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("mixsweep: {e:#}");
                return 1;
            }
        };
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
        return 0;
    }
    let mut cfg = accelserve::experiments::MixCfg::default();
    // A scenario file sets the baseline (clients, requests, pinned
    // transport, per-model policies); explicit flags below override it.
    if let Some(path) = flag(a, "--config") {
        match accelserve::config::load_scenario(path) {
            Ok(sc) => {
                cfg.clients_per_model = sc.n_clients;
                cfg.requests = sc.requests_per_client;
                cfg.warmup = (sc.requests_per_client as f64 * sc.warmup_frac) as usize;
                if let Some(lt) = sc.live_transport {
                    cfg.transports = vec![lt];
                }
                // A config pins the default policy outright — including
                // "max_batch": 1 (b1, batching off) — like batchsweep
                // does; scenario defaults (max_batch 1, flush 0) mean a
                // file without batching keys runs unbatched lanes.
                cfg.policy = BatchCfg {
                    max_batch: sc.max_batch.max(1),
                    flush_us: sc.flush_us,
                };
                cfg.per_model = sc.model_batch.clone();
            }
            Err(e) => {
                eprintln!("config: {e:#}");
                return 2;
            }
        }
    }
    if let Some(list) = flag(a, "--models") {
        cfg.models = list.split(',').map(str::to_string).collect();
    }
    if let Some(n) = flag(a, "--clients").and_then(|v| v.parse::<usize>().ok()) {
        cfg.clients_per_model = n.max(1);
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
        cfg.streams = n.max(1);
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Some(spec) = flag(a, "--policy") {
        match BatchCfg::parse(spec) {
            Some(p) => cfg.policy = p,
            None => {
                eprintln!("bad --policy {spec:?} (want N, or N@FLUSH_US like 8@2000)");
                return 2;
            }
        }
    }
    match parse_model_batch(a) {
        Ok(per_model) if per_model.is_empty() => {}
        Ok(per_model) => cfg.per_model = per_model,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    }
    warn_unbatched_flush("mixsweep", &cfg.policy, &cfg.per_model);
    if let Some(p) = flag(a, "--trace-out") {
        cfg.trace_out = Some(p.into());
    }
    let t = match accelserve::experiments::run_mix_sweep(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("mixsweep: {e:#}");
            return 1;
        }
    };
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Per-stage latency breakdown from wire-carried span timelines, per
/// transport × batch policy — the live Table I / Fig 5–6 reproduction
/// (`accelserve stagebreak`), or the sim-plane twin with `--sim`.
fn cmd_stagebreak(a: &[String]) -> i32 {
    let csv = a.iter().any(|x| x == "--csv");
    let stat = match flag(a, "--pct") {
        None => Stat::Mean,
        Some(s) => match Stat::by_name(s) {
            Some(st) => st,
            None => {
                eprintln!("bad --pct {s:?} (want mean, 50/p50 or 99/p99)");
                return 2;
            }
        },
    };
    if a.iter().any(|x| x == "--sim") {
        // The sim twin runs the same lane model as the live executor
        // (--policies / --streams apply); only artifacts are live-only.
        if flag(a, "--artifacts").is_some() {
            eprintln!(
                "stagebreak: --artifacts is a live-plane knob — the sim twin \
                 generates no artifacts and ignores it"
            );
        }
        let model = flag_or(a, "--model", "MobileNetV3");
        let Some(model) = PaperModel::by_name(model) else {
            eprintln!("unknown paper model {model}; see `accelserve tables --which 2`");
            return 2;
        };
        let mut transports = vec![Transport::Tcp, Transport::Rdma, Transport::Gdr];
        if let Some(list) = flag(a, "--transports") {
            transports.clear();
            for n in list.split(',') {
                match Transport::by_name(n) {
                    Some(t) => transports.push(t),
                    None => {
                        eprintln!("unknown sim transport {n} (local|tcp|rdma|gdr)");
                        return 2;
                    }
                }
            }
        }
        let clients = flag(a, "--clients")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(2)
            .max(1);
        let requests = flag(a, "--requests")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(200)
            .max(1);
        // 0 streams = one per client (ample); smaller counts create the
        // contention that fills the queue/disp lane columns.
        let streams = flag(a, "--streams")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(0);
        let mut policies = vec![BatchCfg::none(), BatchCfg::deadline(8, 2000)];
        if let Some(list) = flag(a, "--policies") {
            policies.clear();
            for spec in list.split(',') {
                match BatchCfg::parse(spec) {
                    Some(p) => policies.push(p),
                    None => {
                        eprintln!("bad batch policy {spec:?} (want N, or N@FLUSH_US like 8@2000)");
                        return 2;
                    }
                }
            }
        }
        let trace_out = flag(a, "--trace-out").map(std::path::PathBuf::from);
        let t = match accelserve::experiments::run_sim_stage_break(
            model,
            &transports,
            &policies,
            clients,
            requests,
            streams,
            stat,
            trace_out.as_deref(),
        ) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("stagebreak: {e:#}");
                return 1;
            }
        };
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
        return 0;
    }
    let mut cfg = accelserve::experiments::StageBreakCfg {
        stat,
        ..Default::default()
    };
    if let Some(m) = flag(a, "--model") {
        cfg.model = m.to_string();
    }
    if let Some(n) = flag(a, "--clients").and_then(|v| v.parse::<usize>().ok()) {
        cfg.clients = n.max(1);
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
        cfg.streams = n.max(1);
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Some(list) = flag(a, "--policies") {
        let mut policies = Vec::new();
        for spec in list.split(',') {
            match BatchCfg::parse(spec) {
                Some(p) => policies.push(p),
                None => {
                    eprintln!("bad batch policy {spec:?} (want N, or N@FLUSH_US like 8@2000)");
                    return 2;
                }
            }
        }
        cfg.policies = policies;
    }
    if let Some(p) = flag(a, "--trace-out") {
        cfg.trace_out = Some(p.into());
    }
    let t = match accelserve::experiments::run_stage_break(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("stagebreak: {e:#}");
            return 1;
        }
    };
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Export a Chrome trace-event timeline to a file (`accelserve
/// traceexport`): a spans-on stagebreak run — live by default, the
/// simulated lane-model twin with `--sim` — whose per-request stage
/// timelines land in `--out` (default `trace.json`) instead of only
/// the summary table. Load the file in `ui.perfetto.dev` or
/// `chrome://tracing`; every stagebreak flag (`--model`, `--clients`,
/// `--requests`, `--transports`, `--policies`, `--streams`, `--pct`)
/// applies.
fn cmd_traceexport(a: &[String]) -> i32 {
    let mut args = a.to_vec();
    if flag(a, "--trace-out").is_none() {
        args.push("--trace-out".to_string());
        args.push(flag_or(a, "--out", "trace.json").to_string());
    }
    cmd_stagebreak(&args)
}

/// Overload × SLO sweep: drive the executor past service capacity with
/// deadline-carrying clients and report goodput, admitted-tail latency,
/// and the shed split per load factor (`accelserve slosweep`).
fn cmd_slosweep(a: &[String]) -> i32 {
    let mut cfg = accelserve::experiments::SloCfg::default();
    if let Some(m) = flag(a, "--model") {
        cfg.model = m.to_string();
    }
    if let Some(list) = flag(a, "--factors") {
        let mut factors = Vec::new();
        for spec in list.split(',') {
            match spec.parse::<f64>() {
                Ok(f) if f > 0.0 => factors.push(f),
                _ => {
                    eprintln!("bad --factors entry {spec:?} (want positive numbers like 1,2,4,8)");
                    return 2;
                }
            }
        }
        cfg.factors = factors;
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
        cfg.streams = n.max(1);
    }
    if let Some(us) = flag(a, "--deadline-us").and_then(|v| v.parse::<u64>().ok()) {
        cfg.deadline_us = Some(us.max(1));
    }
    if let Some(n) = flag(a, "--queue-cap").and_then(|v| v.parse::<usize>().ok()) {
        cfg.queue_cap = n.max(1);
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let t = match accelserve::experiments::run_slo_sweep(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("slosweep: {e:#}");
            return 1;
        }
    };
    if a.iter().any(|x| x == "--csv") {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Credit-backpressure sweep: each overload factor run with credits off
/// (admission control only) and on (clients pace on the server's
/// credit hints), reporting the shed and goodput delta per transport
/// (`accelserve throttlesweep`).
fn cmd_throttlesweep(a: &[String]) -> i32 {
    let mut cfg = accelserve::experiments::ThrottleCfg::default();
    if let Some(m) = flag(a, "--model") {
        cfg.model = m.to_string();
    }
    if let Some(list) = flag(a, "--factors") {
        let mut factors = Vec::new();
        for spec in list.split(',') {
            match spec.parse::<f64>() {
                Ok(f) if f > 0.0 => factors.push(f),
                _ => {
                    eprintln!("bad --factors entry {spec:?} (want positive numbers like 2,4,8)");
                    return 2;
                }
            }
        }
        cfg.factors = factors;
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
        cfg.streams = n.max(1);
    }
    if let Some(us) = flag(a, "--deadline-us").and_then(|v| v.parse::<u64>().ok()) {
        cfg.deadline_us = Some(us.max(1));
    }
    if let Some(n) = flag(a, "--queue-cap").and_then(|v| v.parse::<usize>().ok()) {
        cfg.queue_cap = n.max(1);
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let t = match accelserve::experiments::run_throttle_sweep(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("throttlesweep: {e:#}");
            return 1;
        }
    };
    if a.iter().any(|x| x == "--csv") {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Multi-backend sharding sweep: backend count × transport × placement
/// policy through the routing gateway, plus a 2-stage pipeline row
/// (`accelserve shardsweep`).
fn cmd_shardsweep(a: &[String]) -> i32 {
    let mut cfg = accelserve::experiments::ShardCfg::default();
    if let Some(list) = flag(a, "--backends") {
        let mut counts = Vec::new();
        for spec in list.split(',') {
            match spec.parse::<usize>() {
                Ok(n) if n > 0 => counts.push(n),
                _ => {
                    eprintln!("bad --backends entry {spec:?} (want positive counts like 1,2)");
                    return 2;
                }
            }
        }
        cfg.backends = counts;
    }
    if let Some(list) = flag(a, "--placements") {
        let mut placements = Vec::new();
        for spec in list.split(',') {
            match Placement::by_name(spec) {
                Some(p) => placements.push(p),
                None => {
                    eprintln!("bad --placements entry {spec:?} (want hash or least-loaded)");
                    return 2;
                }
            }
        }
        cfg.placements = placements;
    }
    if let Some(n) = flag(a, "--clients").and_then(|v| v.parse::<usize>().ok()) {
        cfg.clients = n.max(1);
    }
    if let Some(n) = flag(a, "--requests").and_then(|v| v.parse::<usize>().ok()) {
        cfg.requests = n.max(1);
        cfg.warmup = (n / 10).max(2);
    }
    if let Some(n) = flag(a, "--streams").and_then(|v| v.parse::<usize>().ok()) {
        cfg.streams = n.max(1);
    }
    if a.iter().any(|x| x == "--no-pipeline") {
        cfg.pipeline = false;
    }
    if let Some(dir) = flag(a, "--artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(p) = flag(a, "--trace-out") {
        cfg.trace_out = Some(p.into());
    }
    if let Some(list) = flag(a, "--transports") {
        match parse_transports(list) {
            Ok(kinds) => cfg.transports = kinds,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let t = match accelserve::experiments::run_shard_sweep(&cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("shardsweep: {e:#}");
            return 1;
        }
    };
    if a.iter().any(|x| x == "--csv") {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
    0
}

/// Query a running server's executor counters over the stats opcode
/// (`accelserve stats`): per-lane jobs / calls / mean service time /
/// queue depth / sealed reasons / shed reasons plus the cross-model
/// interleave count.
fn cmd_stats(a: &[String]) -> i32 {
    let addr = flag_or(a, "--addr", "127.0.0.1:7007");
    let sock: std::net::SocketAddr = match addr.parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bad addr {addr}: {e}");
            return 2;
        }
    };
    let timeout = flag(a, "--timeout-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(std::time::Duration::from_millis);
    let mut t = match accelserve::transport::tcp::TcpTransport::connect_timed(sock, timeout) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("connect {addr}: {e:#}");
            return 1;
        }
    };
    let mut stats = match fetch_stats(&mut t) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("stats: {e:#}");
            return 1;
        }
    };
    // Deterministic output regardless of lane creation order.
    stats.lanes.sort_by(|a, b| a.model.cmp(&b.model));
    // Best-effort enrichment from the telemetry plane: per-model
    // enqueue→done latency quantiles. A v1 server without OP_METRICS
    // answers with an error; render the table without the columns.
    let metrics = fetch_metrics(&mut t).ok();
    let mut cols: Vec<&str> = vec!["jobs", "calls", "avg_batch", "svc_ms", "depth"];
    if metrics.is_some() {
        cols.push("p50_ms");
        cols.push("p99_ms");
    }
    cols.extend(SEAL_REASON_NAMES);
    for name in SHED_REASON_NAMES {
        cols.push(match name {
            "queue_full" => "shed_cap",
            "deadline" => "shed_ddl",
            other => other,
        });
    }
    let mut table = accelserve::experiments::Table::new(
        format!("executor lanes @ {addr}"),
        &cols,
    );
    for lane in &stats.lanes {
        let mut vals = vec![
            lane.jobs as f64,
            lane.calls as f64,
            lane.jobs as f64 / (lane.calls.max(1)) as f64,
            lane.svc_ns as f64 / (lane.jobs.max(1)) as f64 / 1e6,
            lane.depth as f64,
        ];
        if let Some(m) = &metrics {
            let name =
                accelserve::metrics::telemetry::labeled("accel_exec_ns", "model", &lane.model);
            let (p50, p99) = match m.snap.histo(&name) {
                Some(h) => (
                    h.quantile(0.5) as f64 / 1e6,
                    h.quantile(0.99) as f64 / 1e6,
                ),
                None => (0.0, 0.0),
            };
            vals.push(p50);
            vals.push(p99);
        }
        vals.extend(lane.sealed.iter().map(|&s| s as f64));
        vals.extend(lane.shed.iter().map(|&s| s as f64));
        table.row(lane.model.clone(), vals);
    }
    table.note(format!(
        "interleaves (dispatches that switched model): {}",
        stats.interleaves
    ));
    table.note("sealed-reason columns count sealed batches per lane: single = unbatchable head, full = hit the policy cap, opportunistic = took what was queued, deadline = flush expired, blocked = incompatible work waited while a stream sat idle, slo = sealed early so the head's SLO deadline survives");
    table.note("shed columns count rejected submissions: shed_cap = lane queue at capacity, shed_ddl = deadline unwinnable at admission; svc_ms = mean per-job service time (the admission estimate)");
    if metrics.is_some() {
        table.note("p50_ms/p99_ms: enqueue→device-done latency quantiles from the telemetry histograms (bucket upper bounds, <=25% over)");
    }
    if a.iter().any(|x| x == "--csv") {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.render());
    }
    0
}

/// Scrape a running server (or gateway, which merges its fleet) over
/// the metrics opcode and render Prometheus text exposition
/// (`accelserve metrics`). `--watch SECS` re-scrapes in a loop;
/// `--prom-out FILE` writes the exposition to a file instead of
/// stdout (node_exporter textfile-collector style).
fn cmd_metrics(a: &[String]) -> i32 {
    let addr = flag_or(a, "--addr", "127.0.0.1:7007");
    let sock: std::net::SocketAddr = match addr.parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bad addr {addr}: {e}");
            return 2;
        }
    };
    let timeout = flag(a, "--timeout-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(std::time::Duration::from_millis);
    let watch: Option<u64> = flag(a, "--watch").and_then(|v| v.parse().ok());
    let prom_out = flag(a, "--prom-out");
    loop {
        let mut t = match accelserve::transport::tcp::TcpTransport::connect_timed(sock, timeout) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("connect {addr}: {e:#}");
                return 1;
            }
        };
        let report = match fetch_metrics(&mut t) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("metrics: {e:#}");
                return 1;
            }
        };
        let text = accelserve::metrics::expose::render(&report.snap);
        match prom_out {
            Some(path) => {
                if let Err(e) = std::fs::write(path, &text) {
                    eprintln!("write {path}: {e}");
                    return 1;
                }
                println!(
                    "wrote {} series ({} samples ringed) to {path}",
                    report.snap.counters.len()
                        + report.snap.gauges.len()
                        + report.snap.histos.len(),
                    report.ring.len()
                );
            }
            None => print!("{text}"),
        }
        match watch {
            Some(secs) => std::thread::sleep(std::time::Duration::from_secs(secs)),
            None => return 0,
        }
    }
}

fn cmd_serve(a: &[String]) -> i32 {
    let addr = flag_or(a, "--addr", "127.0.0.1:7007");
    if let Some(tr) = flag(a, "--transport") {
        match accelserve::transport::TransportKind::by_name(tr) {
            Some(accelserve::transport::TransportKind::Tcp) => {}
            Some(other) => {
                eprintln!(
                    "serve: {} is an intra-process transport; use `accelserve matrix \
                     --transports {}` to exercise it",
                    other.name(),
                    other.name()
                );
                return 2;
            }
            None => {
                eprintln!("unknown transport {tr} (tcp|shm|rdma|gdr)");
                return 2;
            }
        }
    }
    let streams: usize = flag_or(a, "--streams", "4").parse().unwrap_or(4);
    let batch: usize = flag_or(a, "--batch", "1").parse().unwrap_or(1).max(1);
    let flush_us: u64 = flag_or(a, "--flush-us", "0").parse().unwrap_or(0);
    let sample_ms: u64 = flag(a, "--sample-ms")
        .and_then(|v| v.parse().ok())
        .unwrap_or(accelserve::metrics::telemetry::DEFAULT_SAMPLE_MS);
    let dir = flag_or(a, "--artifacts", "artifacts");
    // Self-provision: serving should work out of the box, with no
    // Python AOT step required.
    match accelserve::models::gen::ensure_artifacts(dir) {
        Ok(0) => {}
        Ok(n) => println!("generated {n} artifacts into {dir}/"),
        Err(e) => {
            eprintln!("gen-artifacts into {dir}: {e:#}");
            return 1;
        }
    }
    let policy = BatchCfg {
        max_batch: batch,
        flush_us,
    };
    let per_model = match parse_model_batch(a) {
        Ok(pm) => pm,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let sched = SchedCfg {
        per_model: per_model.clone(),
        ..SchedCfg::uniform(policy)
    };
    let exec = match Executor::start_full(dir, streams, sched, &[], sample_ms) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!("executor: {e:#}");
            return 1;
        }
    };
    match serve_tcp(addr, exec) {
        Ok(h) => {
            let overrides = if per_model.is_empty() {
                String::new()
            } else {
                let specs: Vec<String> = per_model
                    .iter()
                    .map(|(m, p)| format!("{m}={}", p.label()))
                    .collect();
                format!(", overrides {}", specs.join(" "))
            };
            println!(
                "serving on {} ({streams} streams, batching {}{overrides})",
                h.addr,
                policy.label()
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("serve: {e:#}");
            1
        }
    }
}

fn cmd_gateway(a: &[String]) -> i32 {
    let addr = flag_or(a, "--addr", "127.0.0.1:7008");
    // Routing mode: one `--backend addr` per coordinator (repeatable).
    // Without any, fall back to the v1 single-upstream relay.
    let backend_flags = flags_all(a, "--backend");
    if backend_flags.is_empty() {
        let upstream = flag_or(a, "--upstream", "127.0.0.1:7007");
        let up: std::net::SocketAddr = match upstream.parse() {
            Ok(u) => u,
            Err(e) => {
                eprintln!("bad upstream {upstream}: {e}");
                return 2;
            }
        };
        return match gateway_tcp(addr, up) {
            Ok(h) => {
                println!("gateway on {} -> {up}", h.addr);
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
            Err(e) => {
                eprintln!("gateway: {e:#}");
                1
            }
        };
    }
    let mut backends = Vec::with_capacity(backend_flags.len());
    for b in &backend_flags {
        match b.parse::<std::net::SocketAddr>() {
            Ok(s) => backends.push(s),
            Err(e) => {
                eprintln!("bad backend {b}: {e}");
                return 2;
            }
        }
    }
    let policy = flag_or(a, "--policy", "hash");
    let Some(placement) = Placement::by_name(policy) else {
        eprintln!("bad --policy {policy} (want hash or least-loaded)");
        return 2;
    };
    let mut rcfg = RouterCfg {
        placement,
        ..RouterCfg::default()
    };
    if let Some(ms) = flag(a, "--refresh-ms").and_then(|v| v.parse::<u64>().ok()) {
        rcfg.refresh = std::time::Duration::from_millis(ms.max(1));
    }
    if let Some(d) = flag(a, "--saturation-depth").and_then(|v| v.parse::<u64>().ok()) {
        rcfg.saturation_depth = d;
    }
    match gateway_tcp_multi(addr, &backends, rcfg) {
        Ok(h) => {
            println!(
                "gateway on {} routing {} backend(s) via {}: {backends:?}",
                h.addr,
                backends.len(),
                placement.name()
            );
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("gateway: {e:#}");
            1
        }
    }
}

fn cmd_client(a: &[String]) -> i32 {
    let addr = flag_or(a, "--addr", "127.0.0.1:7007");
    let model = flag_or(a, "--model", "tiny_resnet").to_string();
    let raw = flag(a, "--raw").map(|v| v == "true").unwrap_or(false);
    let n: usize = flag_or(a, "-n", "100").parse().unwrap_or(100);
    let c: usize = flag_or(a, "-c", "1").parse().unwrap_or(1);
    let sock: std::net::SocketAddr = match addr.parse() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bad addr {addr}: {e}");
            return 2;
        }
    };
    let cfg = LoadCfg {
        model,
        raw,
        spans: false,
        n_clients: c,
        requests_per_client: n,
        priority_client: false,
        payload_elems: if raw { 64 * 64 * 3 } else { 32 * 32 * 3 },
        warmup: (n / 20).max(1),
        deadline_us: flag(a, "--deadline-us").and_then(|v| v.parse::<u64>().ok()),
        credits: a.iter().any(|x| x == "--credits"),
        timeout: flag(a, "--timeout-ms")
            .and_then(|v| v.parse::<u64>().ok())
            .map(std::time::Duration::from_millis),
        pipeline: flag(a, "--pipeline")
            .map(|v| v.split(',').map(str::to_string).collect())
            .unwrap_or_default(),
    };
    match run_tcp(sock, &cfg) {
        Ok(s) => {
            let lat = s.all.total.summary();
            println!(
                "requests={} throughput={:.1} rps  total p50={:.3} ms mean={:.3} ms  infer={:.3} ms  preproc={:.3} ms  net={:.3} ms{}",
                s.all.n(),
                s.throughput_rps,
                lat.p50,
                lat.mean,
                s.all.infer.mean(),
                s.all.preproc.mean(),
                s.all.request.mean() + s.all.response.mean(),
                if s.sheds > 0 || s.req_errors > 0 {
                    format!(
                        "  shed={} of {}  req_errors={}",
                        s.sheds,
                        s.sheds + s.served,
                        s.req_errors
                    )
                } else {
                    String::new()
                },
            );
            0
        }
        Err(e) => {
            eprintln!("client: {e:#}");
            1
        }
    }
}

fn cmd_sim(a: &[String]) -> i32 {
    if let Some(path) = flag(a, "--config") {
        return match accelserve::config::load_scenario(path) {
            Ok(sc) => {
                let s = World::run(sc);
                let (net, copy, proc) = s.all.fractions();
                println!(
                    "total={:.3} ms  net={:.1}% copy={:.1}% proc={:.1}%  thr={:.1} rps",
                    s.all.total.mean(),
                    net * 100.0,
                    copy * 100.0,
                    proc * 100.0,
                    s.throughput_rps
                );
                0
            }
            Err(e) => {
                eprintln!("config: {e:#}");
                2
            }
        };
    }
    let model = flag_or(a, "--model", "ResNet50");
    let Some(model) = PaperModel::by_name(model) else {
        eprintln!("unknown model {model}; see `accelserve tables --which 2`");
        return 2;
    };
    let Some(tr) = Transport::by_name(flag_or(a, "--transport", "gdr")) else {
        eprintln!("unknown transport (local|tcp|rdma|gdr)");
        return 2;
    };
    let c: usize = flag_or(a, "-c", "1").parse().unwrap_or(1);
    let n: usize = flag_or(a, "-n", "300").parse().unwrap_or(300);
    let sharing = match flag_or(a, "--sharing", "multi-stream") {
        "multi-context" => Sharing::MultiContext,
        "mps" => Sharing::Mps,
        _ => Sharing::MultiStream,
    };
    let mut sc = Scenario::direct(model, tr)
        .with_clients(c)
        .with_requests(n)
        .with_sharing(sharing)
        .with_raw(flag_or(a, "--raw", "true") == "true");
    if let Some(ch) = flag(a, "--client-hop").and_then(Transport::by_name) {
        sc.client_hop = Some(ch);
    }
    if let Some(streams) = flag(a, "--streams").and_then(|s| s.parse().ok()) {
        sc = sc.with_streams(streams);
    }
    let s = World::run(sc);
    let (net, copy, proc) = s.all.fractions();
    let lat = s.all.total.summary();
    println!(
        "{} over {} x{}: total={:.3} ms (p99={:.3})  net={:.1}% copy={:.1}% proc={:.1}%  thr={:.1} rps  gpu_util={:.2}",
        model.name,
        tr.name(),
        c,
        lat.mean,
        lat.p99,
        net * 100.0,
        copy * 100.0,
        proc * 100.0,
        s.throughput_rps,
        s.gpu_util,
    );
    0
}

fn cmd_fig(a: &[String]) -> i32 {
    let which = flag_or(a, "--which", "5");
    let n: usize = flag_or(a, "--requests", "300").parse().unwrap_or(300);
    let csv = a.iter().any(|x| x == "--csv");
    let tables = match which {
        "5" => vec![figs::fig5(n)],
        "6" => vec![figs::fig6(n)],
        "7" => vec![figs::fig7(n, true), figs::fig7(n, false)],
        "8" => vec![figs::fig8(n, true), figs::fig8(n, false)],
        "9" => vec![figs::fig9(n)],
        "10" => vec![figs::fig10(n)],
        "11" => vec![
            figs::fig11("MobileNetV3", n),
            figs::fig11("DeepLabV3_ResNet50", n / 3 + 1),
        ],
        "12" => vec![
            figs::fig12_13("MobileNetV3", Transport::Tcp, n),
            figs::fig12_13("MobileNetV3", Transport::Rdma, n),
            figs::fig12_13("MobileNetV3", Transport::Gdr, n),
        ],
        "13" => vec![
            figs::fig12_13("DeepLabV3_ResNet50", Transport::Tcp, n / 3 + 1),
            figs::fig12_13("DeepLabV3_ResNet50", Transport::Rdma, n / 3 + 1),
            figs::fig12_13("DeepLabV3_ResNet50", Transport::Gdr, n / 3 + 1),
        ],
        "14" => vec![figs::fig14(n / 2 + 1)],
        "15" => vec![figs::fig15a(n), figs::fig15b(n), figs::fig15c(n)],
        "16" => vec![figs::fig16(n / 2 + 1)],
        "17" => vec![figs::fig17(n)],
        _ => {
            eprintln!("--which must be 5..17");
            return 2;
        }
    };
    for t in tables {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.render());
        }
    }
    0
}

fn cmd_tables(a: &[String]) -> i32 {
    match flag_or(a, "--which", "2") {
        "2" => print!("{}", figs::table2().render()),
        "3" => print!("{}", figs::table3().render()),
        other => {
            eprintln!("no table {other} (2 or 3; Table I is metrics/mod.rs docs)");
            return 2;
        }
    }
    0
}
