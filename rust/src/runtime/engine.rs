//! The inference engine: one PJRT CPU client + one compiled executable
//! per artifact (the PJRT analogue of a TensorRT engine per profile).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::models::manifest::{ArtifactEntry, Manifest};
use crate::rdmasim::RegionSlice;

/// An input tensor for inference, carried as raw host bytes plus dtype
/// tag — the homogeneous raw-byte interchange RDMA requires (§VII).
///
/// `U8Region` is the GPUDirect variant: the bytes still live in the
/// transport's registered (device-staging) region (a [`RegionSlice`])
/// and are consumed in place, skipping the host bounce copy the `U8`
/// path implies.
#[derive(Debug, Clone)]
pub enum TensorBuf {
    F32(Vec<f32>),
    U8(Vec<u8>),
    U8Region(RegionSlice),
}

impl TensorBuf {
    pub fn len(&self) -> usize {
        match self {
            TensorBuf::F32(v) => v.len(),
            TensorBuf::U8(v) => v.len(),
            TensorBuf::U8Region(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        match self {
            TensorBuf::F32(v) => v.len() * 4,
            TensorBuf::U8(v) => v.len(),
            TensorBuf::U8Region(s) => s.len(),
        }
    }

    /// Dtype tag for diagnostics.
    pub fn dtype(&self) -> &'static str {
        match self {
            TensorBuf::F32(_) => "f32",
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => "u8",
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
}

/// How one [`Engine::infer_timed`] call split between input staging
/// (H2D analogue), compute, and output fetch (D2H analogue).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineTiming {
    /// Artifact lookup + literal build from the input bytes.
    pub h2d_ns: u64,
    /// The executable call.
    pub compute_ns: u64,
    /// Result fetch back to a host f32 vector.
    pub d2h_ns: u64,
}

impl EngineTiming {
    /// Whole engine-internal duration.
    pub fn total_ns(&self) -> u64 {
        self.h2d_ns + self.compute_ns + self.d2h_ns
    }
}

/// Loads artifacts once, compiles each HLO module once, then serves
/// inference calls. Interior mutability: the executable cache fills
/// lazily; PJRT execution itself is routed through a mutex because the
/// CPU client is a single "device" (the A2 analogue).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, &'static Compiled>>,
}

impl Engine {
    /// Create an engine over an artifact directory (with manifest.json).
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) one artifact. Compilation is done once per
    /// process; leaked intentionally — executables live for the process
    /// lifetime, exactly like preloaded TensorRT engines.
    fn get(&self, name: &str) -> Result<&'static Compiled> {
        if let Some(c) = self.compiled.lock().unwrap().get(name) {
            return Ok(c);
        }
        let entry = self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .clone();
        let path = self.manifest.hlo_path(&entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let boxed: &'static Compiled = Box::leak(Box::new(Compiled { exe, entry }));
        self.compiled.lock().unwrap().insert(name.to_string(), boxed);
        Ok(boxed)
    }

    /// Eagerly compile a set of artifacts (server warm-up).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` on `input`; returns the flat f32 output.
    /// For a batched `_bN` artifact, `input` is the row-major
    /// concatenation of the N per-request tensors and the output is the
    /// concatenation of the N per-request rows — each row bit-identical
    /// to running that request through the `_b1` artifact alone
    /// (asserted by `tests/batching.rs`).
    pub fn infer(&self, name: &str, input: &TensorBuf) -> Result<Vec<f32>> {
        self.infer_timed(name, input).map(|(out, _)| out)
    }

    /// [`Engine::infer`] plus the engine-internal stage timing: how the
    /// call split between staging the input (the live analogue of the
    /// H2D copy — literal build from host or region bytes), the compute
    /// itself, and fetching the output back (D2H). This is what the
    /// executor stamps into a request's trace span
    /// (`trace::Stamp::{H2dDone, InferDone, D2hDone}`).
    pub fn infer_timed(
        &self,
        name: &str,
        input: &TensorBuf,
    ) -> Result<(Vec<f32>, EngineTiming)> {
        let t0 = std::time::Instant::now();
        let c = self.get(name)?;
        let spec = &c.entry.inputs[0];
        if input.len() != spec.elems() {
            bail!(
                "{name}: input has {} elements, artifact expects {:?}",
                input.len(),
                spec.shape
            );
        }
        let dims: Vec<usize> = spec.shape.clone();
        let lit = match (input, spec.dtype.as_str()) {
            (TensorBuf::F32(v), "f32") => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &dims,
                    bytes,
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
            (TensorBuf::U8(v), "u8") => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                &dims,
                v,
            )
            .map_err(|e| anyhow!("literal: {e}"))?,
            // GDR path: materialize the literal straight from the
            // registered (device-staging) region — no host bounce
            // buffer between the transport and the runtime.
            (TensorBuf::U8Region(s), "u8") => s
                .with(|bytes| {
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::U8,
                        &dims,
                        bytes,
                    )
                })
                .map_err(|e| anyhow!("literal: {e}"))?,
            (got, want) => bail!(
                "{name}: dtype mismatch (got {}, want {want})",
                got.dtype()
            ),
        };
        let t_staged = std::time::Instant::now();
        let buffers = c
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let t_computed = std::time::Instant::now();
        let result = buffers[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        let out = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
        let t_fetched = std::time::Instant::now();
        Ok((
            out,
            EngineTiming {
                h2d_ns: (t_staged - t0).as_nanos() as u64,
                compute_ns: (t_computed - t_staged).as_nanos() as u64,
                d2h_ns: (t_fetched - t_computed).as_nanos() as u64,
            },
        ))
    }

    /// Output element count of an artifact (for buffer pre-allocation).
    pub fn output_elems(&self, name: &str) -> Result<usize> {
        Ok(self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .output
            .elems())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts are generated on demand (`models::gen`): these tests
    /// always run — a skip is a failure now.
    fn artifacts_dir() -> &'static std::path::Path {
        crate::models::gen::ensure_test_artifacts()
    }

    #[test]
    fn engine_loads_and_infers() {
        let eng = Engine::load(artifacts_dir()).unwrap();
        let plat = eng.platform().to_lowercase();
        assert!(plat == "host" || plat == "cpu", "platform {plat}");
        let n_in = eng.manifest().get("tiny_mobilenet_b1").unwrap().inputs[0].elems();
        let out = eng
            .infer("tiny_mobilenet_b1", &TensorBuf::F32(vec![0.1; n_in]))
            .unwrap();
        assert_eq!(out.len(), 1000);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn preprocess_then_classify_matches_fused_raw() {
        let eng = Engine::load(artifacts_dir()).unwrap();
        let raw = crate::models::zoo::WorkloadData::image(64 * 64 * 3, 9).bytes;
        let pre = eng.infer("preprocess", &TensorBuf::U8(raw.clone())).unwrap();
        let staged = eng
            .infer("tiny_mobilenet_b1", &TensorBuf::F32(pre))
            .unwrap();
        let fused = eng
            .infer("tiny_mobilenet_raw", &TensorBuf::U8(raw))
            .unwrap();
        for (a, b) in staged.iter().zip(&fused) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_equals_singles() {
        let eng = Engine::load(artifacts_dir()).unwrap();
        let n_in = 32 * 32 * 3;
        let a: Vec<f32> = (0..n_in).map(|i| (i % 17) as f32 / 17.0).collect();
        let b: Vec<f32> = (0..n_in).map(|i| (i % 29) as f32 / 29.0).collect();
        let mut batch = a.clone();
        batch.extend_from_slice(&b);
        let out2 = eng
            .infer("tiny_resnet_b2", &TensorBuf::F32(batch))
            .unwrap();
        let o_a = eng.infer("tiny_resnet_b1", &TensorBuf::F32(a)).unwrap();
        let o_b = eng.infer("tiny_resnet_b1", &TensorBuf::F32(b)).unwrap();
        for (x, y) in out2[..1000].iter().zip(&o_a) {
            assert!((x - y).abs() < 1e-3);
        }
        for (x, y) in out2[1000..].iter().zip(&o_b) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn infer_timed_matches_untimed() {
        let eng = Engine::load(artifacts_dir()).unwrap();
        let n_in = eng.manifest().get("tiny_mobilenet_b1").unwrap().inputs[0].elems();
        let input = TensorBuf::F32(vec![0.25; n_in]);
        let (out, tm) = eng.infer_timed("tiny_mobilenet_b1", &input).unwrap();
        assert_eq!(out, eng.infer("tiny_mobilenet_b1", &input).unwrap());
        assert!(tm.compute_ns > 0, "compute took no time: {tm:?}");
        assert_eq!(tm.total_ns(), tm.h2d_ns + tm.compute_ns + tm.d2h_ns);
    }

    #[test]
    fn rejects_bad_inputs() {
        let eng = Engine::load(artifacts_dir()).unwrap();
        assert!(eng.infer("no_such_model", &TensorBuf::F32(vec![0.0])).is_err());
        assert!(eng
            .infer("tiny_mobilenet_b1", &TensorBuf::F32(vec![0.0; 3]))
            .is_err());
        assert!(eng
            .infer("tiny_mobilenet_b1", &TensorBuf::U8(vec![0; 32 * 32 * 3]))
            .is_err());
    }
}
