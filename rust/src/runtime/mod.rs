//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the rust request path. Python never runs here.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and DESIGN.md §3).
//! In this offline build the `xla` dependency is the vendored pure-Rust
//! HLO interpreter (`rust/vendor/xla`), so execution is real either way.

pub mod engine;

pub use engine::{Engine, EngineTiming, TensorBuf};
