//! Shared-link fabric state: serializes concurrent transfers on each
//! 25 GbE direction so that many-client, large-payload workloads (e.g.
//! DeepLabV3's 45 MB responses) contend for wire bandwidth like they do
//! on the paper's testbed.

use crate::net::params::TransportParams;
use crate::sim::rng::Rng;
use crate::sim::time::Ns;

/// Line rate of the facility fabric (Table III: ConnectX-5 25 GbE).
pub const LINE_RATE_GBPS: f64 = 25.0;

/// Direction / hop of a transfer, used to pick the serialized link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Client (or gateway) -> GPU server.
    Request,
    /// GPU server -> client (or gateway).
    Response,
    /// Client -> gateway (proxied mode, first hop).
    ProxyIn,
    /// Gateway -> client (proxied mode, return hop).
    ProxyOut,
}

impl TransferKind {
    fn index(self) -> usize {
        match self {
            TransferKind::Request => 0,
            TransferKind::Response => 1,
            TransferKind::ProxyIn => 2,
            TransferKind::ProxyOut => 3,
        }
    }
}

/// FIFO wire occupancy per link direction + per-message latency sampling.
#[derive(Debug, Clone)]
pub struct Fabric {
    busy_until: [Ns; 4],
}

impl Default for Fabric {
    fn default() -> Self {
        Fabric {
            busy_until: [Ns::ZERO; 4],
        }
    }
}

impl Fabric {
    pub fn new() -> Fabric {
        Fabric::default()
    }

    /// Wire occupancy of `bytes` at line rate.
    pub fn occupancy(bytes: u64) -> Ns {
        Ns::from_us(bytes as f64 * 8.0 / LINE_RATE_GBPS / 1_000.0)
    }

    /// Begin a transfer at `now`; returns its completion time.
    ///
    /// The payload occupies the link serially at line rate (FIFO among
    /// concurrent senders); the message additionally pays the transport's
    /// per-message latency (stack/WR fixed cost + sub-line-rate latency
    /// bandwidth + jitter), of which the occupancy is a lower bound.
    pub fn transfer(
        &mut self,
        kind: TransferKind,
        bytes: u64,
        params: &TransportParams,
        now: Ns,
        rng: &mut Rng,
    ) -> Ns {
        let idx = kind.index();
        let start = now.max(self.busy_until[idx]);
        self.busy_until[idx] = start + Self::occupancy(bytes);
        let hop = params.sample_hop(bytes, rng).max(Self::occupancy(bytes));
        start + hop
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::params::{GDR_PARAMS, TCP_PARAMS};

    #[test]
    fn occupancy_at_line_rate() {
        // 25 Gbit/s => 1 MB takes 320 us on the wire.
        let t = Fabric::occupancy(1_000_000);
        assert!((t.as_us() - 320.0).abs() < 1.0, "{t}");
    }

    #[test]
    fn concurrent_transfers_serialize() {
        let mut f = Fabric::new();
        let mut rng = Rng::new(1);
        let a = f.transfer(TransferKind::Response, 10_000_000, &GDR_PARAMS, Ns::ZERO, &mut rng);
        let b = f.transfer(TransferKind::Response, 10_000_000, &GDR_PARAMS, Ns::ZERO, &mut rng);
        // Second transfer starts only after the first's wire occupancy.
        assert!(b.as_us() > a.as_us() * 1.5, "a={a} b={b}");
    }

    #[test]
    fn directions_independent() {
        let mut f = Fabric::new();
        let mut rng = Rng::new(2);
        let _ = f.transfer(TransferKind::Request, 50_000_000, &TCP_PARAMS, Ns::ZERO, &mut rng);
        let b = f.transfer(TransferKind::Response, 1_000, &TCP_PARAMS, Ns::ZERO, &mut rng);
        // A huge request transfer must not delay the response link.
        assert!(b.as_us() < 1_000.0, "{b}");
    }

    #[test]
    fn hop_never_faster_than_wire() {
        let mut f = Fabric::new();
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let done = f.transfer(
                TransferKind::ProxyIn,
                2_000_000,
                &GDR_PARAMS,
                Ns::ZERO,
                &mut rng,
            );
            assert!(done >= Fabric::occupancy(2_000_000));
        }
    }
}
