//! Transport mechanisms and their calibrated fabric parameters.
//!
//! The facility fabric is 25 GbE with ConnectX-5 RNICs (Table III). Per
//! transport we model the **per-message latency path** an offloaded
//! request experiences:
//!
//! * TCP (ZeroMQ): sender memcpy into the socket, kernel protocol stack,
//!   wire, receiver stack + delivery. CPU does per-byte work on both
//!   sides; per-message *latency* bandwidth is far below link line rate
//!   (single closed-loop message, no pipelining) and jittery.
//! * RDMA: WR posted to the RNIC; NIC DMAs payload host-RAM-to-host-RAM.
//!   Near-line-rate, microsecond fixed cost, very low jitter. Server
//!   still needs H2D/D2H copies through the GPU copy engines.
//! * GDR: identical wire behaviour to RDMA but the RNIC DMAs directly
//!   into/out of GPU memory: the copy-engine stages disappear.
//!
//! Values are calibrated against the paper's own single-client deltas
//! (§IV-A: TCP sends raw/preproc 0.73/0.61 ms slower than GDR; GDR adds
//! 0.27–0.53 ms over local) — see EXPERIMENTS.md §Calibration.

use crate::sim::rng::Rng;
use crate::sim::time::Ns;

/// Transport mechanism for one hop (Experimental Scenarios, §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Transport {
    /// On-device processing: no data movement at all (lower bound).
    Local,
    /// TCP-based ZeroMQ transport (no serialization, Router-Dealer).
    Tcp,
    /// RDMA_WRITE into host RAM; GPU copies via copy engines.
    Rdma,
    /// GPUDirect RDMA: RNIC DMA straight to/from GPU memory.
    Gdr,
}

impl Transport {
    pub fn name(self) -> &'static str {
        match self {
            Transport::Local => "Local",
            Transport::Tcp => "TCP",
            Transport::Rdma => "RDMA",
            Transport::Gdr => "GDR",
        }
    }

    /// Does the server need H2D/D2H staging copies through the GPU copy
    /// engines for this transport? (Fig 2a vs 2b.)
    pub fn needs_gpu_copies(self) -> bool {
        matches!(self, Transport::Tcp | Transport::Rdma)
    }

    pub fn params(self) -> &'static TransportParams {
        match self {
            Transport::Local => &LOCAL_PARAMS,
            Transport::Tcp => &TCP_PARAMS,
            Transport::Rdma => &RDMA_PARAMS,
            Transport::Gdr => &GDR_PARAMS,
        }
    }

    pub fn by_name(s: &str) -> Option<Transport> {
        match s.to_ascii_lowercase().as_str() {
            "local" => Some(Transport::Local),
            "tcp" | "zeromq" | "zmq" => Some(Transport::Tcp),
            "rdma" => Some(Transport::Rdma),
            "gdr" | "gpudirect" => Some(Transport::Gdr),
            _ => None,
        }
    }
}

/// Latency/CPU model of one transport hop.
#[derive(Debug, Clone)]
pub struct TransportParams {
    /// Fixed per-message overhead (stack traversal / WR post + WC poll), us.
    pub fixed_us: f64,
    /// Effective per-message payload rate, Gbit/s (latency bandwidth of a
    /// single closed-loop message, not streaming goodput).
    pub goodput_gbps: f64,
    /// Coefficient of variation of the sampled hop latency.
    pub jitter_cov: f64,
    /// Fixed CPU time consumed per message (send+recv handling), us.
    pub cpu_fixed_us: f64,
    /// CPU time per payload byte (stack copies / checksums), ns per byte.
    pub cpu_ns_per_byte: f64,
}

impl TransportParams {
    /// Wire/stack time for `bytes` through this hop (mean, us).
    pub fn hop_mean_us(&self, bytes: u64) -> f64 {
        self.fixed_us + bytes as f64 * 8.0 / self.goodput_gbps / 1_000.0
    }

    /// Sampled hop latency.
    pub fn sample_hop(&self, bytes: u64, rng: &mut Rng) -> Ns {
        Ns::from_us(self.hop_mean_us(bytes) * rng.noise(self.jitter_cov))
    }

    /// CPU time charged for moving `bytes` through this hop (us).
    pub fn cpu_us(&self, bytes: u64) -> f64 {
        self.cpu_fixed_us + bytes as f64 * self.cpu_ns_per_byte / 1_000.0
    }
}

/// TCP/ZeroMQ: two socket copies + stack each side; single in-flight
/// message sees ~6.5 Gbit/s latency bandwidth on the 25 GbE link.
pub static TCP_PARAMS: TransportParams = TransportParams {
    fixed_us: 60.0,
    goodput_gbps: 6.5,
    jitter_cov: 0.18,
    cpu_fixed_us: 25.0,
    cpu_ns_per_byte: 0.8,
};

/// RDMA (RoCEv2 on ConnectX-5): RNIC DMA at near line rate.
pub static RDMA_PARAMS: TransportParams = TransportParams {
    fixed_us: 8.0,
    goodput_gbps: 24.2,
    jitter_cov: 0.03,
    cpu_fixed_us: 3.0,
    cpu_ns_per_byte: 0.0,
};

/// GDR: identical wire path to RDMA (the difference is on the GPU side).
pub static GDR_PARAMS: TransportParams = TransportParams {
    fixed_us: 8.0,
    goodput_gbps: 24.2,
    jitter_cov: 0.03,
    cpu_fixed_us: 3.0,
    cpu_ns_per_byte: 0.0,
};

/// Local processing: no hop.
pub static LOCAL_PARAMS: TransportParams = TransportParams {
    fixed_us: 0.0,
    goodput_gbps: f64::INFINITY,
    jitter_cov: 0.0,
    cpu_fixed_us: 0.0,
    cpu_ns_per_byte: 0.0,
};

/// Gateway (Router-Dealer proxy) costs: store-and-forward plus protocol
/// translation when the two hops use different mechanisms (a buffer
/// re-registration / copy between the TCP socket and the RDMA MR).
#[derive(Debug, Clone)]
pub struct ProxyParams {
    /// Fixed forwarding decision + queue handoff, us.
    pub forward_fixed_us: f64,
    /// Translation cost per byte when hop protocols differ, ns/B (one
    /// memcpy between transport buffers at gateway memory bandwidth).
    pub translate_ns_per_byte: f64,
}

pub static PROXY_PARAMS: ProxyParams = ProxyParams {
    forward_fixed_us: 15.0,
    translate_ns_per_byte: 0.08,
};

impl ProxyParams {
    /// Gateway residence time for a message of `bytes`, given whether the
    /// ingress and egress protocols differ.
    pub fn residence_us(&self, bytes: u64, translated: bool) -> f64 {
        let t = if translated {
            bytes as f64 * self.translate_ns_per_byte / 1_000.0
        } else {
            0.0
        };
        self.forward_fixed_us + t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_requirements_follow_fig2() {
        assert!(Transport::Tcp.needs_gpu_copies());
        assert!(Transport::Rdma.needs_gpu_copies());
        assert!(!Transport::Gdr.needs_gpu_copies());
        assert!(!Transport::Local.needs_gpu_copies());
    }

    #[test]
    fn single_flow_ordering_gdr_leq_rdma_leq_tcp() {
        // Property: for any payload, mean hop latency orders GDR = RDMA < TCP.
        for bytes in [1u64, 4_000, 602_112, 3_932_160, 45_000_000] {
            let t = TCP_PARAMS.hop_mean_us(bytes);
            let r = RDMA_PARAMS.hop_mean_us(bytes);
            let g = GDR_PARAMS.hop_mean_us(bytes);
            assert_eq!(r, g);
            assert!(g < t, "bytes={bytes}: gdr {g} !< tcp {t}");
        }
    }

    #[test]
    fn latency_monotone_in_bytes() {
        let mut prev = 0.0;
        for bytes in [0u64, 1_000, 10_000, 100_000, 1_000_000] {
            let t = TCP_PARAMS.hop_mean_us(bytes);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn paper_send_deltas_approximated() {
        // §IV-A: TCP sends raw images ~0.73 ms slower and preprocessed
        // tensors ~0.61 ms slower than GDR (ResNet50, 224x224).
        let raw = crate::models::zoo::PaperModel::by_name("ResNet50")
            .unwrap()
            .raw_bytes();
        let pre = 3 * 224 * 224 * 4u64;
        let d_raw = TCP_PARAMS.hop_mean_us(raw) - GDR_PARAMS.hop_mean_us(raw);
        let d_pre = TCP_PARAMS.hop_mean_us(pre) - GDR_PARAMS.hop_mean_us(pre);
        assert!((0.45..1.1).contains(&(d_raw / 1_000.0)), "raw delta {d_raw}us");
        assert!((0.35..0.9).contains(&(d_pre / 1_000.0)), "pre delta {d_pre}us");
        assert!(d_raw > d_pre);
    }

    #[test]
    fn tcp_burns_cpu_rdma_does_not() {
        let b = 1_000_000;
        assert!(TCP_PARAMS.cpu_us(b) > 100.0 * RDMA_PARAMS.cpu_us(b) / 10.0);
        assert_eq!(RDMA_PARAMS.cpu_us(b), GDR_PARAMS.cpu_us(b));
    }

    #[test]
    fn sampling_deterministic_and_near_mean() {
        let mut rng = Rng::new(11);
        let mut sum = 0.0;
        let n = 5_000;
        for _ in 0..n {
            sum += TCP_PARAMS.sample_hop(602_112, &mut rng).as_us();
        }
        let mean = sum / n as f64;
        let want = TCP_PARAMS.hop_mean_us(602_112);
        assert!((mean - want).abs() / want < 0.03, "{mean} vs {want}");
    }

    #[test]
    fn proxy_translation_costs_extra() {
        let same = PROXY_PARAMS.residence_us(1_000_000, false);
        let diff = PROXY_PARAMS.residence_us(1_000_000, true);
        assert!(diff > same);
    }
}
