//! Network fabric models: the edge facility's internal 25 GbE fabric under
//! TCP (ZeroMQ), RDMA (RoCEv2) and GPUDirect RDMA, plus the proxied
//! (gateway) connection mode.

pub mod fabric;
pub mod params;

pub use fabric::{Fabric, TransferKind};
pub use params::{Transport, TransportParams, PROXY_PARAMS};
