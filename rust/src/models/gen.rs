//! Offline artifact generator: emits the live-plane serving artifacts
//! (HLO text + `manifest.json`) **without Python/JAX** — the Rust twin
//! of `python/compile/aot.py` (`accelserve gen-artifacts`).
//!
//! The generated model family mirrors the aot.py registry and I/O
//! archetypes (DESIGN.md §1):
//!
//! * `preprocess`            — raw (64,64,3) u8 frame -> (1,32,32,3) f32
//!   (2x2 average-pool resize + normalize to [-1, 1]),
//! * `tiny_mobilenet_b{1,2,4,8}` — one 3x3 stride-2 conv + relu, global
//!   average pool, dense 1000-class head,
//! * `tiny_resnet_b{1,2,4,8}`    — two stacked 3x3 stride-2 convs,
//! * `tiny_segnet_b{1,2,4,8}`    — 1x1 conv to 21 per-pixel classes
//!   (the large-response DeepLabV3 archetype),
//! * `tiny_*_raw`            — the fused u8 -> preprocess -> model graph.
//!
//! Weights are deterministic (SplitMix64 from a per-model seed,
//! quantized to 3 decimals so the HLO text round-trips bit-exactly);
//! the staged `preprocess` + `_b1` path and the fused `_raw` path share
//! the same emitted constants, so their outputs agree exactly — the
//! invariant `engine.rs::preprocess_then_classify_matches_fused_raw`
//! asserts. Every op emitted is inside the vendored interpreter's
//! supported set (see `rust/vendor/xla`).

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{Context, Result};

use crate::models::manifest::TensorSpec;
use crate::sim::rng::Rng;

pub const RAW_H: usize = 64;
pub const RAW_W: usize = 64;
pub const IN_H: usize = 32;
pub const IN_W: usize = 32;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 1000;
pub const SEG_CLASSES: usize = 21;
/// Batched variants compiled per model (the dynamic batcher's menu).
pub const BATCH_SIZES: [usize; 4] = [1, 2, 4, 8];

/// Incrementally builds one HLO-text module.
struct Hlo {
    body: Vec<String>,
    next: usize,
    has_sum_region: bool,
}

impl Hlo {
    fn new() -> Hlo {
        Hlo {
            body: Vec::new(),
            next: 0,
            has_sum_region: false,
        }
    }

    /// Append one instruction; returns its value name.
    fn push(&mut self, shape: &str, expr: &str) -> String {
        self.next += 1;
        let name = format!("v{}", self.next);
        self.body.push(format!("  {name} = {shape} {expr}"));
        name
    }

    fn param(&mut self, shape: &str, index: usize) -> String {
        let expr = format!("parameter({index})");
        self.push(shape, &expr)
    }

    fn scalar(&mut self, v: f32) -> String {
        let expr = format!("constant({})", fmt_f32(v));
        self.push("f32[]", &expr)
    }

    fn array(&mut self, dims: &[usize], vals: &[f32]) -> String {
        debug_assert_eq!(dims.iter().product::<usize>(), vals.len());
        let expr = format!("constant({})", fmt_nested(dims, vals));
        self.push(&sh_f32(dims), &expr)
    }

    /// Broadcast a scalar to `dims`.
    fn splat(&mut self, v: f32, dims: &[usize]) -> String {
        let s = self.scalar(v);
        let expr = format!("broadcast({s}), dimensions={{}}");
        self.push(&sh_f32(dims), &expr)
    }

    /// The shared scalar-add reduce region (emitted once per module).
    fn sum_region(&mut self) -> &'static str {
        self.has_sum_region = true;
        "sum"
    }

    fn relu(&mut self, x: &str, dims: &[usize]) -> String {
        let zeros = self.splat(0.0, dims);
        let expr = format!("maximum({x}, {zeros})");
        self.push(&sh_f32(dims), &expr)
    }

    /// Render the module; `root` becomes `ROOT tuple(root)` (aot.py
    /// lowers with return_tuple=True, and the engine untuples).
    fn finish(self, module: &str, root_shape: &str, root: &str) -> String {
        let mut text = format!("HloModule {module}\n\n");
        if self.has_sum_region {
            text.push_str(
                "sum {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  \
                 ROOT r = f32[] add(a, b)\n}\n\n",
            );
        }
        text.push_str("ENTRY main {\n");
        for line in &self.body {
            text.push_str(line);
            text.push('\n');
        }
        text.push_str(&format!("  ROOT out = ({root_shape}) tuple({root})\n}}\n"));
        text
    }
}

fn sh_f32(dims: &[usize]) -> String {
    format!(
        "f32[{}]",
        dims.iter()
            .map(usize::to_string)
            .collect::<Vec<_>>()
            .join(",")
    )
}

fn sh_u8(dims: &[usize]) -> String {
    format!(
        "u8[{}]",
        dims.iter()
            .map(usize::to_string)
            .collect::<Vec<_>>()
            .join(",")
    )
}

/// Shortest round-tripping decimal for an f32 (Rust's Debug format).
fn fmt_f32(v: f32) -> String {
    format!("{v:?}")
}

/// Nested-brace HLO constant payload, row-major.
fn fmt_nested(dims: &[usize], vals: &[f32]) -> String {
    match dims.len() {
        0 => fmt_f32(vals[0]),
        1 => format!(
            "{{ {} }}",
            vals.iter().map(|v| fmt_f32(*v)).collect::<Vec<_>>().join(", ")
        ),
        _ => {
            let chunk = vals.len() / dims[0];
            let parts: Vec<String> = (0..dims[0])
                .map(|i| fmt_nested(&dims[1..], &vals[i * chunk..(i + 1) * chunk]))
                .collect();
            format!("{{ {} }}", parts.join(", "))
        }
    }
}

/// Deterministic uniform weights in [-scale, scale], quantized to 3
/// decimals so the emitted text parses back to the exact value.
fn weights(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n)
        .map(|_| (((rng.f64() * 2.0 - 1.0) * scale * 1000.0).round() / 1000.0) as f32)
        .collect()
}

/// One model family: its conv tower and head weights, generated once so
/// every batch variant and the fused raw graph embed identical values.
struct ModelWeights {
    name: &'static str,
    task: &'static str,
    /// 3x3 stride-2 conv filters, (cin, cout, values) per layer.
    convs: Vec<(usize, usize, Vec<f32>)>,
    /// Dense head (feat, classes, values); `None` for segnet.
    dense: Option<(usize, Vec<f32>)>,
    bias: Vec<f32>,
    /// 1x1 segmentation head for segnet.
    seg_head: Option<Vec<f32>>,
}

impl ModelWeights {
    fn classifier(name: &'static str, seed: u64, channels: &[usize]) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let mut convs = Vec::new();
        let mut cin = CHANNELS;
        for &cout in channels {
            let fan_in = 9 * cin;
            let w = weights(&mut rng, 9 * cin * cout, (2.0 / fan_in as f64).sqrt());
            convs.push((cin, cout, w));
            cin = cout;
        }
        let dense = weights(&mut rng, cin * NUM_CLASSES, (2.0 / cin as f64).sqrt());
        let bias = weights(&mut rng, NUM_CLASSES, 0.05);
        ModelWeights {
            name,
            task: "classification",
            convs,
            dense: Some((cin, dense)),
            bias,
            seg_head: None,
        }
    }

    fn segnet(name: &'static str, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let head = weights(&mut rng, CHANNELS * SEG_CLASSES, 0.5);
        let bias = weights(&mut rng, SEG_CLASSES, 0.05);
        ModelWeights {
            name,
            task: "segmentation",
            convs: Vec::new(),
            dense: None,
            bias,
            seg_head: Some(head),
        }
    }

    fn params(&self) -> usize {
        self.convs.iter().map(|(_, _, w)| w.len()).sum::<usize>()
            + self.dense.as_ref().map_or(0, |(_, w)| w.len())
            + self.seg_head.as_ref().map_or(0, Vec::len)
            + self.bias.len()
    }

    /// Per-request output shape.
    fn out_shape(&self, batch: usize) -> Vec<usize> {
        if self.seg_head.is_some() {
            vec![batch, IN_H, IN_W, SEG_CLASSES]
        } else {
            vec![batch, NUM_CLASSES]
        }
    }

    /// Approximate multiply-add GFLOPs at batch 1.
    fn gflops(&self) -> f64 {
        let mut h = IN_H;
        let mut w = IN_W;
        let mut fl = 0f64;
        for (cin, cout, _) in &self.convs {
            h /= 2;
            w /= 2;
            fl += 2.0 * (h * w * 9 * cin * cout) as f64;
        }
        if let Some((feat, _)) = &self.dense {
            fl += 2.0 * (feat * NUM_CLASSES) as f64;
        }
        if self.seg_head.is_some() {
            fl += 2.0 * (IN_H * IN_W * CHANNELS * SEG_CLASSES) as f64;
        }
        fl / 1e9
    }
}

/// Emit the preprocess pipeline: u8[64,64,3] -> f32[1,32,32,3]
/// (2x2 average pool + scale to [-1, 1]).
fn emit_preprocess(h: &mut Hlo, raw: &str) -> String {
    let cvt = {
        let expr = format!("convert({raw})");
        h.push(&sh_f32(&[RAW_H, RAW_W, CHANNELS]), &expr)
    };
    let grouped_dims = [IN_H, 2, IN_W, 2, CHANNELS];
    let grouped = {
        let expr = format!("reshape({cvt})");
        h.push(&sh_f32(&grouped_dims), &expr)
    };
    let zero = h.scalar(0.0);
    let region = h.sum_region();
    let pool_dims = [IN_H, IN_W, CHANNELS];
    let pooled = {
        let expr = format!(
            "reduce({grouped}, {zero}), dimensions={{1,3}}, to_apply={region}"
        );
        h.push(&sh_f32(&pool_dims), &expr)
    };
    // /4 window area, /255 byte range => one divide by 1020, then
    // affine-map [0,1] to [-1,1].
    let denom = h.splat(1020.0, &pool_dims);
    let unit = {
        let expr = format!("divide({pooled}, {denom})");
        h.push(&sh_f32(&pool_dims), &expr)
    };
    let half = h.splat(0.5, &pool_dims);
    let centered = {
        let expr = format!("subtract({unit}, {half})");
        h.push(&sh_f32(&pool_dims), &expr)
    };
    let two = h.splat(2.0, &pool_dims);
    let normed = {
        let expr = format!("multiply({centered}, {two})");
        h.push(&sh_f32(&pool_dims), &expr)
    };
    let expr = format!("reshape({normed})");
    h.push(&sh_f32(&[1, IN_H, IN_W, CHANNELS]), &expr)
}

/// Emit a model body over `x` (f32[batch,32,32,3]); returns the root.
fn emit_model(h: &mut Hlo, x: &str, batch: usize, mw: &ModelWeights) -> String {
    if let Some(head) = &mw.seg_head {
        let out_dims = [batch, IN_H, IN_W, SEG_CLASSES];
        let w = h.array(&[1, 1, CHANNELS, SEG_CLASSES], head);
        let conv = {
            let expr = format!(
                "convolution({x}, {w}), window={{size=1x1}}, dim_labels=b01f_01io->b01f"
            );
            h.push(&sh_f32(&out_dims), &expr)
        };
        let bias = h.array(&[SEG_CLASSES], &mw.bias);
        let bb = {
            let expr = format!("broadcast({bias}), dimensions={{3}}");
            h.push(&sh_f32(&out_dims), &expr)
        };
        let expr = format!("add({conv}, {bb})");
        return h.push(&sh_f32(&out_dims), &expr);
    }

    let mut cur = x.to_string();
    let (mut ch, mut cw) = (IN_H, IN_W);
    for (cin, cout, wvals) in &mw.convs {
        ch /= 2;
        cw /= 2;
        let dims = [batch, ch, cw, *cout];
        let w = h.array(&[3, 3, *cin, *cout], wvals);
        let conv = {
            let expr = format!(
                "convolution({cur}, {w}), window={{size=3x3 stride=2x2 pad=0_1x0_1}}, \
                 dim_labels=b01f_01io->b01f"
            );
            h.push(&sh_f32(&dims), &expr)
        };
        cur = h.relu(&conv, &dims);
    }
    let (feat, dense) = mw.dense.as_ref().expect("classifier has a dense head");
    let zero = h.scalar(0.0);
    let region = h.sum_region();
    let pooled = {
        let expr = format!("reduce({cur}, {zero}), dimensions={{1,2}}, to_apply={region}");
        h.push(&sh_f32(&[batch, *feat]), &expr)
    };
    let area = h.splat((ch * cw) as f32, &[batch, *feat]);
    let avg = {
        let expr = format!("divide({pooled}, {area})");
        h.push(&sh_f32(&[batch, *feat]), &expr)
    };
    let wd = h.array(&[*feat, NUM_CLASSES], dense);
    let logits = {
        let expr = format!(
            "dot({avg}, {wd}), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}"
        );
        h.push(&sh_f32(&[batch, NUM_CLASSES]), &expr)
    };
    let bias = h.array(&[NUM_CLASSES], &mw.bias);
    let bb = {
        let expr = format!("broadcast({bias}), dimensions={{1}}");
        h.push(&sh_f32(&[batch, NUM_CLASSES]), &expr)
    };
    let expr = format!("add({logits}, {bb})");
    h.push(&sh_f32(&[batch, NUM_CLASSES]), &expr)
}

/// One generated artifact, ready to be written + indexed.
struct Artifact {
    name: String,
    model: String,
    task: String,
    inputs: Vec<TensorSpec>,
    output: TensorSpec,
    gflops: f64,
    params: usize,
    text: String,
}

fn spec(shape: &[usize], dtype: &str) -> TensorSpec {
    TensorSpec {
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
    }
}

fn preprocess_artifact() -> Artifact {
    let mut h = Hlo::new();
    let raw = h.param(&sh_u8(&[RAW_H, RAW_W, CHANNELS]), 0);
    let out = emit_preprocess(&mut h, &raw);
    let out_dims = [1, IN_H, IN_W, CHANNELS];
    let text = h.finish("preprocess", &sh_f32(&out_dims), &out);
    Artifact {
        name: "preprocess".into(),
        model: "preprocess".into(),
        task: "preprocess".into(),
        inputs: vec![spec(&[RAW_H, RAW_W, CHANNELS], "u8")],
        output: spec(&out_dims, "f32"),
        gflops: (RAW_H * RAW_W * CHANNELS) as f64 / 1e9,
        params: 0,
        text,
    }
}

fn batched_artifact(mw: &ModelWeights, batch: usize) -> Artifact {
    let name = format!("{}_b{batch}", mw.name);
    let in_dims = [batch, IN_H, IN_W, CHANNELS];
    let mut h = Hlo::new();
    let x = h.param(&sh_f32(&in_dims), 0);
    let out = emit_model(&mut h, &x, batch, mw);
    let out_dims = mw.out_shape(batch);
    let text = h.finish(&name, &sh_f32(&out_dims), &out);
    Artifact {
        name,
        model: mw.name.into(),
        task: mw.task.into(),
        inputs: vec![spec(&in_dims, "f32")],
        output: spec(&out_dims, "f32"),
        gflops: mw.gflops() * batch as f64,
        params: mw.params(),
        text,
    }
}

fn raw_artifact(mw: &ModelWeights) -> Artifact {
    let name = format!("{}_raw", mw.name);
    let mut h = Hlo::new();
    let raw = h.param(&sh_u8(&[RAW_H, RAW_W, CHANNELS]), 0);
    let pre = emit_preprocess(&mut h, &raw);
    let out = emit_model(&mut h, &pre, 1, mw);
    let out_dims = mw.out_shape(1);
    let text = h.finish(&name, &sh_f32(&out_dims), &out);
    Artifact {
        name,
        model: mw.name.into(),
        task: mw.task.into(),
        inputs: vec![spec(&[RAW_H, RAW_W, CHANNELS], "u8")],
        output: spec(&out_dims, "f32"),
        gflops: mw.gflops() + (RAW_H * RAW_W * CHANNELS) as f64 / 1e9,
        params: mw.params(),
        text,
    }
}

fn model_family() -> Vec<ModelWeights> {
    vec![
        ModelWeights::classifier("tiny_mobilenet", 10, &[8]),
        ModelWeights::classifier("tiny_resnet", 11, &[8, 16]),
        ModelWeights::segnet("tiny_segnet", 12),
    ]
}

fn generate_all() -> Vec<Artifact> {
    let mut arts = vec![preprocess_artifact()];
    for mw in model_family() {
        for batch in BATCH_SIZES {
            arts.push(batched_artifact(&mw, batch));
        }
        arts.push(raw_artifact(&mw));
    }
    arts.sort_by(|a, b| a.name.cmp(&b.name));
    arts
}

fn tensor_json(t: &TensorSpec) -> String {
    format!(
        "{{\"shape\": [{}], \"dtype\": \"{}\"}}",
        t.shape
            .iter()
            .map(usize::to_string)
            .collect::<Vec<_>>()
            .join(", "),
        t.dtype
    )
}

fn manifest_json(arts: &[Artifact]) -> String {
    let mut s = String::from(
        "{\n  \"format\": 1,\n  \"generator\": \"accelserve gen-artifacts\",\n  \
         \"artifacts\": [\n",
    );
    for (i, a) in arts.iter().enumerate() {
        let inputs: Vec<String> = a.inputs.iter().map(tensor_json).collect();
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"model\": \"{}\", \"task\": \"{}\", \
             \"file\": \"{}.hlo.txt\",\n     \"inputs\": [{}],\n     \
             \"output\": {},\n     \"gflops\": {}, \"params\": {}, \"hlo_bytes\": {}}}{}\n",
            a.name,
            a.model,
            a.task,
            a.name,
            inputs.join(", "),
            tensor_json(&a.output),
            a.gflops,
            a.params,
            a.text.len(),
            if i + 1 < arts.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Generate every artifact into `dir`; returns the artifact count.
pub fn write_artifacts(dir: impl AsRef<Path>) -> Result<usize> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {}", dir.display()))?;
    let arts = generate_all();
    for a in &arts {
        let path = dir.join(format!("{}.hlo.txt", a.name));
        std::fs::write(&path, &a.text)
            .with_context(|| format!("writing {}", path.display()))?;
    }
    let mpath = dir.join("manifest.json");
    std::fs::write(&mpath, manifest_json(&arts))
        .with_context(|| format!("writing {}", mpath.display()))?;
    Ok(arts.len())
}

/// Self-provision a serving directory: generate the artifacts only if
/// `dir` has no manifest yet (the python AOT pipeline's output, when
/// present, is left untouched). Returns the number of artifacts
/// written, 0 when the directory was already provisioned.
pub fn ensure_artifacts(dir: impl AsRef<Path>) -> Result<usize> {
    let dir = dir.as_ref();
    if dir.join("manifest.json").exists() {
        return Ok(0);
    }
    write_artifacts(dir)
}

/// Artifacts for tests and the transport matrix: generated once per
/// process into a temp directory (a skip is a failure now — no test
/// depends on `make artifacts` anymore).
pub fn ensure_test_artifacts() -> &'static Path {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!(
            "accelserve-artifacts-{}",
            std::process::id()
        ));
        write_artifacts(&dir).expect("generating test artifacts");
        dir
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::Manifest;

    #[test]
    fn generator_writes_parseable_manifest() {
        let dir = ensure_test_artifacts();
        let m = Manifest::load(dir).unwrap();
        // aot.py registry shape: preprocess + 3 models x (4 batches + raw).
        assert_eq!(m.artifacts.len(), 1 + 3 * (BATCH_SIZES.len() + 1));
        assert_eq!(m.batch_sizes("tiny_resnet"), vec![1, 2, 4, 8]);
        let pre = m.get("preprocess").unwrap();
        assert_eq!(pre.inputs[0], spec(&[RAW_H, RAW_W, CHANNELS], "u8"));
        assert_eq!(pre.output.elems(), IN_H * IN_W * CHANNELS);
        let b4 = m.get("tiny_mobilenet_b4").unwrap();
        assert_eq!(b4.inputs[0].shape, vec![4, IN_H, IN_W, CHANNELS]);
        assert_eq!(b4.output.shape, vec![4, NUM_CLASSES]);
        let seg = m.get("tiny_segnet_b1").unwrap();
        assert_eq!(seg.output.elems(), IN_H * IN_W * SEG_CLASSES);
        let raw = m.get("tiny_resnet_raw").unwrap();
        assert_eq!(raw.inputs[0].dtype, "u8");
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "{} missing its HLO text", a.name);
            assert!(a.gflops > 0.0 || a.name == "preprocess");
        }
    }

    #[test]
    fn emitted_hlo_compiles_in_the_interpreter() {
        let dir = ensure_test_artifacts();
        let m = Manifest::load(dir).unwrap();
        let client = xla::PjRtClient::cpu().unwrap();
        for a in &m.artifacts {
            let path = m.hlo_path(a);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .unwrap_or_else(|e| panic!("{}: {e}", a.name));
            client
                .compile(&xla::XlaComputation::from_proto(&proto))
                .unwrap_or_else(|e| panic!("{}: {e}", a.name));
        }
    }

    #[test]
    fn weights_are_deterministic_across_calls() {
        let a = generate_all();
        let b = generate_all();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.text, y.text, "{} text differs between runs", x.name);
        }
    }

    #[test]
    fn nested_constant_formatting() {
        assert_eq!(fmt_nested(&[2], &[1.0, -2.5]), "{ 1.0, -2.5 }");
        assert_eq!(
            fmt_nested(&[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            "{ { 1.0, 2.0 }, { 3.0, 4.0 } }"
        );
    }
}
