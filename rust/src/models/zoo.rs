//! Table II model zoo: the six DNNs the paper serves, with the I/O sizes
//! from the paper and execution profiles calibrated to an NVIDIA A2
//! running TensorRT 8.4 (back-derived from the paper's own reported
//! latencies; see DESIGN.md §1 and EXPERIMENTS.md §Calibration).
//!
//! Each model is decomposed into a sequence of `n_kernels` GPU kernels.
//! A kernel issues `blocks_per_kernel()` thread blocks (two waves at the
//! model's engine occupancy); each launch serializes through the global
//! command frontend for `KERNEL_GAP_US`. This is the granularity at
//! which the paper's GPU findings live (block-level priority, copy/exec
//! interference, stream multiplexing, launch-bound small models).

/// Kernel launch cost: one slot of the GPU's global command frontend
/// (GigaThread) per kernel launch. Launches from *all* streams serialize
/// through this FIFO — the reason small-kernel models (MobileNetV3) see
/// their processing time balloon under concurrency (Fig 12) while big-
/// kernel models barely notice.
pub const KERNEL_GAP_US: f64 = 25.0;

/// Raw camera frames are captured at 2.2x the model's native resolution
/// (decoded RGB, uint8). This preserves the paper's property that the
/// raw-image path always moves more bytes than the preprocessed path.
pub const RAW_SCALE: f64 = 2.2;

/// One entry of Table II plus the calibrated execution profile.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: &'static str,
    pub task: &'static str,
    /// Model complexity from Table II.
    pub gflops: f64,
    /// Native input resolution (C, H, W) from Table II.
    pub input_chw: (u32, u32, u32),
    /// Output elements from Table II (f32 each).
    pub out_elems: u64,
    /// Single-client TensorRT batch-1 inference latency on the A2 (ms).
    pub infer_ms: f64,
    /// GPU preprocessing (resize + normalize) latency (ms).
    pub preproc_ms: f64,
    /// Kernel count of the TensorRT engine (drives launch-gap overhead).
    pub n_kernels: u32,
    /// Average execution-engine occupancy of a kernel wave when the
    /// model runs alone (1..=10). Dense classifiers nearly fill the A2;
    /// latency-bound graphs (MobileNet's pointwise stacks, DeepLab's
    /// decoder chain) leave engines idle, which is exactly the headroom
    /// stream multiplexing exploits (Fig 15a).
    pub occupancy: u32,
}

impl PaperModel {
    /// Preprocessed request payload: f32 CHW tensor, as in the paper's
    /// "preprocessed images" experiments.
    pub fn preprocessed_bytes(&self) -> u64 {
        let (c, h, w) = self.input_chw;
        c as u64 * h as u64 * w as u64 * 4
    }

    /// Raw request payload: uint8 camera frame at RAW_SCALE x native res.
    pub fn raw_bytes(&self) -> u64 {
        let (c, h, w) = self.input_chw;
        let rh = (h as f64 * RAW_SCALE).round() as u64;
        let rw = (w as f64 * RAW_SCALE).round() as u64;
        c as u64 * rh * rw
    }

    /// Response payload: f32 output tensor.
    pub fn response_bytes(&self) -> u64 {
        self.out_elems * 4
    }

    /// Request payload for a given submission mode.
    pub fn request_bytes(&self, raw: bool) -> u64 {
        if raw {
            self.raw_bytes()
        } else {
            self.preprocessed_bytes()
        }
    }

    /// Thread blocks per kernel: two waves at this model's occupancy.
    pub fn blocks_per_kernel(&self) -> u32 {
        2 * self.occupancy
    }

    /// Per-block execution time (us): the compute part of `infer_ms`
    /// (minus launch slots) spread over kernels x 2 waves.
    pub fn block_time_us(&self) -> f64 {
        let gaps = self.n_kernels as f64 * KERNEL_GAP_US / 1_000.0;
        let compute_ms = (self.infer_ms - gaps).max(0.05 * self.infer_ms);
        compute_ms * 1_000.0 / (self.n_kernels as f64 * 2.0)
    }

    /// Preprocessing kernels (always 2: resize, normalize).
    pub fn preproc_kernels(&self) -> u32 {
        2
    }

    pub fn preproc_block_time_us(&self) -> f64 {
        // Two kernels, two waves each; gaps included in preproc_ms.
        let gaps = 2.0 * KERNEL_GAP_US / 1_000.0;
        let compute_ms = (self.preproc_ms - gaps).max(0.2 * self.preproc_ms);
        compute_ms * 1_000.0 / (2.0 * 2.0)
    }

    pub fn by_name(name: &str) -> Option<&'static PaperModel> {
        ZOO.iter().find(|m| m.name.eq_ignore_ascii_case(name))
    }
}

/// The six models of Table II. Input/output shapes are the paper's;
/// `infer_ms` is calibrated (see DESIGN.md §1).
pub static ZOO: &[PaperModel] = &[
    PaperModel {
        name: "MobileNetV3",
        task: "classification",
        gflops: 0.06,
        input_chw: (3, 224, 224),
        out_elems: 1000,
        infer_ms: 0.35,
        preproc_ms: 0.10,
        n_kernels: 12,
        occupancy: 2,
    },
    PaperModel {
        name: "ResNet50",
        task: "classification",
        gflops: 4.1,
        input_chw: (3, 224, 224),
        out_elems: 1000,
        infer_ms: 3.0,
        preproc_ms: 0.10,
        n_kernels: 26,
        occupancy: 9,
    },
    PaperModel {
        name: "EfficientNetB0",
        task: "classification",
        gflops: 0.39,
        input_chw: (3, 224, 224),
        out_elems: 1000,
        infer_ms: 0.9,
        preproc_ms: 0.10,
        n_kernels: 20,
        occupancy: 4,
    },
    PaperModel {
        name: "WideResNet101",
        task: "classification",
        gflops: 22.81,
        input_chw: (3, 224, 224),
        out_elems: 1000,
        infer_ms: 14.0,
        preproc_ms: 0.10,
        n_kernels: 50,
        occupancy: 9,
    },
    PaperModel {
        name: "YoloV4",
        task: "detection",
        gflops: 128.46,
        input_chw: (3, 416, 416),
        // S x S x 3 x 85 for S in {13, 26, 52}.
        out_elems: (13 * 13 + 26 * 26 + 52 * 52) * 3 * 85,
        infer_ms: 45.0,
        preproc_ms: 0.35,
        n_kernels: 60,
        occupancy: 7,
    },
    PaperModel {
        name: "DeepLabV3_ResNet50",
        task: "segmentation",
        gflops: 178.72,
        input_chw: (3, 520, 520),
        // 2 x 21 x 520 x 520 (main + aux heads).
        out_elems: 2 * 21 * 520 * 520,
        infer_ms: 85.0,
        preproc_ms: 0.55,
        n_kernels: 40,
        occupancy: 4,
    },
];

/// Synthetic client payload generator (deterministic pixels) for the
/// live plane; the sim plane uses only the byte counts. The load
/// clients (`coordinator::client`), the transport matrix and the batch
/// sweep all draw their request payloads from here, so two runs with
/// the same seed serve byte-identical traffic.
#[derive(Debug, Clone)]
pub struct WorkloadData {
    pub bytes: Vec<u8>,
}

impl WorkloadData {
    /// Deterministic pseudo-image of `n` bytes from `seed` (same seed,
    /// same bytes — the determinism the bit-identical batching tests
    /// lean on).
    pub fn image(n: usize, seed: u64) -> WorkloadData {
        let mut rng = crate::sim::rng::Rng::new(seed);
        let mut bytes = vec![0u8; n];
        for chunk in bytes.chunks_mut(8) {
            let v = rng.next_u64().to_le_bytes();
            let l = chunk.len();
            chunk.copy_from_slice(&v[..l]);
        }
        WorkloadData { bytes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_matches_table_ii() {
        assert_eq!(ZOO.len(), 6);
        let rn = PaperModel::by_name("resnet50").unwrap();
        assert_eq!(rn.gflops, 4.1);
        assert_eq!(rn.input_chw, (3, 224, 224));
        assert_eq!(rn.preprocessed_bytes(), 3 * 224 * 224 * 4);
        assert_eq!(rn.response_bytes(), 4000);
        let dl = PaperModel::by_name("DeepLabV3_ResNet50").unwrap();
        assert_eq!(dl.response_bytes(), 2 * 21 * 520 * 520 * 4); // ~45.4 MB
        let yolo = PaperModel::by_name("YoloV4").unwrap();
        assert_eq!(yolo.out_elems, (169 + 676 + 2704) * 255);
    }

    #[test]
    fn raw_always_exceeds_preprocessed() {
        // RAW_SCALE = 2.2 guarantees raw u8 frames out-byte f32 tensors:
        // 3*(2.2H)*(2.2W) = 14.5*H*W > 12*H*W = 3*H*W*4.
        for m in ZOO {
            assert!(
                m.raw_bytes() > m.preprocessed_bytes(),
                "{}: raw {} <= preproc {}",
                m.name,
                m.raw_bytes(),
                m.preprocessed_bytes()
            );
        }
    }

    #[test]
    fn compute_ordering_matches_gflops() {
        // infer_ms must be monotone in GFLOPs across the zoo.
        let mut sorted = ZOO.to_vec();
        sorted.sort_by(|a, b| a.gflops.partial_cmp(&b.gflops).unwrap());
        for pair in sorted.windows(2) {
            assert!(
                pair[0].infer_ms <= pair[1].infer_ms,
                "{} vs {}",
                pair[0].name,
                pair[1].name
            );
        }
    }

    #[test]
    fn block_times_positive_and_sane() {
        for m in ZOO {
            let bt = m.block_time_us();
            assert!(bt > 0.0, "{}", m.name);
            // Reconstructed compute + gaps should approximate infer_ms.
            let rebuilt =
                m.n_kernels as f64 * (KERNEL_GAP_US + 2.0 * bt) / 1_000.0;
            assert!(
                (rebuilt - m.infer_ms).abs() / m.infer_ms < 0.35,
                "{}: rebuilt {rebuilt} vs {}",
                m.name,
                m.infer_ms
            );
            assert!(m.preproc_block_time_us() > 0.0);
        }
    }

    #[test]
    fn workload_deterministic() {
        let a = WorkloadData::image(1000, 5);
        let b = WorkloadData::image(1000, 5);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(a.bytes.len(), 1000);
        assert_ne!(a.bytes, WorkloadData::image(1000, 6).bytes);
    }
}
