//! Model zoo: the paper's Table II DNNs (sim plane), the live-plane
//! artifact manifest, and the offline artifact generator.

pub mod gen;
pub mod manifest;
pub mod zoo;

pub use manifest::{ArtifactEntry, Manifest};
pub use zoo::{PaperModel, WorkloadData, ZOO};
