//! Model zoo: the paper's Table II DNNs (sim plane) and the live-plane
//! artifact manifest.

pub mod manifest;
pub mod zoo;

pub use manifest::{ArtifactEntry, Manifest};
pub use zoo::{PaperModel, WorkloadData, ZOO};
