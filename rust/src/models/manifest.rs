//! Live-plane artifact manifest: the JSON index that `accelserve
//! gen-artifacts` (or the original `python -m compile.aot` pipeline)
//! writes next to the HLO text artifacts.
//!
//! [`Manifest`] is the executor's source of truth for what can run:
//! each [`ArtifactEntry`] names one compiled executable with its
//! [`TensorSpec`] I/O contract, and [`Manifest::batch_sizes`] is the
//! dynamic batcher's menu — which `_b{N}` variants exist for a model
//! and therefore how far concurrent requests can be coalesced.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::json::Json;

/// One AOT-compiled serving executable.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// Source model name (e.g. "tiny_resnet").
    pub model: String,
    pub task: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input shapes/dtypes, in parameter order.
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    pub gflops: f64,
}

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        let per = match self.dtype.as_str() {
            "f32" | "i32" => 4,
            "u8" => 1,
            "f16" | "bf16" => 2,
            _ => 4,
        };
        self.elems() * per
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor missing shape")?
            .iter()
            .map(|d| d.as_u64().map(|u| u as usize).context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .context("tensor missing dtype")?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// The parsed manifest plus its directory (for resolving artifact paths).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let format = root.get("format").and_then(Json::as_u64).unwrap_or(0);
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts")?
        {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactEntry {
                name: req_str(a, "name")?,
                model: req_str(a, "model")?,
                task: req_str(a, "task")?,
                file: req_str(a, "file")?,
                inputs,
                output: TensorSpec::from_json(
                    a.get("output").context("artifact missing output")?,
                )?,
                gflops: a.get("gflops").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO text.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Distinct model names in the manifest, sorted — the menu of
    /// servable models for multi-model experiments (`mixsweep`).
    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.iter().map(|a| a.model.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Batched variants available for a model (the `N`s of its `_bN`
    /// artifacts), sorted ascending — the dynamic batcher's menu. A
    /// model with no batched variants returns only `[1]` (or an empty
    /// vec when the model is unknown), telling the batcher that holding
    /// requests for it buys nothing.
    pub fn batch_sizes(&self, model: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .filter_map(|a| {
                a.name
                    .rsplit_once("_b")
                    .and_then(|(_, b)| b.parse::<usize>().ok())
            })
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("artifact missing {key}"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1, "jax": "0.8.2",
      "artifacts": [
        {"name": "m_b1", "model": "m", "task": "classification",
         "file": "m_b1.hlo.txt",
         "inputs": [{"shape": [1, 32, 32, 3], "dtype": "f32"}],
         "output": {"shape": [1, 1000], "dtype": "f32"},
         "gflops": 0.005, "params": 10, "sha256": "ab", "hlo_bytes": 2},
        {"name": "m_b4", "model": "m", "task": "classification",
         "file": "m_b4.hlo.txt",
         "inputs": [{"shape": [4, 32, 32, 3], "dtype": "f32"}],
         "output": {"shape": [4, 1000], "dtype": "f32"},
         "gflops": 0.02, "params": 10, "sha256": "cd", "hlo_bytes": 2}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("m_b1").unwrap();
        assert_eq!(a.inputs[0].shape, vec![1, 32, 32, 3]);
        assert_eq!(a.inputs[0].byte_len(), 32 * 32 * 3 * 4);
        assert_eq!(a.output.shape, vec![1, 1000]);
        assert_eq!(m.hlo_path(a), PathBuf::from("/tmp/m_b1.hlo.txt"));
        assert_eq!(m.batch_sizes("m"), vec![1, 4]);
        assert_eq!(m.models(), vec!["m".to_string()]);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 2, "artifacts": []}"#, "/".into()).is_err());
        assert!(Manifest::parse("{}", "/".into()).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // Integration hook: when `make artifacts` has run, validate it.
        if let Ok(m) = Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
            assert!(m.get("tiny_resnet_b1").is_some());
            assert!(!m.batch_sizes("tiny_resnet").is_empty());
        }
    }
}
