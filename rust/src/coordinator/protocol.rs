//! Wire protocol for the live serving plane.
//!
//! Framing is the transport's job; this module encodes the message
//! *inside* a frame. Payloads are raw tensor bytes (no serialization —
//! the homogeneity requirement of RDMA, §VII, kept for TCP too so the
//! comparison stays fair, §III-A).
//!
//! ```text
//! Request:  [op u8][flags u8][prio u8][name_len u8][name]
//!             [deadline_us u64, iff FLAG_DEADLINE]
//!             [n u8][len u8][stage]... iff FLAG_PIPELINE][payload]
//! Response: status 0 (v1 Ok):
//!             [0][queue_ns u64][preproc_ns u64][infer_ns u64][payload]
//!           status 1 (Err): [1][utf8 message]
//!           status 2 (v2 Ok + span): [2][queue_ns][preproc_ns][infer_ns]
//!             [span block][payload]   (see `trace::wire`)
//!           status 3 (Stats): [3][ver][interleaves u64][n u8][lanes...]
//!           status 4 (Shed): [4][reason u8][utf8 message]
//!           status 5 (credit envelope): [5][ver][credits u16]
//!             [pace_ns u64][inner response frame]   (see `CreditHint`)
//!           status 6 (Pipeline): [6][n u8] then per stage
//!             [name_len u8][name][sent_ns u64][recv_ns u64][span block],
//!             then [payload]   (the final stage's output tensor)
//!           status 7 (Metrics): [7][ver] then the telemetry snapshot
//!             (counter/gauge/histogram lists) and the sample ring —
//!             see `encode_metrics`
//! ```
//!
//! # Protocol v2 and compatibility
//!
//! v2 adds the request flags [`FLAG_SPANS`], [`FLAG_DEADLINE`],
//! [`FLAG_CREDITS`] and [`FLAG_PIPELINE`], the stats/shape opcodes
//! [`OP_STATS`]/[`OP_SHAPE`], the [`Response::Shed`] status, the
//! status-5 credit envelope, and the status-6 pipeline response, all
//! *opt-in*, so the two directions stay mutually compatible:
//!
//! * a **v1 client against a v2 server** never sets `FLAG_SPANS`,
//!   `FLAG_DEADLINE` or `FLAG_CREDITS`, so its frames carry no deadline
//!   word and the server answers with a status-0 frame — byte-identical
//!   to v1 (a deadline-less lane is also never shed on deadline
//!   grounds, and a credit-less request is never paced);
//! * a **v2 client against a v1 server** sets flag bits the old server
//!   ignores and gets a status-0 frame back, which the v2 decoder
//!   still accepts (span absent, nothing shed, no credit hint —
//!   [`decode_with_credit`] reports `None` and the client simply stays
//!   unpaced).
//!
//! The one caveat: a v2 client that sets `FLAG_DEADLINE` against a v1
//! server would have its deadline word read as payload — deadline use
//! therefore requires a v2 server, exactly like `OP_STATS` does.
//! `FLAG_CREDITS` has no such caveat (it adds no request bytes, only
//! asks the server to wrap its response), so a credits-on client
//! degrades gracefully against a v1 server.
//! `FLAG_PIPELINE` adds request bytes (the stage list) and therefore
//! needs a peer that knows it — the routing gateway. A plain server
//! parses the stage list but refuses to chain (it answers with a
//! protocol `Err` directing the client at the gateway), so the bytes
//! are never misread as payload.
//! `tests/trace_protocol.rs` pins both directions.
//!
//! Deadlines are *relative* (microseconds from server receipt), so no
//! client/server clock synchronisation is needed — the deadline clock
//! starts when the request frame lands, mirroring how the paper's
//! latency decomposition anchors on the receive boundary (§III-B).

use anyhow::{bail, Result};

use crate::metrics::telemetry::{HistoSnap, MetricsReport, Sample, Snapshot, N_BUCKETS};
use crate::trace::wire::decode_span_block;
use crate::trace::{SpanBlock, SpanRec};

use super::executor::{
    CreditHint, ExecStats, LaneStats, ShedReason, N_SEAL_REASONS, N_SHED_REASONS,
};

/// Request opcode: run inference (the v1 opcode).
pub const OP_INFER: u8 = 1;
/// Request opcode (v2): snapshot the executor's per-lane counters.
/// Frame is the 4-byte header only (`[OP_STATS][0][0][0]`).
pub const OP_STATS: u8 = 2;
/// Request opcode (v2): ask for a model's per-request tensor shape —
/// `[OP_SHAPE][0][0][name_len][name]`, answered with a v1 Ok frame
/// whose payload is `[in_elems u32 LE][out_elems u32 LE]`. The routing
/// gateway uses it to size the inter-stage tensor bridge of a
/// pipeline chain without loading the manifest itself.
pub const OP_SHAPE: u8 = 3;
/// Request opcode (v2): snapshot the always-on telemetry plane — the
/// metric registry plus the sampler ring. Frame is the 4-byte header
/// only (`[OP_METRICS][0][0][0]`), answered with a status-7 frame.
/// Like `OP_STATS`, a gateway answers it with the fleet-merged view.
pub const OP_METRICS: u8 = 4;
/// flags bit 0: payload is a raw uint8 camera frame (server preprocesses).
pub const FLAG_RAW: u8 = 1;
/// flags bit 1 (v2): client asks for the span timeline in the response.
pub const FLAG_SPANS: u8 = 2;
/// flags bit 2 (v2): a `deadline_us` word follows the model name — the
/// request's SLO budget, relative microseconds from server receipt.
pub const FLAG_DEADLINE: u8 = 4;
/// flags bit 3 (v2): the client wants proactive-backpressure hints —
/// the server wraps its response in the status-5 credit envelope
/// (adds no request bytes, so it is safe against a v1 server, which
/// simply ignores the bit and answers unwrapped).
pub const FLAG_CREDITS: u8 = 8;
/// flags bit 4 (v2): the request is a pipeline chain — an ordered
/// stage list follows the name (and the deadline word, when both flags
/// are set): `[n u8]` then `n` × `[len u8][stage name]`, the models of
/// stages 1..; the header's `model` field is stage 0. Chaining is the
/// routing gateway's job ([`Response::Pipeline`] comes back); a plain
/// server answers such a request with a protocol `Err`.
pub const FLAG_PIPELINE: u8 = 16;
/// Total stage cap for a pipeline chain (head model + listed stages).
/// Small on purpose: the gateway re-buffers every inter-stage tensor.
pub const MAX_PIPELINE_STAGES: usize = 8;
/// Stats response wire version (2 added `svc_ns` + shed counters and
/// the sixth seal reason; v1 frames are rejected, stats are advisory).
pub const STATS_VER: u8 = 2;
/// Credit-envelope wire version ([`encode_with_credit`]).
pub const CREDIT_VER: u8 = 1;
/// Metrics response wire version ([`Response::Metrics`]).
pub const METRICS_VER: u8 = 1;

/// A parsed inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub model: String,
    pub raw: bool,
    /// Ask the server to return the request's span timeline (v2).
    pub spans: bool,
    pub prio: u8,
    /// SLO budget in microseconds from server receipt (v2, opt-in via
    /// [`FLAG_DEADLINE`]). `None` keeps the frame byte-identical to v1.
    pub deadline_us: Option<u64>,
    /// Ask the server for credit/pacing hints ([`FLAG_CREDITS`], v2):
    /// the response comes back wrapped in the status-5 envelope. `false`
    /// keeps both directions byte-identical to v1.
    pub credits: bool,
    /// Pipeline chain: the models of stages 1.. ([`FLAG_PIPELINE`],
    /// v2); `model` above is stage 0. Empty keeps the frame
    /// byte-identical to v1.
    pub pipeline: Vec<String>,
    pub payload: Vec<u8>,
}

/// Request header fields without the payload: what the zero-copy
/// receive path parses in place, leaving the payload bytes untouched
/// inside the transport's registered region.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestMeta {
    pub model: String,
    pub raw: bool,
    /// The client set [`FLAG_SPANS`].
    pub spans: bool,
    pub prio: u8,
    /// The client set [`FLAG_DEADLINE`]: SLO budget in µs from receipt.
    pub deadline_us: Option<u64>,
    /// The client set [`FLAG_CREDITS`]: wrap the response in the
    /// credit envelope.
    pub credits: bool,
    /// The client set [`FLAG_PIPELINE`]: the models of stages 1..
    /// (stage 0 is `model`). Empty means no pipeline.
    pub pipeline: Vec<String>,
}

/// Encode a stats request frame (v2): header only, no payload.
pub fn encode_stats_request() -> Vec<u8> {
    vec![OP_STATS, 0, 0, 0]
}

/// Encode a metrics request frame (v2): header only, no payload.
pub fn encode_metrics_request() -> Vec<u8> {
    vec![OP_METRICS, 0, 0, 0]
}

/// Encode a shape request frame (v2): header carrying the model name,
/// no payload.
pub fn encode_shape_request(model: &str) -> Vec<u8> {
    let name = model.as_bytes();
    assert!(name.len() <= u8::MAX as usize, "model name too long");
    let mut buf = Vec::with_capacity(4 + name.len());
    buf.extend_from_slice(&[OP_SHAPE, 0, 0, name.len() as u8]);
    buf.extend_from_slice(name);
    buf
}

/// Parse a shape request frame back into the model name (server side).
pub fn decode_shape_request(buf: &[u8]) -> Result<String> {
    if buf.len() < 4 || buf[0] != OP_SHAPE {
        bail!("not a shape request");
    }
    let name_len = buf[3] as usize;
    if buf.len() != 4 + name_len || name_len == 0 {
        bail!("malformed shape request ({} bytes, name_len {name_len})", buf.len());
    }
    Ok(std::str::from_utf8(&buf[4..])?.to_string())
}

/// Payload of a shape response: `[in_elems u32 LE][out_elems u32 LE]`
/// inside a plain v1 Ok frame.
pub fn shape_payload(in_elems: usize, out_elems: usize) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8);
    buf.extend_from_slice(&(in_elems as u32).to_le_bytes());
    buf.extend_from_slice(&(out_elems as u32).to_le_bytes());
    buf
}

/// Parse a shape-response payload back into `(in_elems, out_elems)`.
pub fn parse_shape_payload(buf: &[u8]) -> Result<(usize, usize)> {
    if buf.len() != 8 {
        bail!("shape payload must be 8 bytes, got {}", buf.len());
    }
    let in_elems = u32::from_le_bytes(buf[0..4].try_into().expect("4 bytes")) as usize;
    let out_elems = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes")) as usize;
    Ok((in_elems, out_elems))
}

/// Opcode of a request frame (for dispatch before full parsing).
pub fn request_opcode(buf: &[u8]) -> Result<u8> {
    match buf.first() {
        Some(&op) => Ok(op),
        None => bail!("empty request frame"),
    }
}

/// Parse the request header from a frame, returning the metadata and
/// the byte offset where the payload starts.
pub fn split_header(buf: &[u8]) -> Result<(RequestMeta, usize)> {
    if buf.len() < 4 {
        bail!("short request frame: {} bytes", buf.len());
    }
    if buf[0] != OP_INFER {
        bail!("unknown opcode {}", buf[0]);
    }
    let name_len = buf[3] as usize;
    if buf.len() < 4 + name_len {
        bail!("truncated model name");
    }
    let model = std::str::from_utf8(&buf[4..4 + name_len])?.to_string();
    let mut at = 4 + name_len;
    let deadline_us = if buf[1] & FLAG_DEADLINE != 0 {
        if buf.len() < at + 8 {
            bail!("truncated deadline word");
        }
        let us = u64::from_le_bytes(buf[at..at + 8].try_into().expect("8 bytes"));
        at += 8;
        Some(us)
    } else {
        None
    };
    let pipeline = if buf[1] & FLAG_PIPELINE != 0 {
        let n = *buf
            .get(at)
            .ok_or_else(|| anyhow::anyhow!("truncated pipeline stage count"))?
            as usize;
        at += 1;
        if n == 0 {
            bail!("empty pipeline stage list");
        }
        if 1 + n > MAX_PIPELINE_STAGES {
            bail!("pipeline of {} stages exceeds cap {MAX_PIPELINE_STAGES}", 1 + n);
        }
        let mut stages = Vec::with_capacity(n);
        for k in 0..n {
            let len = *buf
                .get(at)
                .ok_or_else(|| anyhow::anyhow!("pipeline truncated at stage {k}"))?
                as usize;
            at += 1;
            if len == 0 {
                bail!("pipeline stage {k} has an empty model name");
            }
            if buf.len() < at + len {
                bail!("pipeline truncated inside stage {k} name");
            }
            let stage = std::str::from_utf8(&buf[at..at + len])?.to_string();
            at += len;
            if stage == model || stages.contains(&stage) {
                bail!("duplicate pipeline stage {stage:?}");
            }
            stages.push(stage);
        }
        stages
    } else {
        Vec::new()
    };
    Ok((
        RequestMeta {
            model,
            raw: buf[1] & FLAG_RAW != 0,
            spans: buf[1] & FLAG_SPANS != 0,
            prio: buf[2],
            deadline_us,
            credits: buf[1] & FLAG_CREDITS != 0,
            pipeline,
        },
        at,
    ))
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let name = self.model.as_bytes();
        assert!(name.len() <= u8::MAX as usize, "model name too long");
        let mut buf = Vec::with_capacity(12 + name.len() + self.payload.len());
        buf.push(OP_INFER);
        let mut flags = 0u8;
        if self.raw {
            flags |= FLAG_RAW;
        }
        if self.spans {
            flags |= FLAG_SPANS;
        }
        if self.deadline_us.is_some() {
            flags |= FLAG_DEADLINE;
        }
        if self.credits {
            flags |= FLAG_CREDITS;
        }
        if !self.pipeline.is_empty() {
            flags |= FLAG_PIPELINE;
        }
        buf.push(flags);
        buf.push(self.prio);
        buf.push(name.len() as u8);
        buf.extend_from_slice(name);
        if let Some(us) = self.deadline_us {
            buf.extend_from_slice(&us.to_le_bytes());
        }
        if !self.pipeline.is_empty() {
            assert!(
                1 + self.pipeline.len() <= MAX_PIPELINE_STAGES,
                "pipeline too long"
            );
            buf.push(self.pipeline.len() as u8);
            for stage in &self.pipeline {
                let s = stage.as_bytes();
                assert!(
                    !s.is_empty() && s.len() <= u8::MAX as usize,
                    "bad pipeline stage name"
                );
                buf.push(s.len() as u8);
                buf.extend_from_slice(s);
            }
        }
        buf.extend_from_slice(&self.payload);
        buf
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let (meta, payload_off) = split_header(buf)?;
        Ok(Request {
            model: meta.model,
            raw: meta.raw,
            spans: meta.spans,
            prio: meta.prio,
            deadline_us: meta.deadline_us,
            credits: meta.credits,
            pipeline: meta.pipeline,
            payload: buf[payload_off..].to_vec(),
        })
    }
}

/// Server-side stage timings reported with every response, the live
/// analogue of the paper's fine-grained pipeline profiling (§III-B).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageNs {
    /// Time queued before an execution stream picked the request up.
    pub queue_ns: u64,
    /// GPU/PJRT preprocessing time (raw inputs only).
    pub preproc_ns: u64,
    /// Inference execution time.
    pub infer_ns: u64,
}

impl StageNs {
    pub fn total(&self) -> u64 {
        self.queue_ns + self.preproc_ns + self.infer_ns
    }
}

/// A parsed response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Inference result. `span` is present iff the client asked for the
    /// timeline ([`FLAG_SPANS`]) *and* the server speaks v2 — its
    /// presence selects the status-2 encoding, its absence the
    /// v1-identical status-0 encoding.
    Ok {
        stages: StageNs,
        span: Option<SpanBlock>,
        payload: Vec<u8>,
    },
    Err(String),
    /// Executor per-lane counter snapshot (v2, answer to [`OP_STATS`]).
    Stats(ExecStats),
    /// Admission control rejected the request up front (v2): the lane
    /// was over its queue cap or the deadline was already unwinnable.
    /// Distinct from [`Response::Err`] so clients can tell load
    /// shedding (retry later / downgrade SLO) from real failures.
    Shed { reason: ShedReason, msg: String },
    /// Result of a pipeline chain (v2, answer to a [`FLAG_PIPELINE`]
    /// request): per-stage timing records on the *gateway's* clock plus
    /// the final stage's output tensor. One clock for every stage is
    /// what lets a client prove the chain never round-tripped through
    /// it: stage K's `recv_ns` ≤ stage K+1's `sent_ns`, gap owned
    /// entirely by the gateway-side bridge.
    Pipeline {
        stages: Vec<PipelineStage>,
        payload: Vec<u8>,
    },
    /// Telemetry-plane snapshot + sample ring (v2, answer to
    /// [`OP_METRICS`]). A gateway answers with the fleet-merged
    /// snapshot and an empty ring.
    Metrics(MetricsReport),
}

/// One chained stage's record inside [`Response::Pipeline`]: when the
/// gateway dispatched it (`sent_ns`) and got its reply (`recv_ns`),
/// both as ns offsets from the gateway's receipt of the client
/// request, plus the stage's own server span block (empty when the
/// client didn't ask for spans).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineStage {
    pub model: String,
    pub sent_ns: u64,
    pub recv_ns: u64,
    pub span: SpanBlock,
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Ok {
                stages,
                span,
                payload,
            } => {
                let mut buf = Vec::with_capacity(25 + payload.len());
                buf.push(if span.is_some() { 2u8 } else { 0u8 });
                buf.extend_from_slice(&stages.queue_ns.to_le_bytes());
                buf.extend_from_slice(&stages.preproc_ns.to_le_bytes());
                buf.extend_from_slice(&stages.infer_ns.to_le_bytes());
                if let Some(block) = span {
                    buf.extend_from_slice(&block.encode());
                }
                buf.extend_from_slice(payload);
                buf
            }
            Response::Err(msg) => {
                let mut buf = Vec::with_capacity(1 + msg.len());
                buf.push(1u8);
                buf.extend_from_slice(msg.as_bytes());
                buf
            }
            Response::Stats(stats) => encode_stats(stats),
            Response::Shed { reason, msg } => {
                let mut buf = Vec::with_capacity(2 + msg.len());
                buf.push(4u8);
                buf.push(reason.code());
                buf.extend_from_slice(msg.as_bytes());
                buf
            }
            Response::Pipeline { stages, payload } => {
                let mut buf = Vec::with_capacity(2 + stages.len() * 32 + payload.len());
                buf.push(6u8);
                assert!(
                    stages.len() >= 2 && stages.len() <= MAX_PIPELINE_STAGES,
                    "pipeline response needs 2..={MAX_PIPELINE_STAGES} stages"
                );
                buf.push(stages.len() as u8);
                for st in stages {
                    let name = st.model.as_bytes();
                    assert!(
                        !name.is_empty() && name.len() <= u8::MAX as usize,
                        "bad stage model name"
                    );
                    buf.push(name.len() as u8);
                    buf.extend_from_slice(name);
                    buf.extend_from_slice(&st.sent_ns.to_le_bytes());
                    buf.extend_from_slice(&st.recv_ns.to_le_bytes());
                    buf.extend_from_slice(&st.span.encode());
                }
                buf.extend_from_slice(payload);
                buf
            }
            Response::Metrics(report) => encode_metrics(report),
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        if buf.is_empty() {
            bail!("empty response frame");
        }
        match buf[0] {
            status @ (0 | 2) => {
                if buf.len() < 25 {
                    bail!("short ok response");
                }
                let u = |i: usize| {
                    u64::from_le_bytes(buf[i..i + 8].try_into().expect("8 bytes"))
                };
                let stages = StageNs {
                    queue_ns: u(1),
                    preproc_ns: u(9),
                    infer_ns: u(17),
                };
                let (span, payload_off) = if status == 2 {
                    let (block, used) = decode_span_block(&buf[25..])?;
                    (Some(block), 25 + used)
                } else {
                    (None, 25)
                };
                Ok(Response::Ok {
                    stages,
                    span,
                    payload: buf[payload_off..].to_vec(),
                })
            }
            1 => Ok(Response::Err(
                String::from_utf8_lossy(&buf[1..]).to_string(),
            )),
            3 => Ok(Response::Stats(decode_stats(buf)?)),
            4 => {
                if buf.len() < 2 {
                    bail!("short shed response");
                }
                let reason = ShedReason::from_code(buf[1])
                    .ok_or_else(|| anyhow::anyhow!("unknown shed reason {}", buf[1]))?;
                Ok(Response::Shed {
                    reason,
                    msg: String::from_utf8_lossy(&buf[2..]).to_string(),
                })
            }
            6 => {
                if buf.len() < 2 {
                    bail!("short pipeline response");
                }
                let n = buf[1] as usize;
                if !(2..=MAX_PIPELINE_STAGES).contains(&n) {
                    bail!("pipeline response claims {n} stages (want 2..={MAX_PIPELINE_STAGES})");
                }
                let mut at = 2usize;
                let mut stages: Vec<PipelineStage> = Vec::with_capacity(n);
                for k in 0..n {
                    let name_len = *buf
                        .get(at)
                        .ok_or_else(|| anyhow::anyhow!("pipeline response truncated at stage {k}"))?
                        as usize;
                    at += 1;
                    if name_len == 0 {
                        bail!("pipeline response stage {k} has an empty model name");
                    }
                    if buf.len() < at + name_len + 16 {
                        bail!("pipeline response truncated inside stage {k}");
                    }
                    let model = std::str::from_utf8(&buf[at..at + name_len])?.to_string();
                    at += name_len;
                    let sent_ns =
                        u64::from_le_bytes(buf[at..at + 8].try_into().expect("8 bytes"));
                    let recv_ns =
                        u64::from_le_bytes(buf[at + 8..at + 16].try_into().expect("8 bytes"));
                    at += 16;
                    let (span, used) = decode_span_block(&buf[at..])?;
                    at += used;
                    if sent_ns > recv_ns {
                        bail!("pipeline stage {k} sent after its reply ({sent_ns} > {recv_ns})");
                    }
                    if let Some(prev) = stages.last() {
                        if sent_ns < prev.recv_ns {
                            bail!(
                                "pipeline stage {k} dispatched before stage {} replied",
                                k - 1
                            );
                        }
                    }
                    stages.push(PipelineStage {
                        model,
                        sent_ns,
                        recv_ns,
                        span,
                    });
                }
                Ok(Response::Pipeline {
                    stages,
                    payload: buf[at..].to_vec(),
                })
            }
            7 => Ok(Response::Metrics(decode_metrics(buf)?)),
            s => bail!("unknown response status {s}"),
        }
    }
}

/// Convert a live span record into the decoded-block form carried by
/// [`Response::Ok`] (what the server attaches before encoding).
pub fn span_to_block(span: &SpanRec) -> SpanBlock {
    SpanBlock::of(span)
}

/// Byte length of the credit-envelope header:
/// `[5][ver][credits u16][pace_ns u64]`.
const CREDIT_HDR: usize = 12;

/// Encode a response, wrapping it in the status-5 credit envelope when
/// a hint is attached (the server's answer to a [`FLAG_CREDITS`]
/// request). With `hint == None` this is exactly [`Response::encode`],
/// so flag-off traffic stays byte-identical to v1.
pub fn encode_with_credit(resp: &Response, hint: Option<CreditHint>) -> Vec<u8> {
    let inner = resp.encode();
    let Some(h) = hint else { return inner };
    let mut buf = Vec::with_capacity(CREDIT_HDR + inner.len());
    buf.push(5u8);
    buf.push(CREDIT_VER);
    buf.extend_from_slice(&h.credits.to_le_bytes());
    buf.extend_from_slice(&h.pace_ns.to_le_bytes());
    buf.extend_from_slice(&inner);
    buf
}

/// Decode a response that may carry the status-5 credit envelope. A
/// bare (v1 or unwrapped v2) frame decodes with `None` — what a
/// credits-on client sees from a v1 server, degrading to unpaced. The
/// envelope is rejected when truncated (cut inside the header or with
/// no inner frame), on an unknown version, and when nested (the inner
/// frame's status 5 is unknown to [`Response::decode`]).
pub fn decode_with_credit(buf: &[u8]) -> Result<(Response, Option<CreditHint>)> {
    if buf.first() != Some(&5u8) {
        return Ok((Response::decode(buf)?, None));
    }
    if buf.len() <= CREDIT_HDR {
        bail!("truncated credit envelope: {} bytes", buf.len());
    }
    if buf[1] != CREDIT_VER {
        bail!("unknown credit envelope version {}", buf[1]);
    }
    let credits = u16::from_le_bytes(buf[2..4].try_into().expect("2 bytes"));
    let pace_ns = u64::from_le_bytes(buf[4..12].try_into().expect("8 bytes"));
    let inner = Response::decode(&buf[CREDIT_HDR..])?;
    Ok((inner, Some(CreditHint { credits, pace_ns })))
}

/// Encode an [`ExecStats`] snapshot as a status-3 frame.
fn encode_stats(stats: &ExecStats) -> Vec<u8> {
    let mut buf = Vec::with_capacity(11 + stats.lanes.len() * 64);
    buf.push(3u8);
    buf.push(STATS_VER);
    buf.extend_from_slice(&stats.interleaves.to_le_bytes());
    assert!(stats.lanes.len() <= u8::MAX as usize, "too many lanes");
    buf.push(stats.lanes.len() as u8);
    for lane in &stats.lanes {
        let name = lane.model.as_bytes();
        assert!(name.len() <= u8::MAX as usize, "model name too long");
        buf.push(name.len() as u8);
        buf.extend_from_slice(name);
        buf.extend_from_slice(&lane.jobs.to_le_bytes());
        buf.extend_from_slice(&lane.calls.to_le_bytes());
        buf.extend_from_slice(&lane.svc_ns.to_le_bytes());
        buf.extend_from_slice(&lane.depth.to_le_bytes());
        for &s in &lane.sealed {
            buf.extend_from_slice(&s.to_le_bytes());
        }
        for &s in &lane.shed {
            buf.extend_from_slice(&s.to_le_bytes());
        }
    }
    buf
}

/// Decode a status-3 stats frame (rejects truncation and bad versions).
fn decode_stats(buf: &[u8]) -> Result<ExecStats> {
    if buf.len() < 11 {
        bail!("short stats response: {} bytes", buf.len());
    }
    if buf[1] != STATS_VER {
        bail!("unknown stats version {}", buf[1]);
    }
    let interleaves = u64::from_le_bytes(buf[2..10].try_into().expect("8 bytes"));
    let n_lanes = buf[10] as usize;
    let mut at = 11usize;
    let mut lanes = Vec::with_capacity(n_lanes);
    for k in 0..n_lanes {
        let name_len = *buf
            .get(at)
            .ok_or_else(|| anyhow::anyhow!("stats truncated at lane {k}"))?
            as usize;
        at += 1;
        let fixed = 8 + 8 + 8 + 4 + 8 * N_SEAL_REASONS + 8 * N_SHED_REASONS;
        if buf.len() < at + name_len + fixed {
            bail!("stats truncated inside lane {k}");
        }
        let model = std::str::from_utf8(&buf[at..at + name_len])?.to_string();
        at += name_len;
        let u64_at = |i: usize| u64::from_le_bytes(buf[i..i + 8].try_into().expect("8 bytes"));
        let jobs = u64_at(at);
        let calls = u64_at(at + 8);
        let svc_ns = u64_at(at + 16);
        let depth = u32::from_le_bytes(buf[at + 24..at + 28].try_into().expect("4 bytes"));
        at += 28;
        let mut sealed = [0u64; N_SEAL_REASONS];
        for s in sealed.iter_mut() {
            *s = u64_at(at);
            at += 8;
        }
        let mut shed = [0u64; N_SHED_REASONS];
        for s in shed.iter_mut() {
            *s = u64_at(at);
            at += 8;
        }
        lanes.push(LaneStats {
            model,
            jobs,
            calls,
            svc_ns,
            depth,
            sealed,
            shed,
        });
    }
    if at != buf.len() {
        bail!("stats frame has {} trailing bytes", buf.len() - at);
    }
    Ok(ExecStats { interleaves, lanes })
}

/// Encode a [`MetricsReport`] as a status-7 frame:
///
/// ```text
/// [7][METRICS_VER]
/// [nc u16 LE] then nc × [name_len u8][name][value u64]      counters
/// [ng u16]    then ng × [name_len u8][name][value u64]      gauges
/// [nh u16]    then nh × [name_len u8][name][count u64]
///               [sum u64][nb u8] then nb × [idx u8][c u64]  histograms
/// [ns u16]    then ns × [at_ms u64][counter list][gauge list] samples
/// ```
///
/// Histogram buckets travel sparse (only non-zero buckets, indices
/// strictly increasing into the shared [`N_BUCKETS`] layout) because a
/// live histogram typically populates a narrow band of the 128-bucket
/// range.
fn encode_metrics(report: &MetricsReport) -> Vec<u8> {
    fn push_kv(buf: &mut Vec<u8>, kvs: &[(String, u64)]) {
        assert!(kvs.len() <= u16::MAX as usize, "too many series");
        buf.extend_from_slice(&(kvs.len() as u16).to_le_bytes());
        for (name, v) in kvs {
            let n = name.as_bytes();
            assert!(!n.is_empty() && n.len() <= u8::MAX as usize, "bad series name");
            buf.push(n.len() as u8);
            buf.extend_from_slice(n);
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut buf = Vec::with_capacity(64 + report.snap.histos.len() * 128);
    buf.push(7u8);
    buf.push(METRICS_VER);
    push_kv(&mut buf, &report.snap.counters);
    push_kv(&mut buf, &report.snap.gauges);
    assert!(report.snap.histos.len() <= u16::MAX as usize, "too many histograms");
    buf.extend_from_slice(&(report.snap.histos.len() as u16).to_le_bytes());
    for (name, h) in &report.snap.histos {
        let n = name.as_bytes();
        assert!(!n.is_empty() && n.len() <= u8::MAX as usize, "bad histogram name");
        buf.push(n.len() as u8);
        buf.extend_from_slice(n);
        buf.extend_from_slice(&h.count.to_le_bytes());
        buf.extend_from_slice(&h.sum.to_le_bytes());
        let nonzero: Vec<(usize, u64)> = h
            .buckets
            .iter()
            .enumerate()
            .take(N_BUCKETS)
            .filter(|(_, &c)| c != 0)
            .map(|(i, &c)| (i, c))
            .collect();
        assert!(nonzero.len() <= u8::MAX as usize, "bucket list too long");
        buf.push(nonzero.len() as u8);
        for (i, c) in nonzero {
            buf.push(i as u8);
            buf.extend_from_slice(&c.to_le_bytes());
        }
    }
    assert!(report.ring.len() <= u16::MAX as usize, "sample ring too long");
    buf.extend_from_slice(&(report.ring.len() as u16).to_le_bytes());
    for s in &report.ring {
        buf.extend_from_slice(&s.at_ms.to_le_bytes());
        push_kv(&mut buf, &s.counters);
        push_kv(&mut buf, &s.gauges);
    }
    buf
}

/// Decode a status-7 metrics frame (rejects truncation anywhere, bad
/// versions, out-of-range or non-increasing bucket indices, and
/// trailing bytes).
fn decode_metrics(buf: &[u8]) -> Result<MetricsReport> {
    fn read_u16(buf: &[u8], at: &mut usize) -> Result<usize> {
        if buf.len() < *at + 2 {
            bail!("metrics frame truncated at a list count");
        }
        let v = u16::from_le_bytes(buf[*at..*at + 2].try_into().expect("2 bytes")) as usize;
        *at += 2;
        Ok(v)
    }
    fn read_u64(buf: &[u8], at: &mut usize) -> Result<u64> {
        if buf.len() < *at + 8 {
            bail!("metrics frame truncated at a u64 word");
        }
        let v = u64::from_le_bytes(buf[*at..*at + 8].try_into().expect("8 bytes"));
        *at += 8;
        Ok(v)
    }
    fn read_name(buf: &[u8], at: &mut usize) -> Result<String> {
        let len = *buf
            .get(*at)
            .ok_or_else(|| anyhow::anyhow!("metrics frame truncated at a name length"))?
            as usize;
        *at += 1;
        if len == 0 {
            bail!("metrics frame has an empty series name");
        }
        if buf.len() < *at + len {
            bail!("metrics frame truncated inside a series name");
        }
        let name = std::str::from_utf8(&buf[*at..*at + len])?.to_string();
        *at += len;
        Ok(name)
    }
    fn read_kv(buf: &[u8], at: &mut usize) -> Result<Vec<(String, u64)>> {
        let n = read_u16(buf, at)?;
        let mut out = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let name = read_name(buf, at)?;
            let v = read_u64(buf, at)?;
            out.push((name, v));
        }
        Ok(out)
    }

    if buf.len() < 2 {
        bail!("short metrics response: {} bytes", buf.len());
    }
    if buf[1] != METRICS_VER {
        bail!("unknown metrics version {}", buf[1]);
    }
    let mut at = 2usize;
    let counters = read_kv(buf, &mut at)?;
    let gauges = read_kv(buf, &mut at)?;
    let nh = read_u16(buf, &mut at)?;
    let mut histos = Vec::with_capacity(nh.min(1024));
    for _ in 0..nh {
        let name = read_name(buf, &mut at)?;
        let count = read_u64(buf, &mut at)?;
        let sum = read_u64(buf, &mut at)?;
        let nb = *buf
            .get(at)
            .ok_or_else(|| anyhow::anyhow!("metrics frame truncated at a bucket count"))?
            as usize;
        at += 1;
        let mut buckets = vec![0u64; N_BUCKETS];
        let mut prev: Option<usize> = None;
        for _ in 0..nb {
            let idx = *buf
                .get(at)
                .ok_or_else(|| anyhow::anyhow!("metrics frame truncated at a bucket index"))?
                as usize;
            at += 1;
            if idx >= N_BUCKETS {
                bail!("histogram bucket index {idx} out of range");
            }
            if let Some(p) = prev {
                if idx <= p {
                    bail!("histogram bucket indices must strictly increase");
                }
            }
            prev = Some(idx);
            buckets[idx] = read_u64(buf, &mut at)?;
        }
        histos.push((name, HistoSnap { count, sum, buckets }));
    }
    let ns = read_u16(buf, &mut at)?;
    let mut ring = Vec::with_capacity(ns.min(1024));
    for _ in 0..ns {
        let at_ms = read_u64(buf, &mut at)?;
        let counters = read_kv(buf, &mut at)?;
        let gauges = read_kv(buf, &mut at)?;
        ring.push(Sample { at_ms, counters, gauges });
    }
    if at != buf.len() {
        bail!("metrics frame has {} trailing bytes", buf.len() - at);
    }
    Ok(MetricsReport {
        snap: Snapshot { counters, gauges, histos },
        ring,
    })
}

/// f32 slice -> LE bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// LE bytes -> f32 vec.
pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        bail!("payload not f32-aligned: {} bytes", b.len());
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            model: "tiny_resnet".into(),
            raw: true,
            spans: false,
            prio: 7,
            deadline_us: None,
            credits: false,
            pipeline: vec![],
            payload: vec![1, 2, 3, 4, 5],
        };
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        let with_spans = Request {
            spans: true,
            ..r.clone()
        };
        assert_eq!(Request::decode(&with_spans.encode()).unwrap(), with_spans);
        let with_deadline = Request {
            deadline_us: Some(2_500),
            ..r.clone()
        };
        let frame = with_deadline.encode();
        assert_eq!(frame[1] & FLAG_DEADLINE, FLAG_DEADLINE);
        assert_eq!(Request::decode(&frame).unwrap(), with_deadline);
        // Without the flag the frame is byte-identical to v1: exactly
        // 8 bytes (the deadline word) shorter, same payload tail.
        assert_eq!(frame.len(), r.encode().len() + 8);
        // FLAG_CREDITS adds the flag bit and nothing else — same length,
        // same bytes everywhere but the flags byte.
        let with_credits = Request {
            credits: true,
            ..r.clone()
        };
        let cframe = with_credits.encode();
        assert_eq!(cframe[1] & FLAG_CREDITS, FLAG_CREDITS);
        assert_eq!(Request::decode(&cframe).unwrap(), with_credits);
        assert_eq!(cframe.len(), r.encode().len());
        assert_eq!(&cframe[2..], &r.encode()[2..]);
    }

    #[test]
    fn split_header_matches_decode() {
        let r = Request {
            model: "tiny_mobilenet".into(),
            raw: false,
            spans: true,
            prio: 3,
            deadline_us: Some(1_000),
            credits: true,
            pipeline: vec![],
            payload: vec![9; 12],
        };
        let frame = r.encode();
        let (meta, off) = split_header(&frame).unwrap();
        assert_eq!(meta.model, "tiny_mobilenet");
        assert!(!meta.raw);
        assert!(meta.spans);
        assert_eq!(meta.prio, 3);
        assert_eq!(meta.deadline_us, Some(1_000));
        assert!(meta.credits);
        assert_eq!(&frame[off..], &r.payload[..]);
        assert!(split_header(&[]).is_err());
        // A frame cut inside the deadline word is rejected, not read
        // into the payload.
        let header_end = 4 + "tiny_mobilenet".len();
        assert!(split_header(&frame[..header_end + 4]).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Ok {
            stages: StageNs {
                queue_ns: 123,
                preproc_ns: 456,
                infer_ns: 789,
            },
            span: None,
            payload: f32s_to_bytes(&[1.5, -2.25]),
        };
        let frame = r.encode();
        assert_eq!(frame[0], 0, "span-less Ok must stay a v1 status-0 frame");
        let d = Response::decode(&frame).unwrap();
        assert_eq!(d, r);
        if let Response::Ok {
            payload, stages, ..
        } = d
        {
            assert_eq!(bytes_to_f32s(&payload).unwrap(), vec![1.5, -2.25]);
            assert_eq!(stages.total(), 123 + 456 + 789);
        }
        let e = Response::Err("boom".into());
        assert_eq!(Response::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn shed_roundtrip_and_validation() {
        for reason in [ShedReason::QueueFull, ShedReason::Deadline] {
            let r = Response::Shed {
                reason,
                msg: format!("lane full ({reason:?})"),
            };
            let frame = r.encode();
            assert_eq!(frame[0], 4, "shed is a distinct status, not Err");
            assert_eq!(Response::decode(&frame).unwrap(), r);
        }
        // Truncated (no reason byte) and unknown reason codes rejected.
        assert!(Response::decode(&[4]).is_err());
        assert!(Response::decode(&[4, 99]).is_err());
        // An empty message is fine — the reason byte alone suffices.
        let bare = Response::Shed {
            reason: ShedReason::QueueFull,
            msg: String::new(),
        };
        assert_eq!(Response::decode(&bare.encode()).unwrap(), bare);
    }

    #[test]
    fn v2_response_carries_span_block() {
        let mut span = SpanRec::begin();
        span.mark(crate::trace::Stamp::RecvDone);
        span.mark(crate::trace::Stamp::InferDone);
        span.mark(crate::trace::Stamp::ReplySend);
        let block = span_to_block(&span);
        let r = Response::Ok {
            stages: StageNs::default(),
            span: Some(block.clone()),
            payload: f32s_to_bytes(&[7.5]),
        };
        let frame = r.encode();
        assert_eq!(frame[0], 2, "span selects the status-2 encoding");
        match Response::decode(&frame).unwrap() {
            Response::Ok { span, payload, .. } => {
                assert_eq!(span, Some(block));
                assert_eq!(bytes_to_f32s(&payload).unwrap(), vec![7.5]);
            }
            other => panic!("decoded {other:?}"),
        }
        // Truncating inside the span block must be rejected, not read
        // into the payload.
        assert!(Response::decode(&frame[..27]).is_err());
    }

    #[test]
    fn stats_roundtrip_and_validation() {
        let stats = ExecStats {
            interleaves: 42,
            lanes: vec![
                LaneStats {
                    model: "tiny_mobilenet".into(),
                    jobs: 100,
                    calls: 30,
                    svc_ns: 1_234_567,
                    depth: 3,
                    sealed: [1, 2, 3, 4, 5, 6],
                    shed: [7, 2],
                },
                LaneStats {
                    model: "tiny_resnet".into(),
                    jobs: 8,
                    calls: 8,
                    svc_ns: 99,
                    depth: 0,
                    sealed: [8, 0, 0, 0, 0, 0],
                    shed: [0, 0],
                },
            ],
        };
        let r = Response::Stats(stats.clone());
        let frame = r.encode();
        assert_eq!(frame[0], 3);
        assert_eq!(Response::decode(&frame).unwrap(), Response::Stats(stats));
        // Truncation anywhere inside the frame is rejected.
        for cut in 1..frame.len() {
            assert!(Response::decode(&frame[..cut]).is_err(), "cut {cut}");
        }
        // Trailing garbage is rejected.
        let mut long = frame.clone();
        long.push(0);
        assert!(Response::decode(&long).is_err());
        // Bad version is rejected.
        let mut bad = frame;
        bad[1] = 9;
        assert!(Response::decode(&bad).is_err());
    }

    #[test]
    fn stats_request_is_dispatchable() {
        let frame = encode_stats_request();
        assert_eq!(request_opcode(&frame).unwrap(), OP_STATS);
        // The v1 parser rejects it (unknown opcode), as a v1 server
        // would — the client surface treats that as "stats unsupported".
        assert!(split_header(&frame).is_err());
        assert!(request_opcode(&[]).is_err());
        let infer = Request {
            model: "m".into(),
            raw: false,
            spans: false,
            prio: 0,
            deadline_us: None,
            credits: false,
            pipeline: vec![],
            payload: vec![],
        }
        .encode();
        assert_eq!(request_opcode(&infer).unwrap(), OP_INFER);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[9, 0, 0, 0]).is_err());
        assert!(Request::decode(&[1, 0, 0, 200, 1, 2]).is_err());
        assert!(Response::decode(&[]).is_err());
        assert!(Response::decode(&[0, 1, 2]).is_err());
        assert!(Response::decode(&[7]).is_err());
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }

    #[test]
    fn credit_envelope_roundtrips_every_inner_status() {
        // The hint attaches uniformly: Ok, Err and Shed responses all
        // wrap and unwrap with the hint intact and the inner response
        // unchanged.
        let hint = CreditHint {
            credits: 3,
            pace_ns: 1_500_000,
        };
        let inners = [
            Response::Ok {
                stages: StageNs {
                    queue_ns: 1,
                    preproc_ns: 2,
                    infer_ns: 3,
                },
                span: None,
                payload: f32s_to_bytes(&[4.5]),
            },
            Response::Err("boom".into()),
            Response::Shed {
                reason: ShedReason::Deadline,
                msg: "unwinnable".into(),
            },
        ];
        for inner in inners {
            let frame = encode_with_credit(&inner, Some(hint));
            assert_eq!(frame[0], 5, "credit envelope is status 5");
            assert_eq!(frame[1], CREDIT_VER);
            let (got, got_hint) = decode_with_credit(&frame).unwrap();
            assert_eq!(got, inner);
            assert_eq!(got_hint, Some(hint));
            // The plain v1 decoder must NOT silently misread the
            // envelope — status 5 is an error to it, which is what
            // makes credits require explicit opt-in.
            assert!(Response::decode(&frame).is_err());
        }
    }

    #[test]
    fn credit_envelope_absent_means_byte_identical_frames() {
        // hint == None is a strict no-op: the exact bytes Response::
        // encode produces, accepted by both decoders, hint None.
        let inner = Response::Ok {
            stages: StageNs::default(),
            span: None,
            payload: f32s_to_bytes(&[1.0, 2.0]),
        };
        let frame = encode_with_credit(&inner, None);
        assert_eq!(frame, inner.encode());
        assert_eq!(frame[0], 0, "still a v1 status-0 frame");
        let (got, hint) = decode_with_credit(&frame).unwrap();
        assert_eq!(got, inner);
        assert_eq!(hint, None);
    }

    #[test]
    fn credit_envelope_rejects_truncation_version_and_nesting() {
        let inner = Response::Err("e".into());
        let hint = CreditHint {
            credits: 1,
            pace_ns: 7,
        };
        let frame = encode_with_credit(&inner, Some(hint));
        // Any cut inside the header or leaving no inner frame fails.
        for cut in 1..=12 {
            assert!(decode_with_credit(&frame[..cut]).is_err(), "cut {cut}");
        }
        // Unknown envelope version.
        let mut bad = frame.clone();
        bad[1] = 9;
        assert!(decode_with_credit(&bad).is_err());
        // A nested envelope is rejected, not recursed into.
        let nested = encode_with_credit(&inner, Some(hint));
        let mut outer = vec![5u8, CREDIT_VER, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0];
        outer.extend_from_slice(&nested);
        assert!(decode_with_credit(&outer).is_err());
    }

    #[test]
    fn pipeline_request_roundtrip_and_v1_byte_identity() {
        let plain = Request {
            model: "tiny_mobilenet".into(),
            raw: false,
            spans: false,
            prio: 0,
            deadline_us: None,
            credits: false,
            pipeline: vec![],
            payload: vec![7; 16],
        };
        let chained = Request {
            pipeline: vec!["tiny_segnet".into()],
            ..plain.clone()
        };
        let frame = chained.encode();
        assert_eq!(frame[1] & FLAG_PIPELINE, FLAG_PIPELINE);
        assert_eq!(Request::decode(&frame).unwrap(), chained);
        // Flag off → byte-identical to v1: the stage list (count byte +
        // len byte + name) is the only difference.
        let v1 = plain.encode();
        assert_eq!(v1[1] & FLAG_PIPELINE, 0);
        assert_eq!(frame.len(), v1.len() + 2 + "tiny_segnet".len());
        // Same header+name prefix (bar the flags byte) and same payload
        // tail — the stage list is the only insertion.
        let head = 4 + "tiny_mobilenet".len();
        assert_eq!(frame[2..head], v1[2..head]);
        assert_eq!(frame[frame.len() - 16..], v1[v1.len() - 16..]);
        assert_eq!(Request::decode(&v1).unwrap(), plain);
        // Stage list composes with the deadline word: deadline first,
        // then the stage list, then the payload.
        let both = Request {
            deadline_us: Some(5_000),
            pipeline: vec!["tiny_segnet".into(), "tiny_resnet".into()],
            ..plain.clone()
        };
        let bframe = both.encode();
        assert_eq!(bframe[1] & (FLAG_DEADLINE | FLAG_PIPELINE), FLAG_DEADLINE | FLAG_PIPELINE);
        assert_eq!(Request::decode(&bframe).unwrap(), both);
        let (meta, off) = split_header(&bframe).unwrap();
        assert_eq!(meta.pipeline, vec!["tiny_segnet", "tiny_resnet"]);
        assert_eq!(&bframe[off..], &both.payload[..]);
    }

    #[test]
    fn pipeline_stage_list_rejects_malformed() {
        let good = Request {
            model: "a".into(),
            raw: false,
            spans: false,
            prio: 0,
            deadline_us: None,
            credits: false,
            pipeline: vec!["b".into(), "c".into()],
            payload: vec![],
        }
        .encode();
        assert!(Request::decode(&good).is_ok());
        // Truncation anywhere inside the stage list is rejected — the
        // bytes must never be silently read as payload.
        for cut in 4..good.len() {
            assert!(Request::decode(&good[..cut]).is_err(), "cut {cut}");
        }
        // Empty stage list (flag set, count 0).
        let empty = vec![OP_INFER, FLAG_PIPELINE, 0, 1, b'a', 0];
        assert!(split_header(&empty).unwrap_err().to_string().contains("empty pipeline"));
        // Empty stage name.
        let noname = vec![OP_INFER, FLAG_PIPELINE, 0, 1, b'a', 1, 0];
        assert!(split_header(&noname).is_err());
        // Duplicate stage vs the head model and within the list.
        let dup_head = vec![OP_INFER, FLAG_PIPELINE, 0, 1, b'a', 1, 1, b'a'];
        assert!(split_header(&dup_head).unwrap_err().to_string().contains("duplicate"));
        let dup_list =
            vec![OP_INFER, FLAG_PIPELINE, 0, 1, b'a', 2, 1, b'b', 1, b'b'];
        assert!(split_header(&dup_list).unwrap_err().to_string().contains("duplicate"));
        // Over the stage cap.
        let mut long = vec![OP_INFER, FLAG_PIPELINE, 0, 1, b'a', MAX_PIPELINE_STAGES as u8];
        for k in 0..MAX_PIPELINE_STAGES {
            long.push(1);
            long.push(b'b' + k as u8);
        }
        assert!(split_header(&long).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn pipeline_response_roundtrip_and_validation() {
        let mut span = SpanRec::begin();
        span.mark(crate::trace::Stamp::RecvDone);
        span.mark(crate::trace::Stamp::InferDone);
        let block = span_to_block(&span);
        let r = Response::Pipeline {
            stages: vec![
                PipelineStage {
                    model: "tiny_mobilenet".into(),
                    sent_ns: 1_000,
                    recv_ns: 9_000,
                    span: block.clone(),
                },
                PipelineStage {
                    model: "tiny_segnet".into(),
                    sent_ns: 9_500,
                    recv_ns: 20_000,
                    span: SpanBlock::default(), // spans off → empty block
                },
            ],
            payload: f32s_to_bytes(&[1.0, 2.0, 3.0]),
        };
        let frame = r.encode();
        assert_eq!(frame[0], 6, "pipeline response is status 6");
        assert_eq!(Response::decode(&frame).unwrap(), r);
        // Truncation anywhere inside the stage records is rejected.
        let payload_start = frame.len() - 12;
        for cut in 1..payload_start {
            assert!(Response::decode(&frame[..cut]).is_err(), "cut {cut}");
        }
        // Fewer than two stages is not a chain.
        let mut one = frame.clone();
        one[1] = 1;
        assert!(Response::decode(&one).is_err());
        // Stage windows must be coherent on the gateway clock: a stage
        // replying before it was sent, or a later stage dispatched
        // before the earlier one replied, means a client round-trip
        // (or clock abuse) sneaked in — reject both.
        let backwards = Response::Pipeline {
            stages: vec![
                PipelineStage {
                    model: "a".into(),
                    sent_ns: 5,
                    recv_ns: 10,
                    span: SpanBlock::default(),
                },
                PipelineStage {
                    model: "b".into(),
                    sent_ns: 7, // dispatched before stage 0 replied
                    recv_ns: 30,
                    span: SpanBlock::default(),
                },
            ],
            payload: vec![],
        };
        assert!(Response::decode(&backwards.encode()).is_err());
    }

    #[test]
    fn metrics_roundtrip_and_validation() {
        use crate::metrics::telemetry::{labeled, Registry};
        let reg = Registry::new();
        reg.counter("accel_jobs_total").add(12);
        reg.counter(&labeled("accel_seal_total", "reason", "full")).add(3);
        reg.gauge("accel_queue_depth").set(5);
        let h = reg.histo(&labeled("accel_exec_ns", "model", "tiny_mobilenet"));
        for v in [150u64, 150, 9_000, 2_000_000] {
            h.observe(v);
        }
        let mut ring = crate::metrics::telemetry::SampleRing::new(4);
        ring.push(100, &reg.snapshot());
        reg.counter("accel_jobs_total").add(8);
        ring.push(200, &reg.snapshot());
        let report = MetricsReport {
            snap: reg.snapshot(),
            ring: ring.samples(),
        };

        let r = Response::Metrics(report.clone());
        let frame = r.encode();
        assert_eq!(frame[0], 7, "metrics response is status 7");
        assert_eq!(frame[1], METRICS_VER);
        assert_eq!(Response::decode(&frame).unwrap(), r);

        // Truncation anywhere inside the frame is rejected.
        for cut in 1..frame.len() {
            assert!(Response::decode(&frame[..cut]).is_err(), "cut {cut}");
        }
        // Trailing garbage is rejected.
        let mut long = frame.clone();
        long.push(0);
        assert!(Response::decode(&long).is_err());
        // Bad version is rejected.
        let mut bad = frame.clone();
        bad[1] = 9;
        assert!(Response::decode(&bad).is_err());

        // An empty report (fresh registry, no samples) round-trips too.
        let empty = Response::Metrics(MetricsReport::default());
        assert_eq!(Response::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn metrics_decode_rejects_bad_bucket_indices() {
        // Hand-build a frame with one histogram whose bucket index is
        // out of range, then one whose indices do not increase.
        fn base(bucket_bytes: &[u8]) -> Vec<u8> {
            let mut f = vec![7u8, METRICS_VER];
            f.extend_from_slice(&0u16.to_le_bytes()); // counters
            f.extend_from_slice(&0u16.to_le_bytes()); // gauges
            f.extend_from_slice(&1u16.to_le_bytes()); // one histogram
            f.push(1);
            f.push(b'h');
            f.extend_from_slice(&2u64.to_le_bytes()); // count
            f.extend_from_slice(&10u64.to_le_bytes()); // sum
            f.extend_from_slice(bucket_bytes);
            f.extend_from_slice(&0u16.to_le_bytes()); // samples
            f
        }
        let mut out_of_range = vec![1u8, N_BUCKETS as u8];
        out_of_range.extend_from_slice(&2u64.to_le_bytes());
        assert!(Response::decode(&base(&out_of_range)).is_err());
        let mut dup = vec![2u8, 5];
        dup.extend_from_slice(&1u64.to_le_bytes());
        dup.push(5);
        dup.extend_from_slice(&1u64.to_le_bytes());
        assert!(Response::decode(&base(&dup)).is_err());
        // A well-formed sparse list decodes.
        let mut ok = vec![2u8, 5];
        ok.extend_from_slice(&1u64.to_le_bytes());
        ok.push(9);
        ok.extend_from_slice(&1u64.to_le_bytes());
        assert!(Response::decode(&base(&ok)).is_ok());
    }

    #[test]
    fn metrics_request_is_dispatchable() {
        let frame = encode_metrics_request();
        assert_eq!(request_opcode(&frame).unwrap(), OP_METRICS);
        // The v1 parser rejects it, like OP_STATS/OP_SHAPE — the client
        // surface treats that as "metrics unsupported".
        assert!(split_header(&frame).is_err());
    }

    #[test]
    fn shape_request_and_payload_roundtrip() {
        let frame = encode_shape_request("tiny_segnet");
        assert_eq!(request_opcode(&frame).unwrap(), OP_SHAPE);
        assert_eq!(decode_shape_request(&frame).unwrap(), "tiny_segnet");
        // The v1 parser rejects the opcode outright, like OP_STATS.
        assert!(split_header(&frame).is_err());
        assert!(decode_shape_request(&frame[..5]).is_err());
        assert!(decode_shape_request(&encode_stats_request()).is_err());
        let payload = shape_payload(3072, 21504);
        assert_eq!(parse_shape_payload(&payload).unwrap(), (3072, 21504));
        assert!(parse_shape_payload(&payload[..7]).is_err());
    }
}
