//! Wire protocol for the live serving plane.
//!
//! Framing is the transport's job; this module encodes the message
//! *inside* a frame. Payloads are raw tensor bytes (no serialization —
//! the homogeneity requirement of RDMA, §VII, kept for TCP too so the
//! comparison stays fair, §III-A).
//!
//! ```text
//! Request:  [op u8][flags u8][prio u8][name_len u8][name][payload]
//! Response: [status u8][queue_ns u64][preproc_ns u64][infer_ns u64][payload]
//! ```

use anyhow::{bail, Result};

/// Request opcodes.
pub const OP_INFER: u8 = 1;
/// flags bit 0: payload is a raw uint8 camera frame (server preprocesses).
pub const FLAG_RAW: u8 = 1;

/// A parsed inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub model: String,
    pub raw: bool,
    pub prio: u8,
    pub payload: Vec<u8>,
}

/// Request header fields without the payload: what the zero-copy
/// receive path parses in place, leaving the payload bytes untouched
/// inside the transport's registered region.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestMeta {
    pub model: String,
    pub raw: bool,
    pub prio: u8,
}

/// Parse the request header from a frame, returning the metadata and
/// the byte offset where the payload starts.
pub fn split_header(buf: &[u8]) -> Result<(RequestMeta, usize)> {
    if buf.len() < 4 {
        bail!("short request frame: {} bytes", buf.len());
    }
    if buf[0] != OP_INFER {
        bail!("unknown opcode {}", buf[0]);
    }
    let name_len = buf[3] as usize;
    if buf.len() < 4 + name_len {
        bail!("truncated model name");
    }
    let model = std::str::from_utf8(&buf[4..4 + name_len])?.to_string();
    Ok((
        RequestMeta {
            model,
            raw: buf[1] & FLAG_RAW != 0,
            prio: buf[2],
        },
        4 + name_len,
    ))
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let name = self.model.as_bytes();
        assert!(name.len() <= u8::MAX as usize, "model name too long");
        let mut buf = Vec::with_capacity(4 + name.len() + self.payload.len());
        buf.push(OP_INFER);
        buf.push(if self.raw { FLAG_RAW } else { 0 });
        buf.push(self.prio);
        buf.push(name.len() as u8);
        buf.extend_from_slice(name);
        buf.extend_from_slice(&self.payload);
        buf
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let (meta, payload_off) = split_header(buf)?;
        Ok(Request {
            model: meta.model,
            raw: meta.raw,
            prio: meta.prio,
            payload: buf[payload_off..].to_vec(),
        })
    }
}

/// Server-side stage timings reported with every response, the live
/// analogue of the paper's fine-grained pipeline profiling (§III-B).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageNs {
    /// Time queued before an execution stream picked the request up.
    pub queue_ns: u64,
    /// GPU/PJRT preprocessing time (raw inputs only).
    pub preproc_ns: u64,
    /// Inference execution time.
    pub infer_ns: u64,
}

impl StageNs {
    pub fn total(&self) -> u64 {
        self.queue_ns + self.preproc_ns + self.infer_ns
    }
}

/// A parsed response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok { stages: StageNs, payload: Vec<u8> },
    Err(String),
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Ok { stages, payload } => {
                let mut buf = Vec::with_capacity(25 + payload.len());
                buf.push(0u8);
                buf.extend_from_slice(&stages.queue_ns.to_le_bytes());
                buf.extend_from_slice(&stages.preproc_ns.to_le_bytes());
                buf.extend_from_slice(&stages.infer_ns.to_le_bytes());
                buf.extend_from_slice(payload);
                buf
            }
            Response::Err(msg) => {
                let mut buf = Vec::with_capacity(1 + msg.len());
                buf.push(1u8);
                buf.extend_from_slice(msg.as_bytes());
                buf
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        if buf.is_empty() {
            bail!("empty response frame");
        }
        match buf[0] {
            0 => {
                if buf.len() < 25 {
                    bail!("short ok response");
                }
                let u = |i: usize| {
                    u64::from_le_bytes(buf[i..i + 8].try_into().expect("8 bytes"))
                };
                Ok(Response::Ok {
                    stages: StageNs {
                        queue_ns: u(1),
                        preproc_ns: u(9),
                        infer_ns: u(17),
                    },
                    payload: buf[25..].to_vec(),
                })
            }
            1 => Ok(Response::Err(
                String::from_utf8_lossy(&buf[1..]).to_string(),
            )),
            s => bail!("unknown response status {s}"),
        }
    }
}

/// f32 slice -> LE bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// LE bytes -> f32 vec.
pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        bail!("payload not f32-aligned: {} bytes", b.len());
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            model: "tiny_resnet".into(),
            raw: true,
            prio: 7,
            payload: vec![1, 2, 3, 4, 5],
        };
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn split_header_matches_decode() {
        let r = Request {
            model: "tiny_mobilenet".into(),
            raw: false,
            prio: 3,
            payload: vec![9; 12],
        };
        let frame = r.encode();
        let (meta, off) = split_header(&frame).unwrap();
        assert_eq!(meta.model, "tiny_mobilenet");
        assert!(!meta.raw);
        assert_eq!(meta.prio, 3);
        assert_eq!(&frame[off..], &r.payload[..]);
        assert!(split_header(&[]).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::Ok {
            stages: StageNs {
                queue_ns: 123,
                preproc_ns: 456,
                infer_ns: 789,
            },
            payload: f32s_to_bytes(&[1.5, -2.25]),
        };
        let d = Response::decode(&r.encode()).unwrap();
        assert_eq!(d, r);
        if let Response::Ok { payload, stages } = d {
            assert_eq!(bytes_to_f32s(&payload).unwrap(), vec![1.5, -2.25]);
            assert_eq!(stages.total(), 123 + 456 + 789);
        }
        let e = Response::Err("boom".into());
        assert_eq!(Response::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[9, 0, 0, 0]).is_err());
        assert!(Request::decode(&[1, 0, 0, 200, 1, 2]).is_err());
        assert!(Response::decode(&[]).is_err());
        assert!(Response::decode(&[0, 1, 2]).is_err());
        assert!(Response::decode(&[7]).is_err());
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
