//! Closed-loop load generator (the paper's client): N client threads,
//! each sending `requests` back-to-back inference requests and
//! recording the Table I latency breakdown from its own clock plus the
//! server-reported stage timings — and, since protocol v2, the
//! server's span timeline, collapsed per request into the nine-stage
//! [`StageBreakdown`] and aggregated into [`LiveStats::spans`].

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::stats::{ReqRecord, StageAgg};
use crate::metrics::telemetry::MetricsReport;
use crate::models::zoo::WorkloadData;
use crate::sim::time::Ns;
use crate::trace::{BreakdownAgg, SpanBlock, Stage, StageBreakdown};
use crate::transport::tcp::TcpTransport;
use crate::transport::MsgTransport;

use super::executor::{CreditHint, ExecStats};
use super::protocol::{self, Request, Response};

/// Load-generation configuration.
#[derive(Debug, Clone)]
pub struct LoadCfg {
    pub model: String,
    /// Send raw uint8 frames (server preprocesses) or f32 tensors.
    pub raw: bool,
    /// Request span timelines ([`protocol::FLAG_SPANS`], protocol v2).
    /// Off by default so legacy experiments measure under the exact v1
    /// conditions (no span block on the wire, no extra server stamps);
    /// `stagebreak` turns it on.
    pub spans: bool,
    pub n_clients: usize,
    pub requests_per_client: usize,
    /// Client 0 gets high priority.
    pub priority_client: bool,
    /// Payload element count (per-request input size).
    pub payload_elems: usize,
    /// Warmup requests discarded per client.
    pub warmup: usize,
    /// Per-request SLO budget in µs from server receipt
    /// ([`protocol::FLAG_DEADLINE`], protocol v2). `None` keeps frames
    /// byte-identical to v1 and exempts the traffic from deadline
    /// shedding.
    pub deadline_us: Option<u64>,
    /// Honour server credit/pacing hints ([`protocol::FLAG_CREDITS`],
    /// protocol v2): each client's closed loop feeds the returned
    /// hints into a [`TokenPacer`] and slows down *before* admission
    /// control would shed. Off by default — frames stay byte-identical
    /// to v1, and a v1 server (which never sends hints) leaves the
    /// pacer inert.
    pub credits: bool,
    /// Connect/read/write timeout for each client connection; `None`
    /// blocks forever (the v1 behaviour). Set it when the server may
    /// hang — a stalled peer then surfaces as a client error instead of
    /// wedging the calling thread.
    pub timeout: Option<Duration>,
    /// Chained stage models after [`LoadCfg::model`]
    /// ([`protocol::FLAG_PIPELINE`], protocol v2): the gateway runs the
    /// whole chain server-side and replies once. Empty keeps frames
    /// byte-identical to v1. Only meaningful against a routing gateway
    /// — a plain coordinator refuses to chain.
    pub pipeline: Vec<String>,
}

/// Aggregate results of one live run.
#[derive(Debug, Default)]
pub struct LiveStats {
    pub all: StageAgg,
    pub priority: StageAgg,
    pub normal: StageAgg,
    /// Nine-stage span breakdowns (protocol v2). Empty when the server
    /// answered with v1 span-less responses.
    pub spans: BreakdownAgg,
    pub duration_s: f64,
    pub throughput_rps: f64,
    /// Clients that died mid-run (transport/decode failure). Their
    /// tallies up to the failure still count in `served`/`sheds`, so
    /// the totals stay reconcilable against the server's lane counters.
    pub errors: usize,
    /// Per-request `Response::Err` frames. Unlike a client failure the
    /// loop continues — one failed request does not discard a client's
    /// remaining traffic.
    pub req_errors: usize,
    /// Requests the server shed (admission control, protocol v2) —
    /// counted across warmup too, so the total matches the executor's
    /// per-lane shed counters exactly.
    pub sheds: usize,
    /// Requests actually served OK (including warmup); the goodput
    /// numerator under overload.
    pub served: usize,
    /// Per-request span timelines in wall-clock order per client
    /// (protocol v2 + spans on): the raw material for Chrome-trace
    /// export ([`crate::trace::ChromeTrace`]). Empty when spans were
    /// off or the server answered v1.
    pub timeline: Vec<TimelineRec>,
}

/// One request's placement on the run's wall clock: when it was sent
/// (ns offset from the run start), how long it took end to end, and
/// its server span block — everything the timeline exporter needs.
#[derive(Debug, Clone)]
pub struct TimelineRec {
    pub client: usize,
    /// Send instant as a ns offset from the run's start.
    pub t0_ns: u64,
    /// Client-observed end-to-end latency, ns.
    pub total_ns: u64,
    pub span: SpanBlock,
}

/// One measured request: the Table I record plus, when the server
/// returned a span timeline, its nine-stage breakdown.
#[derive(Debug, Clone)]
pub struct ClientRec {
    pub rec: ReqRecord,
    pub breakdown: Option<StageBreakdown>,
    /// When the request was sent (the client's own clock).
    pub sent_at: Instant,
    /// The server's span block, kept verbatim for timeline export.
    pub span: Option<SpanBlock>,
}

/// Query a server's executor counters over an open connection (the
/// stats opcode, protocol v2). A v1 server answers with an error
/// response, surfaced here as `Err`.
pub fn fetch_stats(t: &mut dyn MsgTransport) -> Result<ExecStats> {
    t.send(&protocol::encode_stats_request())?;
    match Response::decode(&t.recv()?)? {
        Response::Stats(s) => Ok(s),
        Response::Err(e) => bail!("server rejected stats request: {e}"),
        Response::Ok { .. } => bail!("server answered stats with an inference response"),
        Response::Shed { msg, .. } => bail!("server shed a stats request: {msg}"),
        Response::Pipeline { .. } => bail!("server answered stats with a pipeline response"),
        Response::Metrics(_) => bail!("server answered stats with a metrics response"),
    }
}

/// Query a server's telemetry plane — registry snapshot plus sampler
/// ring — over an open connection (the metrics opcode, protocol v2).
/// Works against a coordinator (local registry) or a routing gateway
/// (fleet-merged snapshot, empty ring). A server predating the opcode
/// answers with an error response, surfaced here as `Err` — callers
/// degrade by omitting histogram-derived columns.
pub fn fetch_metrics(t: &mut dyn MsgTransport) -> Result<MetricsReport> {
    t.send(&protocol::encode_metrics_request())?;
    match Response::decode(&t.recv()?)? {
        Response::Metrics(m) => Ok(m),
        Response::Err(e) => bail!("server rejected metrics request: {e}"),
        other => bail!("unexpected response to metrics request: {other:?}"),
    }
}

/// Query a model's per-request tensor shape — `(in_elems, out_elems)`
/// — over an open connection (the shape opcode, protocol v2). Works
/// against a coordinator (manifest lookup) or a routing gateway
/// (forwarded to the model's placed backend).
pub fn fetch_shape(t: &mut dyn MsgTransport, model: &str) -> Result<(usize, usize)> {
    t.send(&protocol::encode_shape_request(model))?;
    match Response::decode(&t.recv()?)? {
        Response::Ok { payload, .. } => protocol::parse_shape_payload(&payload),
        Response::Err(e) => bail!("server rejected shape request: {e}"),
        other => bail!("unexpected response to shape request: {other:?}"),
    }
}

/// What one closed-loop client observed: the measured (post-warmup)
/// records plus the served/shed tallies for goodput accounting.
///
/// Tallies are **always** populated, even when the client died partway
/// through its loop — the failure lands in [`ClientRun::fatal`] instead
/// of discarding the run. Before this, a client that errored on request
/// k silently dropped its k−1 completed requests from the aggregate,
/// so client-side totals could never reconcile with the server's lane
/// counters under fault injection.
#[derive(Debug, Default)]
pub struct ClientRun {
    /// Post-warmup measured requests (latency records).
    pub recs: Vec<ClientRec>,
    /// Requests answered OK, warmup included.
    pub oks: usize,
    /// Requests the server shed, warmup included.
    pub sheds: usize,
    /// Requests answered with a per-request [`Response::Err`] frame.
    /// The loop keeps going — the server stayed up and spoke protocol,
    /// so the rest of the traffic is still worth offering.
    pub req_errors: usize,
    /// The transport/decode failure that ended the loop early, if any.
    pub fatal: Option<anyhow::Error>,
}

/// Client-side token bucket fed by server [`CreditHint`]s (the
/// tentpole's pacing half). `credits` caps the burst the server is
/// willing to absorb right now; `pace_ns` is the steady-state refill
/// interval. A zero-credit hint empties the bucket outright — the
/// server just shed on this lane and wants silence for a beat.
///
/// Time is passed in explicitly (`Instant` arguments) so refill math is
/// deterministic under test; no hidden clock reads.
#[derive(Debug)]
pub struct TokenPacer {
    capacity: u64,
    tokens: u64,
    pace_ns: u64,
    last_refill: Instant,
}

impl TokenPacer {
    /// A fresh pacer is permissive: one token, no pacing — the first
    /// request always goes out immediately, and real limits arrive with
    /// the first hint.
    pub fn new(now: Instant) -> TokenPacer {
        TokenPacer {
            capacity: 1,
            tokens: 1,
            pace_ns: 0,
            last_refill: now,
        }
    }

    /// Fold a server hint into the bucket. Capacity tracks the hint's
    /// credit grant (floored at 1 so the closed loop can always make
    /// progress once the pace interval elapses); a zero-credit hint
    /// additionally drains the tokens already held.
    pub fn apply(&mut self, hint: &CreditHint) {
        self.capacity = u64::from(hint.credits).max(1);
        self.pace_ns = hint.pace_ns;
        self.tokens = self.tokens.min(self.capacity);
        if hint.credits == 0 {
            self.tokens = 0;
        }
    }

    /// Credit earned tokens for elapsed time. With no pace the bucket
    /// refills instantly; otherwise one token per `pace_ns`, advancing
    /// `last_refill` by exactly the time consumed so fractional
    /// intervals carry over.
    fn refill(&mut self, now: Instant) {
        if self.pace_ns == 0 {
            self.tokens = self.capacity;
            self.last_refill = now;
            return;
        }
        let elapsed = now.saturating_duration_since(self.last_refill).as_nanos() as u64;
        let earned = elapsed / self.pace_ns;
        if earned > 0 {
            self.tokens = (self.tokens + earned).min(self.capacity);
            self.last_refill += Duration::from_nanos(earned * self.pace_ns);
        }
    }

    /// Try to take a token at `now`. Returns [`Duration::ZERO`] on
    /// success (token consumed), or how long to wait before the next
    /// token matures (nothing consumed) — callers sleep and retry.
    pub fn acquire_at(&mut self, now: Instant) -> Duration {
        self.refill(now);
        if self.tokens > 0 {
            self.tokens -= 1;
            return Duration::ZERO;
        }
        (self.last_refill + Duration::from_nanos(self.pace_ns)).saturating_duration_since(now)
    }
}

/// Drive a closed loop over an arbitrary connected transport. With
/// [`LoadCfg::spans`] set, requests ask for span timelines
/// ([`protocol::FLAG_SPANS`]); a span-less (v1) response simply yields
/// records without breakdowns. A shed response ([`Response::Shed`]) is
/// tallied — not a client failure — and the loop moves straight on to
/// the next request, which is what makes the closed loop keep offering
/// load under admission control. A per-request [`Response::Err`] is
/// likewise tallied and the loop continues; only a transport or decode
/// failure ends the run early, and even then the partial tallies come
/// back (in [`ClientRun`], with the failure in [`ClientRun::fatal`])
/// rather than being discarded.
///
/// With [`LoadCfg::credits`] set, each request carries
/// [`protocol::FLAG_CREDITS`] and the returned hints drive a
/// [`TokenPacer`]: the client sleeps out its pacing debt *before*
/// sending, converting server-side sheds into client-side delay.
pub fn run_client_loop(t: &mut dyn MsgTransport, cfg: &LoadCfg, client_idx: usize) -> ClientRun {
    let prio = if cfg.priority_client && client_idx == 0 {
        10
    } else {
        0
    };
    let payload = if cfg.raw {
        WorkloadData::image(cfg.payload_elems, 42 + client_idx as u64).bytes
    } else {
        // Deterministic f32 tensor in [0, 1).
        super::protocol::f32s_to_bytes(
            &WorkloadData::image(cfg.payload_elems, 42 + client_idx as u64)
                .bytes
                .iter()
                .map(|&b| b as f32 / 255.0)
                .collect::<Vec<f32>>(),
        )
    };
    let req = Request {
        model: cfg.model.clone(),
        raw: cfg.raw,
        spans: cfg.spans,
        prio,
        deadline_us: cfg.deadline_us,
        credits: cfg.credits,
        pipeline: cfg.pipeline.clone(),
        payload,
    }
    .encode();

    let mut out = ClientRun::default();
    let mut pacer = cfg.credits.then(|| TokenPacer::new(Instant::now()));
    for i in 0..cfg.requests_per_client {
        if let Some(p) = pacer.as_mut() {
            // Pay the pacing debt before offering the next request.
            loop {
                let wait = p.acquire_at(Instant::now());
                if wait.is_zero() {
                    break;
                }
                std::thread::sleep(wait);
            }
        }
        let t0 = Instant::now();
        if let Err(e) = t.send(&req).context("client send failed") {
            out.fatal = Some(e);
            return out;
        }
        let frame = match t.recv().context("client recv failed") {
            Ok(f) => f,
            Err(e) => {
                out.fatal = Some(e);
                return out;
            }
        };
        let total = t0.elapsed();
        let decoded = protocol::decode_with_credit(&frame).context("client decode failed");
        let (resp, hint) = match decoded {
            Ok(pair) => pair,
            Err(e) => {
                out.fatal = Some(e);
                return out;
            }
        };
        if let (Some(p), Some(h)) = (pacer.as_mut(), hint.as_ref()) {
            p.apply(h);
        }
        match resp {
            Response::Err(e) => {
                // The server stayed up and spoke protocol — one failed
                // request does not condemn the rest of the loop.
                log::warn!("client {client_idx}: server error on request {i}: {e}");
                out.req_errors += 1;
            }
            Response::Stats(_) | Response::Metrics(_) => {
                out.fatal = Some(anyhow!("unsolicited stats/metrics response"));
                return out;
            }
            Response::Shed { .. } => {
                // Admission control said no — cheap, expected under
                // overload. No latency record: the request wasn't served.
                out.sheds += 1;
            }
            Response::Ok { stages, span, .. } => {
                out.oks += 1;
                if i < cfg.warmup {
                    continue;
                }
                let total_ns = total.as_nanos() as u64;
                let server_ns = stages.total();
                // Transport time = client-observed total minus server
                // processing (the paper's ZeroMQ accounting, §III-B);
                // split evenly between request and response paths.
                let net_ns = total_ns.saturating_sub(server_ns);
                let breakdown = span
                    .as_ref()
                    .map(|block| StageBreakdown::from_span(block, total_ns));
                // The scheduler-residence stages come straight from the
                // span breakdown when the server returned one; a v1
                // span-less response leaves them zero.
                let lane = |s: Stage| Ns(breakdown.as_ref().map_or(0, |b| b.get(s)));
                out.recs.push(ClientRec {
                    rec: ReqRecord {
                        client: client_idx,
                        total: Ns(total_ns),
                        request: Ns(net_ns / 2),
                        response: Ns(net_ns - net_ns / 2),
                        lane_queue: lane(Stage::LaneQueue),
                        gather_wait: lane(Stage::GatherWait),
                        dispatch_wait: lane(Stage::DispatchWait),
                        copy_h2d: Ns(0),
                        copy_d2h: Ns(0),
                        preproc: Ns(stages.preproc_ns),
                        infer: Ns(stages.queue_ns + stages.infer_ns),
                        cpu_us: 0.0,
                        priority: prio > 0,
                    },
                    breakdown,
                    sent_at: t0,
                    span,
                });
            }
            Response::Pipeline { stages, .. } => {
                // One reply for the whole chain: the gateway already ran
                // every stage back-to-back. Decode validated stage
                // windows are monotone, so last recv − first sent is the
                // chain's server-side residence time.
                out.oks += 1;
                if i < cfg.warmup {
                    continue;
                }
                let total_ns = total.as_nanos() as u64;
                let chain_ns = match (stages.first(), stages.last()) {
                    (Some(first), Some(last)) => last.recv_ns.saturating_sub(first.sent_ns),
                    _ => 0,
                };
                let busy_ns: u64 = stages.iter().map(|s| s.recv_ns - s.sent_ns).sum();
                let net_ns = total_ns.saturating_sub(chain_ns);
                out.recs.push(ClientRec {
                    rec: ReqRecord {
                        client: client_idx,
                        total: Ns(total_ns),
                        request: Ns(net_ns / 2),
                        response: Ns(net_ns - net_ns / 2),
                        lane_queue: Ns(0),
                        gather_wait: Ns(0),
                        dispatch_wait: Ns(0),
                        copy_h2d: Ns(0),
                        copy_d2h: Ns(0),
                        preproc: Ns(0),
                        infer: Ns(busy_ns),
                        cpu_us: 0.0,
                        priority: prio > 0,
                    },
                    breakdown: None,
                    sent_at: t0,
                    span: None,
                });
            }
        }
    }
    out
}

/// Run the full load test over any transport: spawns
/// [`LoadCfg::n_clients`] closed-loop threads, each dialing its own
/// [`MsgTransport`] connection through the `connect` closure (client
/// index passed in, e.g. for per-client rings or priority addressing),
/// and aggregates the per-request records into [`LiveStats`].
pub fn run_on<T, F>(connect: F, cfg: &LoadCfg) -> Result<LiveStats>
where
    T: MsgTransport,
    F: Fn(usize) -> Result<T> + Sync,
{
    let t_start = Instant::now();
    let results: Vec<ClientRun> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..cfg.n_clients {
            let connect = &connect;
            handles.push(s.spawn(move || -> ClientRun {
                let mut t = match connect(c).context("client connect failed") {
                    Ok(t) => t,
                    Err(e) => {
                        return ClientRun {
                            fatal: Some(e),
                            ..ClientRun::default()
                        }
                    }
                };
                run_client_loop(&mut t, cfg, c)
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| ClientRun {
                    fatal: Some(anyhow!("client thread panicked")),
                    ..ClientRun::default()
                })
            })
            .collect()
    });
    let mut stats = LiveStats::default();
    for run in results {
        // Fold the tallies from every run — including one that died
        // partway through. Discarding a failed client's completed
        // requests (the old behaviour) made client-side totals drift
        // from the server's lane counters whenever anything went wrong.
        stats.served += run.oks;
        stats.sheds += run.sheds;
        stats.req_errors += run.req_errors;
        for cr in &run.recs {
            let r = &cr.rec;
            stats.all.push(r);
            if r.priority {
                stats.priority.push(r);
            } else {
                stats.normal.push(r);
            }
            if let Some(b) = &cr.breakdown {
                stats.spans.push(b, r.total.0);
            }
            if let Some(block) = &cr.span {
                stats.timeline.push(TimelineRec {
                    client: r.client,
                    t0_ns: cr.sent_at.saturating_duration_since(t_start).as_nanos() as u64,
                    total_ns: r.total.0,
                    span: block.clone(),
                });
            }
        }
        if let Some(e) = run.fatal {
            stats.errors += 1;
            log::warn!("client failed: {e:#}");
        }
    }
    stats.duration_s = t_start.elapsed().as_secs_f64();
    // Goodput: only requests that were actually served count — shed
    // requests cost a round-trip but produce nothing.
    stats.throughput_rps = stats.served as f64 / stats.duration_s.max(1e-9);
    Ok(stats)
}

/// Run the full TCP load test: spawns `n_clients` closed-loop threads
/// (honouring [`LoadCfg::timeout`] on connect and reads).
pub fn run_tcp(addr: SocketAddr, cfg: &LoadCfg) -> Result<LiveStats> {
    run_on(|_client| TcpTransport::connect_timed(addr, cfg.timeout), cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pacer_token_bucket_refills_deterministically() {
        let t0 = Instant::now();
        let mut p = TokenPacer::new(t0);
        // Fresh pacer: first acquire free, second too (pace 0 refills).
        assert_eq!(p.acquire_at(t0), Duration::ZERO);
        assert_eq!(p.acquire_at(t0), Duration::ZERO);

        // Hint: 2 credits, one token per millisecond.
        p.apply(&CreditHint {
            credits: 2,
            pace_ns: 1_000_000,
        });
        // The apply clamps but does not grant: the bucket was emptied
        // by the acquires above, so the next request owes a full pace
        // interval.
        assert_eq!(p.acquire_at(t0), Duration::from_millis(1));

        // Exactly one pace interval later: one token matured.
        let t1 = t0 + Duration::from_millis(1);
        assert_eq!(p.acquire_at(t1), Duration::ZERO);
        assert_eq!(p.acquire_at(t1), Duration::from_millis(1));

        // 2.5 intervals elapse: earns 2 tokens (fraction carries over,
        // capped at capacity 2), and the carry means the next token
        // matures half an interval after the cap point.
        let t2 = t1 + Duration::from_micros(2_500);
        assert_eq!(p.acquire_at(t2), Duration::ZERO);
        assert_eq!(p.acquire_at(t2), Duration::ZERO);
        assert_eq!(p.acquire_at(t2), Duration::from_micros(500));

        // Zero-credit hint drains the bucket outright.
        let t3 = t2 + Duration::from_millis(10);
        p.refill(t3);
        p.apply(&CreditHint {
            credits: 0,
            pace_ns: 4_000_000,
        });
        assert!(p.acquire_at(t3) > Duration::ZERO);
    }
}
