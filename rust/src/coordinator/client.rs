//! Closed-loop load generator (the paper's client): N client threads,
//! each sending `requests` back-to-back inference requests and
//! recording the Table I latency breakdown from its own clock plus the
//! server-reported stage timings — and, since protocol v2, the
//! server's span timeline, collapsed per request into the nine-stage
//! [`StageBreakdown`] and aggregated into [`LiveStats::spans`].

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::metrics::stats::{ReqRecord, StageAgg};
use crate::models::zoo::WorkloadData;
use crate::sim::time::Ns;
use crate::trace::{BreakdownAgg, StageBreakdown};
use crate::transport::tcp::TcpTransport;
use crate::transport::MsgTransport;

use super::executor::ExecStats;
use super::protocol::{self, Request, Response};

/// Load-generation configuration.
#[derive(Debug, Clone)]
pub struct LoadCfg {
    pub model: String,
    /// Send raw uint8 frames (server preprocesses) or f32 tensors.
    pub raw: bool,
    /// Request span timelines ([`protocol::FLAG_SPANS`], protocol v2).
    /// Off by default so legacy experiments measure under the exact v1
    /// conditions (no span block on the wire, no extra server stamps);
    /// `stagebreak` turns it on.
    pub spans: bool,
    pub n_clients: usize,
    pub requests_per_client: usize,
    /// Client 0 gets high priority.
    pub priority_client: bool,
    /// Payload element count (per-request input size).
    pub payload_elems: usize,
    /// Warmup requests discarded per client.
    pub warmup: usize,
    /// Per-request SLO budget in µs from server receipt
    /// ([`protocol::FLAG_DEADLINE`], protocol v2). `None` keeps frames
    /// byte-identical to v1 and exempts the traffic from deadline
    /// shedding.
    pub deadline_us: Option<u64>,
    /// Connect/read/write timeout for each client connection; `None`
    /// blocks forever (the v1 behaviour). Set it when the server may
    /// hang — a stalled peer then surfaces as a client error instead of
    /// wedging the calling thread.
    pub timeout: Option<Duration>,
}

/// Aggregate results of one live run.
#[derive(Debug, Default)]
pub struct LiveStats {
    pub all: StageAgg,
    pub priority: StageAgg,
    pub normal: StageAgg,
    /// Nine-stage span breakdowns (protocol v2). Empty when the server
    /// answered with v1 span-less responses.
    pub spans: BreakdownAgg,
    pub duration_s: f64,
    pub throughput_rps: f64,
    pub errors: usize,
    /// Requests the server shed (admission control, protocol v2) —
    /// counted across warmup too, so the total matches the executor's
    /// per-lane shed counters exactly.
    pub sheds: usize,
    /// Requests actually served OK (including warmup); the goodput
    /// numerator under overload.
    pub served: usize,
}

/// One measured request: the Table I record plus, when the server
/// returned a span timeline, its nine-stage breakdown.
#[derive(Debug, Clone)]
pub struct ClientRec {
    pub rec: ReqRecord,
    pub breakdown: Option<StageBreakdown>,
}

/// Query a server's executor counters over an open connection (the
/// stats opcode, protocol v2). A v1 server answers with an error
/// response, surfaced here as `Err`.
pub fn fetch_stats(t: &mut dyn MsgTransport) -> Result<ExecStats> {
    t.send(&protocol::encode_stats_request())?;
    match Response::decode(&t.recv()?)? {
        Response::Stats(s) => Ok(s),
        Response::Err(e) => bail!("server rejected stats request: {e}"),
        Response::Ok { .. } => bail!("server answered stats with an inference response"),
        Response::Shed { msg, .. } => bail!("server shed a stats request: {msg}"),
    }
}

/// What one closed-loop client observed: the measured (post-warmup)
/// records plus the served/shed tallies for goodput accounting.
#[derive(Debug, Default)]
pub struct ClientRun {
    /// Post-warmup measured requests (latency records).
    pub recs: Vec<ClientRec>,
    /// Requests answered OK, warmup included.
    pub oks: usize,
    /// Requests the server shed, warmup included.
    pub sheds: usize,
}

/// Drive a closed loop over an arbitrary connected transport. With
/// [`LoadCfg::spans`] set, requests ask for span timelines
/// ([`protocol::FLAG_SPANS`]); a span-less (v1) response simply yields
/// records without breakdowns. A shed response ([`Response::Shed`]) is
/// tallied — not a client failure — and the loop moves straight on to
/// the next request, which is what makes the closed loop keep offering
/// load under admission control.
pub fn run_client_loop(
    t: &mut dyn MsgTransport,
    cfg: &LoadCfg,
    client_idx: usize,
) -> Result<ClientRun> {
    let prio = if cfg.priority_client && client_idx == 0 {
        10
    } else {
        0
    };
    let payload = if cfg.raw {
        WorkloadData::image(cfg.payload_elems, 42 + client_idx as u64).bytes
    } else {
        // Deterministic f32 tensor in [0, 1).
        super::protocol::f32s_to_bytes(
            &WorkloadData::image(cfg.payload_elems, 42 + client_idx as u64)
                .bytes
                .iter()
                .map(|&b| b as f32 / 255.0)
                .collect::<Vec<f32>>(),
        )
    };
    let req = Request {
        model: cfg.model.clone(),
        raw: cfg.raw,
        spans: cfg.spans,
        prio,
        deadline_us: cfg.deadline_us,
        payload,
    }
    .encode();

    let mut out = ClientRun::default();
    for i in 0..cfg.requests_per_client {
        let t0 = Instant::now();
        t.send(&req)?;
        let frame = t.recv()?;
        let total = t0.elapsed();
        match Response::decode(&frame)? {
            Response::Err(e) => bail!("server error: {e}"),
            Response::Stats(_) => bail!("unsolicited stats response"),
            Response::Shed { .. } => {
                // Admission control said no — cheap, expected under
                // overload. No latency record: the request wasn't served.
                out.sheds += 1;
            }
            Response::Ok { stages, span, .. } => {
                out.oks += 1;
                if i < cfg.warmup {
                    continue;
                }
                let total_ns = total.as_nanos() as u64;
                let server_ns = stages.total();
                // Transport time = client-observed total minus server
                // processing (the paper's ZeroMQ accounting, §III-B);
                // split evenly between request and response paths.
                let net_ns = total_ns.saturating_sub(server_ns);
                out.recs.push(ClientRec {
                    rec: ReqRecord {
                        client: client_idx,
                        total: Ns(total_ns),
                        request: Ns(net_ns / 2),
                        response: Ns(net_ns - net_ns / 2),
                        copy_h2d: Ns(0),
                        copy_d2h: Ns(0),
                        preproc: Ns(stages.preproc_ns),
                        infer: Ns(stages.queue_ns + stages.infer_ns),
                        cpu_us: 0.0,
                        priority: prio > 0,
                    },
                    breakdown: span
                        .map(|block| StageBreakdown::from_span(&block, total_ns)),
                });
            }
        }
    }
    Ok(out)
}

/// Run the full load test over any transport: spawns
/// [`LoadCfg::n_clients`] closed-loop threads, each dialing its own
/// [`MsgTransport`] connection through the `connect` closure (client
/// index passed in, e.g. for per-client rings or priority addressing),
/// and aggregates the per-request records into [`LiveStats`].
pub fn run_on<T, F>(connect: F, cfg: &LoadCfg) -> Result<LiveStats>
where
    T: MsgTransport,
    F: Fn(usize) -> Result<T> + Sync,
{
    let t_start = Instant::now();
    let results: Vec<Result<ClientRun>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..cfg.n_clients {
            let connect = &connect;
            handles.push(s.spawn(move || -> Result<ClientRun> {
                let mut t = connect(c)?;
                run_client_loop(&mut t, cfg, c)
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("client thread panicked")))
            })
            .collect()
    });
    let mut stats = LiveStats::default();
    for res in results {
        match res {
            Ok(run) => {
                // A successful client completed its whole closed loop
                // (warmup requests were served even though unrecorded).
                stats.served += run.oks;
                stats.sheds += run.sheds;
                for cr in &run.recs {
                    let r = &cr.rec;
                    stats.all.push(r);
                    if r.priority {
                        stats.priority.push(r);
                    } else {
                        stats.normal.push(r);
                    }
                    if let Some(b) = &cr.breakdown {
                        stats.spans.push(b, r.total.0);
                    }
                }
            }
            Err(e) => {
                stats.errors += 1;
                log::warn!("client failed: {e}");
            }
        }
    }
    stats.duration_s = t_start.elapsed().as_secs_f64();
    // Goodput: only requests that were actually served count — shed
    // requests cost a round-trip but produce nothing.
    stats.throughput_rps = stats.served as f64 / stats.duration_s.max(1e-9);
    Ok(stats)
}

/// Run the full TCP load test: spawns `n_clients` closed-loop threads
/// (honouring [`LoadCfg::timeout`] on connect and reads).
pub fn run_tcp(addr: SocketAddr, cfg: &LoadCfg) -> Result<LiveStats> {
    run_on(|_client| TcpTransport::connect_timed(addr, cfg.timeout), cfg)
}
