//! Closed-loop load generator (the paper's client): N client threads,
//! each sending `requests` back-to-back inference requests and
//! recording the Table I latency breakdown from its own clock plus the
//! server-reported stage timings.

use std::net::SocketAddr;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::metrics::stats::{ReqRecord, StageAgg};
use crate::models::zoo::WorkloadData;
use crate::sim::time::Ns;
use crate::transport::tcp::TcpTransport;
use crate::transport::MsgTransport;

use super::protocol::{Request, Response};

/// Load-generation configuration.
#[derive(Debug, Clone)]
pub struct LoadCfg {
    pub model: String,
    /// Send raw uint8 frames (server preprocesses) or f32 tensors.
    pub raw: bool,
    pub n_clients: usize,
    pub requests_per_client: usize,
    /// Client 0 gets high priority.
    pub priority_client: bool,
    /// Payload element count (per-request input size).
    pub payload_elems: usize,
    /// Warmup requests discarded per client.
    pub warmup: usize,
}

/// Aggregate results of one live run.
#[derive(Debug, Default)]
pub struct LiveStats {
    pub all: StageAgg,
    pub priority: StageAgg,
    pub normal: StageAgg,
    pub duration_s: f64,
    pub throughput_rps: f64,
    pub errors: usize,
}

/// Drive a closed loop over an arbitrary connected transport.
pub fn run_client_loop(
    t: &mut dyn MsgTransport,
    cfg: &LoadCfg,
    client_idx: usize,
) -> Result<Vec<ReqRecord>> {
    let prio = if cfg.priority_client && client_idx == 0 {
        10
    } else {
        0
    };
    let payload = if cfg.raw {
        WorkloadData::image(cfg.payload_elems, 42 + client_idx as u64).bytes
    } else {
        // Deterministic f32 tensor in [0, 1).
        super::protocol::f32s_to_bytes(
            &WorkloadData::image(cfg.payload_elems, 42 + client_idx as u64)
                .bytes
                .iter()
                .map(|&b| b as f32 / 255.0)
                .collect::<Vec<f32>>(),
        )
    };
    let req = Request {
        model: cfg.model.clone(),
        raw: cfg.raw,
        prio,
        payload,
    }
    .encode();

    let mut out = Vec::with_capacity(cfg.requests_per_client);
    for i in 0..cfg.requests_per_client {
        let t0 = Instant::now();
        t.send(&req)?;
        let frame = t.recv()?;
        let total = t0.elapsed();
        match Response::decode(&frame)? {
            Response::Err(e) => bail!("server error: {e}"),
            Response::Ok { stages, .. } => {
                if i < cfg.warmup {
                    continue;
                }
                let total_ns = total.as_nanos() as u64;
                let server_ns = stages.total();
                // Transport time = client-observed total minus server
                // processing (the paper's ZeroMQ accounting, §III-B);
                // split evenly between request and response paths.
                let net_ns = total_ns.saturating_sub(server_ns);
                out.push(ReqRecord {
                    client: client_idx,
                    total: Ns(total_ns),
                    request: Ns(net_ns / 2),
                    response: Ns(net_ns - net_ns / 2),
                    copy_h2d: Ns(0),
                    copy_d2h: Ns(0),
                    preproc: Ns(stages.preproc_ns),
                    infer: Ns(stages.queue_ns + stages.infer_ns),
                    cpu_us: 0.0,
                    priority: prio > 0,
                });
            }
        }
    }
    Ok(out)
}

/// Run the full load test over any transport: spawns
/// [`LoadCfg::n_clients`] closed-loop threads, each dialing its own
/// [`MsgTransport`] connection through the `connect` closure (client
/// index passed in, e.g. for per-client rings or priority addressing),
/// and aggregates the per-request records into [`LiveStats`].
pub fn run_on<T, F>(connect: F, cfg: &LoadCfg) -> Result<LiveStats>
where
    T: MsgTransport,
    F: Fn(usize) -> Result<T> + Sync,
{
    let t_start = Instant::now();
    let results: Vec<Result<Vec<ReqRecord>>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..cfg.n_clients {
            let connect = &connect;
            handles.push(s.spawn(move || -> Result<Vec<ReqRecord>> {
                let mut t = connect(c)?;
                run_client_loop(&mut t, cfg, c)
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("client thread panicked")))
            })
            .collect()
    });
    let mut stats = LiveStats::default();
    let mut served = 0usize;
    for res in results {
        match res {
            Ok(records) => {
                // A successful client completed its whole closed loop
                // (warmup requests were served even though unrecorded).
                served += cfg.requests_per_client;
                for r in &records {
                    stats.all.push(r);
                    if r.priority {
                        stats.priority.push(r);
                    } else {
                        stats.normal.push(r);
                    }
                }
            }
            Err(e) => {
                stats.errors += 1;
                log::warn!("client failed: {e}");
            }
        }
    }
    stats.duration_s = t_start.elapsed().as_secs_f64();
    stats.throughput_rps = served as f64 / stats.duration_s.max(1e-9);
    Ok(stats)
}

/// Run the full TCP load test: spawns `n_clients` closed-loop threads.
pub fn run_tcp(addr: SocketAddr, cfg: &LoadCfg) -> Result<LiveStats> {
    run_on(|_client| TcpTransport::connect(addr), cfg)
}
