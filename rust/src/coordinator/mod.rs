//! L3 coordinator (live plane): the model-serving framework — wire
//! protocol ([`protocol`]), execution service ([`executor`]: shared
//! stream pool + per-model priority lanes + continuous cross-request
//! batching), server ([`serve_on`]), router-dealer gateway
//! ([`gateway_on`]) with a multi-backend routing tier ([`router`],
//! [`routed_gateway_on`]), and the closed-loop load generator
//! ([`run_on`]).
//! Policies here mirror the simulated world so both planes exercise
//! the same design (DESIGN.md §3).
//!
//! The request lifecycle through these modules — and how it maps onto
//! the paper's recv/preprocess/infer/reply pipeline stages — is
//! documented in `docs/ARCHITECTURE.md`.

pub mod client;
mod conn_track;
pub mod executor;
pub mod gateway;
pub mod protocol;
pub mod router;
pub mod server;

pub use client::{
    fetch_metrics, fetch_shape, fetch_stats, run_client_loop, run_on, run_tcp, ClientRec,
    ClientRun, LiveStats, LoadCfg, TimelineRec, TokenPacer,
};
pub use executor::{
    BatchCfg, CreditHint, Done, ExecError, ExecStats, Executor, LaneStats, ModelPolicy, SchedCfg,
    SealReason, ShedReason, DEFAULT_QUEUE_CAP, N_SEAL_REASONS, N_SHED_REASONS, SEAL_REASON_NAMES,
    SHED_REASON_NAMES,
};
pub use gateway::{
    gateway_on, gateway_tcp, gateway_tcp_multi, handle_routed_conn, routed_gateway_on,
    GatewayHandle, GatewayLoop,
};
pub use router::{
    fit_f32, merge_stats, pick_least_loaded, queue_depth, shed_total, BackendSpec, HashRing,
    Placement, Router, RouterCfg, DEFAULT_VNODES,
};
pub use server::{handle_conn, serve_on, serve_tcp, ServeLoop, ServerHandle};
