//! L3 coordinator (live plane): the model-serving framework — wire
//! protocol, execution service (streams + priority + dynamic batching),
//! server, router-dealer gateway, and the closed-loop load generator.
//! Policies here mirror the simulated world so both planes exercise the
//! same design (DESIGN.md §3).

pub mod client;
pub mod executor;
pub mod gateway;
pub mod protocol;
pub mod server;

pub use client::{run_on, run_tcp, LiveStats, LoadCfg};
pub use executor::{BatchCfg, Done, Executor};
pub use gateway::{gateway_on, gateway_tcp, GatewayHandle, GatewayLoop};
pub use server::{handle_conn, serve_on, serve_tcp, ServeLoop, ServerHandle};
