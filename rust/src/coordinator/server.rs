//! The model-serving server: transport-agnostic connection handler plus
//! a TCP listener front-end. Thread-per-connection, mirroring the
//! paper's design ("the server allocates the same number of threads as
//! the number of clients", §III-A), with all GPU work funneled through
//! the shared `Executor`.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::runtime::TensorBuf;
use crate::transport::tcp::TcpTransport;
use crate::transport::MsgTransport;

use super::executor::Executor;
use super::protocol::{f32s_to_bytes, Request, Response};

/// Serve one connection until the peer hangs up: the request-handling /
/// preprocessing / inference / response-handling pipeline of Fig 3.
pub fn handle_conn(mut t: impl MsgTransport, exec: &Executor) {
    loop {
        let frame = match t.recv() {
            Ok(f) => f,
            Err(_) => return, // peer closed
        };
        let resp = match Request::decode(&frame) {
            Err(e) => Response::Err(format!("bad request: {e}")),
            Ok(req) => {
                let payload = if req.raw {
                    TensorBuf::U8(req.payload)
                } else {
                    match super::protocol::bytes_to_f32s(&req.payload) {
                        Ok(v) => TensorBuf::F32(v),
                        Err(e) => {
                            let _ = t.send(&Response::Err(e.to_string()).encode());
                            continue;
                        }
                    }
                };
                match exec.infer_sync(&req.model, req.raw, req.prio, payload) {
                    Ok(done) => Response::Ok {
                        stages: done.stages,
                        payload: f32s_to_bytes(&done.output),
                    },
                    Err(e) => Response::Err(e.to_string()),
                }
            }
        };
        if t.send(&resp.encode()).is_err() {
            return;
        }
    }
}

/// A running TCP server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Request shutdown (existing connections finish their in-flight
    /// request loop on peer close).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Start a TCP server on `addr` (use port 0 for ephemeral), routing all
/// work through `exec`.
pub fn serve_tcp(addr: &str, exec: Arc<Executor>) -> Result<ServerHandle> {
    let listener = TcpTransport::listen(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nonblocking(false).ok();
                    let exec = exec.clone();
                    std::thread::spawn(move || {
                        handle_conn(TcpTransport::from_stream(stream), &exec)
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    });
    Ok(ServerHandle {
        addr: local,
        stop,
        accept_thread: Some(accept_thread),
    })
}
