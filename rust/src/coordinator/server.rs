//! The model-serving server: a transport-agnostic connection handler
//! plus a transport-generic accept loop (`serve_on`) with a TCP
//! front-end (`serve_tcp`). Thread-per-connection, mirroring the
//! paper's design ("the server allocates the same number of threads as
//! the number of clients", §III-A), with all GPU work funneled through
//! the shared `Executor`.
//!
//! The receive path is zero-copy aware: `handle_conn` asks the
//! transport for a [`RecvMsg`], and when a GDR transport hands back a
//! registered-region view of a raw frame, the payload reaches the
//! `Executor` as a `TensorBuf::U8Region` — no host bounce copy between
//! the NIC ring and the GPU staging buffer (the live-plane analogue of
//! the paper's GPUDirect path, Fig 2b).

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::TensorBuf;
use crate::trace::{SpanRec, Stamp};
use crate::transport::tcp::{TcpAcceptor, TcpTransport};
use crate::transport::{Acceptor, MsgTransport, RecvMsg};

use super::conn_track::ConnTracker;
use super::executor::{ExecError, Executor};
use super::protocol::{self, f32s_to_bytes, RequestMeta, Response, StageNs};

/// Decode one received message into request metadata plus the payload
/// tensor, preserving a region view for raw GDR payloads.
fn request_from_msg(msg: RecvMsg) -> Result<(RequestMeta, TensorBuf)> {
    match msg {
        RecvMsg::Host(frame) => {
            let (meta, off) = protocol::split_header(&frame)?;
            let payload = if meta.raw {
                TensorBuf::U8(frame[off..].to_vec())
            } else {
                TensorBuf::F32(protocol::bytes_to_f32s(&frame[off..])?)
            };
            Ok((meta, payload))
        }
        RecvMsg::Region(slice) => {
            let (meta, off) = slice.with(protocol::split_header)?;
            let len = slice.len() - off;
            let payload = if meta.raw {
                // Zero-copy: the raw frame stays in the registered
                // (device-staging) region all the way to the engine.
                TensorBuf::U8Region(slice.sub(off, len))
            } else {
                // f32 tensors need host-side reinterpretation anyway;
                // decode straight out of the region (one copy, not two).
                TensorBuf::F32(slice.sub(off, len).with(protocol::bytes_to_f32s)?)
            };
            Ok((meta, payload))
        }
    }
}

/// Opcode of a received frame without materializing region payloads.
fn msg_opcode(msg: &RecvMsg) -> Option<u8> {
    match msg {
        RecvMsg::Host(v) => v.first().copied(),
        RecvMsg::Region(s) => s.with(|b| b.first().copied()),
    }
}

/// Serve one connection until the peer hangs up: the request-handling /
/// preprocessing / inference / response-handling pipeline of Fig 3.
///
/// Every request gets a trace span based at the transport's receive
/// boundary ([`MsgTransport::recv_boundary`], the live analogue of a
/// WR timestamp); the executor and engine stamp it as the job moves,
/// and the response carries it back when the client asked for spans
/// (protocol v2). A stats-opcode frame is answered from
/// [`Executor::stats`] without touching the lanes.
pub fn handle_conn(mut t: impl MsgTransport, exec: &Executor) {
    loop {
        let msg = match t.recv_msg() {
            Ok(m) => m,
            Err(_) => return, // peer closed
        };
        if msg_opcode(&msg) == Some(protocol::OP_STATS) {
            drop(msg); // release a region slot before the next receive
            if t.send(&Response::Stats(exec.stats()).encode()).is_err() {
                return;
            }
            continue;
        }
        if msg_opcode(&msg) == Some(protocol::OP_METRICS) {
            drop(msg);
            // Registry snapshot + sampler ring; like stats, answered
            // without touching the lanes.
            if t.send(&Response::Metrics(exec.metrics_report()).encode()).is_err() {
                return;
            }
            continue;
        }
        if msg_opcode(&msg) == Some(protocol::OP_SHAPE) {
            let frame = match &msg {
                RecvMsg::Host(v) => v.clone(),
                RecvMsg::Region(s) => s.with(|b| b.to_vec()),
            };
            drop(msg);
            // Answered from the manifest without touching the lanes —
            // the routing gateway uses this to size pipeline bridges.
            let resp = match protocol::decode_shape_request(&frame)
                .and_then(|model| exec.shape(&model))
            {
                Ok((in_elems, out_elems)) => Response::Ok {
                    stages: StageNs::default(),
                    span: None,
                    payload: protocol::shape_payload(in_elems, out_elems),
                },
                Err(e) => Response::Err(format!("bad shape request: {e}")),
            };
            if t.send(&resp.encode()).is_err() {
                return;
            }
            continue;
        }
        let mut span = SpanRec::begin_at(t.recv_boundary().unwrap_or_else(Instant::now));
        // With FLAG_CREDITS set, every response — Ok, Shed and Err alike
        // — carries a backpressure hint for the request's lane (the
        // status-5 envelope); without it the frame is byte-identical to
        // v1. A malformed request has no parsed lane to price, so its
        // Err goes out unwrapped.
        let (resp, credit_model) = match request_from_msg(msg) {
            Err(e) => (Response::Err(format!("bad request: {e}")), None),
            // A plain coordinator parses the stage list but never
            // chains: that is the routing gateway's job, and silently
            // running only stage 0 would corrupt pipeline results.
            Ok((meta, _)) if !meta.pipeline.is_empty() => (
                Response::Err(format!(
                    "pipeline chaining requires the routing gateway ({} + {} chained stages)",
                    meta.model,
                    meta.pipeline.len()
                )),
                None,
            ),
            Ok((meta, payload)) => {
                span.mark(Stamp::RecvDone);
                let resp = match exec.infer_deadline(
                    &meta.model,
                    meta.raw,
                    meta.prio,
                    payload,
                    meta.deadline_us,
                    span,
                ) {
                    Ok(done) => {
                        let mut span = done.span;
                        span.mark(Stamp::ReplySend);
                        Response::Ok {
                            stages: done.stages,
                            span: meta.spans.then(|| protocol::span_to_block(&span)),
                            payload: f32s_to_bytes(&done.output),
                        }
                    }
                    // Admission control's rejection keeps its own wire
                    // status so the client can tell load shedding from
                    // a genuine failure.
                    Err(ExecError::Shed { reason, msg }) => Response::Shed { reason, msg },
                    Err(e @ ExecError::Failed(_)) => Response::Err(e.to_string()),
                };
                (resp, meta.credits.then_some(meta.model))
            }
        };
        let frame = match credit_model {
            Some(model) => {
                protocol::encode_with_credit(&resp, Some(exec.credit_hint(&model)))
            }
            None => resp.encode(),
        };
        if t.send(&frame).is_err() {
            return;
        }
    }
}

/// A running transport-generic accept loop.
pub struct ServeLoop {
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    conns: ConnTracker,
}

impl ServeLoop {
    /// Stop accepting, then unblock and join the per-connection handler
    /// threads (their transports are shut down via
    /// [`crate::transport::MsgTransport::shutdown_hook`], so a handler
    /// parked in `recv` on an idle client returns promptly). Before the
    /// tracker existed only the accept thread was joined and `stop()`
    /// left handlers serving forever.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.conns.stop_all();
    }
}

/// Start a server over any transport listener: every connection
/// accepted from the [`Acceptor`] gets a handler thread running
/// [`handle_conn`] against the shared [`Executor`]. Because each
/// connection blocks in [`Executor::infer_sync`] on its own reply
/// channel, the executor's continuous batcher can fuse requests from
/// many connections — per model, across models concurrently — and
/// still scatter each output row back to the right client.
pub fn serve_on<A: Acceptor>(mut acceptor: A, exec: Arc<Executor>) -> ServeLoop {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let conns = ConnTracker::new();
    let conns2 = conns.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match acceptor.poll_accept() {
                Ok(Some(conn)) => {
                    let exec = exec.clone();
                    let hook = conn.shutdown_hook();
                    let handle = std::thread::spawn(move || handle_conn(conn, &exec));
                    conns2.track(handle, [hook]);
                }
                Ok(None) => std::thread::sleep(Duration::from_millis(2)),
                Err(_) => break,
            }
        }
    });
    ServeLoop {
        stop,
        accept_thread: Some(accept_thread),
        conns,
    }
}

/// A running TCP server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    inner: ServeLoop,
}

impl ServerHandle {
    pub fn stop(self) {
        self.inner.stop();
    }
}

/// Start a TCP server on `addr` (use port 0 for ephemeral), routing all
/// work through `exec`.
pub fn serve_tcp(addr: &str, exec: Arc<Executor>) -> Result<ServerHandle> {
    let listener = TcpTransport::listen(addr)?;
    let acceptor = TcpAcceptor::new(listener)?;
    let local = acceptor.local_addr()?;
    Ok(ServerHandle {
        addr: local,
        inner: serve_on(acceptor, exec),
    })
}
