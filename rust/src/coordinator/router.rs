//! Model → backend routing for the multi-coordinator gateway tier.
//!
//! The paper's offloaded task traverses "a multi-stage pipeline that
//! spans across multiple compute nodes and proxies interconnected via a
//! dedicated network fabric" (§I). This module is the placement brain
//! of that fabric: a [`Router`] maps each model to one of N coordinator
//! backends via a pluggable [`Placement`] policy, pools upstream
//! connections per backend, and routes around backends that saturate
//! (queue-depth / shed-rate signal from the stats opcode) or die
//! (marked down, retried on a backoff).
//!
//! Two policies:
//!
//! * **Consistent hash** — a vnode ring keyed on stable backend
//!   indices. Placement is a pure function of the model name and the
//!   backend count, so it survives gateway restarts, and growing the
//!   fleet from N to N+1 backends moves only ~1/(N+1) of the models.
//! * **Least loaded** — sticky model → backend assignments, placed (and
//!   re-placed when the home saturates or dies) on the backend with the
//!   smallest queued depth in the latest stats snapshot.
//!
//! The router itself never parses payloads; the gateway forwards client
//! frames verbatim and only consults [`Router::route`] for the hop.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::transport::tcp::TcpTransport;
use crate::transport::MsgTransport;

use super::client::{fetch_metrics, fetch_shape, fetch_stats};
use super::executor::{ExecStats, LaneStats, N_SEAL_REASONS, N_SHED_REASONS};

use crate::metrics::telemetry::MetricsReport;

/// Default vnodes per backend on the consistent-hash ring. 64 keeps the
/// ring balanced (worst observed share ~56% on 2 backends over the
/// 64-model synthetic set pinned in `tests/routing.rs`) while staying
/// cheap to rebuild.
pub const DEFAULT_VNODES: usize = 64;

/// Pluggable model → backend placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Vnode hash ring: deterministic, restart-stable, minimal movement
    /// when the backend count changes.
    ConsistentHash,
    /// Sticky assignment to the backend with the smallest queued depth
    /// per the latest stats snapshots.
    LeastLoaded,
}

impl Placement {
    /// Parse a CLI/scenario spelling.
    pub fn by_name(name: &str) -> Option<Placement> {
        match name.to_ascii_lowercase().as_str() {
            "hash" | "consistent-hash" | "consistent_hash" => Some(Placement::ConsistentHash),
            "least-loaded" | "least_loaded" | "load" => Some(Placement::LeastLoaded),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::ConsistentHash => "hash",
            Placement::LeastLoaded => "least-loaded",
        }
    }

    /// All policies, for sweep drivers.
    pub fn all() -> [Placement; 2] {
        [Placement::ConsistentHash, Placement::LeastLoaded]
    }
}

/// FNV-1a 64 with a murmur-style avalanche finalizer. Raw FNV-1a's
/// high bits barely avalanche on short, similar keys — vnode names
/// differ by one digit — which skews the ring badly (a 2-backend ring
/// placed all three tiny models on one backend without the finalizer).
fn hash64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// Consistent-hash ring over backend *indices* (`backend-0`,
/// `backend-1`, …): placement depends only on the model name and the
/// backend count, never on addresses or construction order, so it is
/// identical across gateway restarts.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(ring point, backend index)` sorted by point.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    pub fn new(backends: usize, vnodes_per_backend: usize) -> HashRing {
        assert!(backends > 0, "ring needs at least one backend");
        assert!(vnodes_per_backend > 0, "ring needs at least one vnode");
        let mut points = Vec::with_capacity(backends * vnodes_per_backend);
        for idx in 0..backends {
            for v in 0..vnodes_per_backend {
                points.push((hash64(format!("backend-{idx}#{v}").as_bytes()), idx));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// Home backend of `model`: the owner of the first vnode clockwise
    /// from the model's hash point (wrapping past the top).
    pub fn place(&self, model: &str) -> usize {
        let h = hash64(model.as_bytes());
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[i % self.points.len()].1
    }
}

/// Load signal of a stats snapshot: total queued jobs across lanes.
pub fn queue_depth(stats: &ExecStats) -> u64 {
    stats.lanes.iter().map(|l| u64::from(l.depth)).sum()
}

/// Total sheds (all reasons) across lanes.
pub fn shed_total(stats: &ExecStats) -> u64 {
    stats.lanes.iter().map(|l| l.shed.iter().sum::<u64>()).sum()
}

/// Pure least-loaded choice over per-backend candidates
/// `(saturated, queue_depth, sticky_assignments)`; `None` marks an
/// unusable (down) backend. Ordering: non-saturated beats saturated,
/// then lower depth, then fewer sticky assignments, then lower index.
/// The assignment tie-break matters at cold start: every depth is 0
/// before traffic, and without it all models would pile onto backend 0.
pub fn pick_least_loaded(candidates: &[Option<(bool, u64, u64)>]) -> Option<usize> {
    let mut best: Option<(bool, u64, u64, usize)> = None;
    for (idx, cand) in candidates.iter().enumerate() {
        let Some((sat, depth, assigned)) = *cand else {
            continue;
        };
        let key = (sat, depth, assigned, idx);
        let better = match best {
            None => true,
            Some(b) => key < b,
        };
        if better {
            best = Some(key);
        }
    }
    best.map(|(_, _, _, idx)| idx)
}

/// Sum per-backend stats snapshots into one fleet view: lanes merged by
/// model (sorted by name), counters added. This is the gateway's answer
/// to the stats opcode, so a client sees the same shape whether it asks
/// one coordinator or the whole fleet.
pub fn merge_stats<'a, I>(snaps: I) -> ExecStats
where
    I: IntoIterator<Item = &'a ExecStats>,
{
    let mut interleaves = 0u64;
    let mut by_model: HashMap<String, LaneStats> = HashMap::new();
    for s in snaps {
        interleaves += s.interleaves;
        for lane in &s.lanes {
            let e = by_model
                .entry(lane.model.clone())
                .or_insert_with(|| LaneStats {
                    model: lane.model.clone(),
                    jobs: 0,
                    calls: 0,
                    svc_ns: 0,
                    depth: 0,
                    sealed: [0; N_SEAL_REASONS],
                    shed: [0; N_SHED_REASONS],
                });
            e.jobs += lane.jobs;
            e.calls += lane.calls;
            e.svc_ns += lane.svc_ns;
            e.depth += lane.depth;
            for (dst, src) in e.sealed.iter_mut().zip(lane.sealed) {
                *dst += src;
            }
            for (dst, src) in e.shed.iter_mut().zip(lane.shed) {
                *dst += src;
            }
        }
    }
    let mut lanes: Vec<LaneStats> = by_model.into_values().collect();
    lanes.sort_by(|a, b| a.model.cmp(&b.model));
    ExecStats { interleaves, lanes }
}

/// Refit an f32 tensor payload to `target_elems` elements for the
/// stage-to-stage bridge of a pipeline chain: stage K's output rarely
/// matches stage K+1's input shape (a 1000-class logit vector feeding a
/// 3072-element image head), so the gateway truncates long tensors and
/// cycle-repeats short ones. Lossy on purpose — the experiments measure
/// the transport hop, not model semantics.
pub fn fit_f32(bytes: &[u8], target_elems: usize) -> Result<Vec<u8>> {
    if bytes.is_empty() || bytes.len() % 4 != 0 {
        bail!(
            "stage output is not an f32 tensor ({} bytes)",
            bytes.len()
        );
    }
    if target_elems == 0 {
        bail!("stage input shape is empty");
    }
    let want = target_elems * 4;
    if bytes.len() == want {
        return Ok(bytes.to_vec());
    }
    if bytes.len() > want {
        return Ok(bytes[..want].to_vec());
    }
    let mut out = Vec::with_capacity(want);
    while out.len() < want {
        let need = want - out.len();
        out.extend_from_slice(&bytes[..need.min(bytes.len())]);
    }
    Ok(out)
}

/// How the router reaches one backend: a label for tables/logs plus a
/// dial closure (any [`MsgTransport`], so a TCP-facing gateway can
/// dealer into an RDMA/GDR fabric exactly like the relay mode).
pub struct BackendSpec {
    pub label: String,
    connect: Box<dyn Fn() -> Result<Box<dyn MsgTransport>> + Send + Sync>,
}

impl BackendSpec {
    pub fn new<F>(label: impl Into<String>, connect: F) -> BackendSpec
    where
        F: Fn() -> Result<Box<dyn MsgTransport>> + Send + Sync + 'static,
    {
        BackendSpec {
            label: label.into(),
            connect: Box::new(connect),
        }
    }

    /// A TCP backend at `addr`, labelled by the address.
    pub fn tcp(addr: SocketAddr) -> BackendSpec {
        BackendSpec::new(addr.to_string(), move || {
            Ok(Box::new(TcpTransport::connect(addr)?) as Box<dyn MsgTransport>)
        })
    }
}

/// Router tuning knobs.
pub struct RouterCfg {
    pub placement: Placement,
    /// Vnodes per backend on the hash ring.
    pub vnodes: usize,
    /// Cadence of the gateway's background stats refresh.
    pub refresh: Duration,
    /// A backend whose snapshot shows at least this many queued jobs is
    /// saturated and routed around while a lighter backend exists.
    /// `u64::MAX` disables the depth signal.
    pub saturation_depth: u64,
    /// Treat a backend as saturated when its shed counters grew between
    /// consecutive snapshots (the shed-rate signal).
    pub shed_saturates: bool,
    /// How long a dead backend stays quarantined before an optimistic
    /// redial.
    pub retry_backoff: Duration,
}

impl Default for RouterCfg {
    fn default() -> RouterCfg {
        RouterCfg {
            placement: Placement::ConsistentHash,
            vnodes: DEFAULT_VNODES,
            refresh: Duration::from_millis(50),
            saturation_depth: u64::MAX,
            shed_saturates: true,
            retry_backoff: Duration::from_millis(500),
        }
    }
}

/// Mutable health/load view of one backend.
struct BackendState {
    up: bool,
    /// Set when the backend is down: when this instant passes, the next
    /// lease attempts an optimistic redial (half-open).
    retry_at: Option<Instant>,
    snapshot: Option<ExecStats>,
    saturated: bool,
    /// Shed total of the previous snapshot, for the delta signal.
    shed_seen: u64,
    /// Latest telemetry report (metrics opcode). `None` until the first
    /// successful metrics refresh — a v1 backend simply never fills it.
    metrics: Option<MetricsReport>,
}

struct Backend {
    spec: BackendSpec,
    state: Mutex<BackendState>,
    /// Idle pooled connections, reused across requests and clients.
    pool: Mutex<Vec<Box<dyn MsgTransport>>>,
    /// Requests answered by this backend (job-share accounting).
    jobs: AtomicU64,
    /// Live sticky assignments (least-loaded tie-break).
    assigned: AtomicU64,
}

/// The routing tier's placement + health state over N backends.
pub struct Router {
    cfg: RouterCfg,
    backends: Vec<Backend>,
    ring: HashRing,
    /// Least-loaded sticky model → backend map.
    sticky: Mutex<HashMap<String, usize>>,
    /// Cached model shapes from the shape opcode (pipeline bridge).
    shapes: Mutex<HashMap<String, (usize, usize)>>,
    /// Routing decisions that diverged from the policy's home placement
    /// (hash: walked off the home vnode owner; least-loaded: sticky
    /// reassignment). Counted per request.
    rebalances: AtomicU64,
}

impl Router {
    pub fn new(specs: Vec<BackendSpec>, cfg: RouterCfg) -> Router {
        assert!(!specs.is_empty(), "router needs at least one backend");
        let ring = HashRing::new(specs.len(), cfg.vnodes);
        let backends = specs
            .into_iter()
            .map(|spec| Backend {
                spec,
                state: Mutex::new(BackendState {
                    up: true,
                    retry_at: None,
                    snapshot: None,
                    saturated: false,
                    shed_seen: 0,
                    metrics: None,
                }),
                pool: Mutex::new(Vec::new()),
                jobs: AtomicU64::new(0),
                assigned: AtomicU64::new(0),
            })
            .collect();
        Router {
            cfg,
            backends,
            ring,
            sticky: Mutex::new(HashMap::new()),
            shapes: Mutex::new(HashMap::new()),
            rebalances: AtomicU64::new(0),
        }
    }

    pub fn cfg(&self) -> &RouterCfg {
        &self.cfg
    }

    pub fn n_backends(&self) -> usize {
        self.backends.len()
    }

    pub fn label(&self, idx: usize) -> &str {
        &self.backends[idx].spec.label
    }

    /// Requests answered per backend (job-share accounting).
    pub fn jobs_per_backend(&self) -> Vec<u64> {
        self.backends
            .iter()
            .map(|b| b.jobs.load(Ordering::Relaxed))
            .collect()
    }

    /// Count one answered request against backend `idx`.
    pub fn note_job(&self, idx: usize) {
        self.backends[idx].jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn rebalances(&self) -> u64 {
        self.rebalances.load(Ordering::Relaxed)
    }

    /// A backend is usable when up, or down but past its retry backoff
    /// (half-open: the next lease redials it).
    pub fn is_usable(&self, idx: usize) -> bool {
        let st = self.backends[idx].state.lock().unwrap();
        st.up
            || st
                .retry_at
                .map(|t| Instant::now() >= t)
                .unwrap_or(true)
    }

    fn is_saturated(&self, idx: usize) -> bool {
        self.backends[idx].state.lock().unwrap().saturated
    }

    /// Choose the backend for one request on `model`, honouring health
    /// and saturation. Errors only when every backend is down and still
    /// inside its backoff window.
    pub fn route(&self, model: &str) -> Result<usize> {
        let n = self.backends.len();
        match self.cfg.placement {
            Placement::ConsistentHash => {
                let home = self.ring.place(model);
                let mut fallback = None;
                for step in 0..n {
                    let idx = (home + step) % n;
                    if !self.is_usable(idx) {
                        continue;
                    }
                    if !self.is_saturated(idx) {
                        if idx != home {
                            self.rebalances.fetch_add(1, Ordering::Relaxed);
                        }
                        return Ok(idx);
                    }
                    if fallback.is_none() {
                        fallback = Some(idx);
                    }
                }
                // Everything usable is saturated: the home (or nearest
                // usable) backend still beats an error.
                match fallback {
                    Some(idx) => {
                        if idx != home {
                            self.rebalances.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(idx)
                    }
                    None => bail!("all {n} backends down for {model}"),
                }
            }
            Placement::LeastLoaded => {
                let mut sticky = self.sticky.lock().unwrap();
                if let Some(&idx) = sticky.get(model) {
                    if self.is_usable(idx) && !self.is_saturated(idx) {
                        return Ok(idx);
                    }
                }
                let pick = self.pick_backend(model)?;
                self.backends[pick].assigned.fetch_add(1, Ordering::Relaxed);
                match sticky.insert(model.to_string(), pick) {
                    Some(prev) if prev == pick => {
                        // Re-placed onto the same backend (e.g. every
                        // backend saturated): undo the double count.
                        self.backends[pick].assigned.fetch_sub(1, Ordering::Relaxed);
                    }
                    Some(prev) => {
                        self.backends[prev].assigned.fetch_sub(1, Ordering::Relaxed);
                        self.rebalances.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {}
                }
                Ok(pick)
            }
        }
    }

    fn pick_backend(&self, model: &str) -> Result<usize> {
        let candidates: Vec<Option<(bool, u64, u64)>> = self
            .backends
            .iter()
            .enumerate()
            .map(|(idx, b)| {
                if !self.is_usable(idx) {
                    return None;
                }
                let st = b.state.lock().unwrap();
                let depth = st.snapshot.as_ref().map(queue_depth).unwrap_or(0);
                Some((st.saturated, depth, b.assigned.load(Ordering::Relaxed)))
            })
            .collect();
        pick_least_loaded(&candidates)
            .ok_or_else(|| anyhow!("all {} backends down for {model}", self.backends.len()))
    }

    /// Take a connection to backend `idx` from its pool, dialing a new
    /// one when empty. A successful dial flips a half-open backend back
    /// up; a failed dial re-quarantines it.
    pub fn lease(&self, idx: usize) -> Result<Box<dyn MsgTransport>> {
        if let Some(conn) = self.backends[idx].pool.lock().unwrap().pop() {
            return Ok(conn);
        }
        match (self.backends[idx].spec.connect)() {
            Ok(conn) => {
                let mut st = self.backends[idx].state.lock().unwrap();
                st.up = true;
                st.retry_at = None;
                Ok(conn)
            }
            Err(e) => {
                self.mark_down(idx);
                Err(e)
            }
        }
    }

    /// Return a healthy connection to the pool for reuse.
    pub fn release(&self, idx: usize, conn: Box<dyn MsgTransport>) {
        self.backends[idx].pool.lock().unwrap().push(conn);
    }

    /// Quarantine a backend after a connect or I/O failure: drop its
    /// pooled connections (they share the dead peer) and schedule an
    /// optimistic redial after the backoff.
    pub fn mark_down(&self, idx: usize) {
        self.backends[idx].pool.lock().unwrap().clear();
        let mut st = self.backends[idx].state.lock().unwrap();
        st.up = false;
        st.retry_at = Some(Instant::now() + self.cfg.retry_backoff);
        st.saturated = false;
        st.snapshot = None;
        st.metrics = None;
    }

    /// Install a stats snapshot for backend `idx`, deriving the
    /// saturation flag from the depth threshold and the shed delta
    /// against the previous snapshot. Used by [`Router::refresh_now`]
    /// and directly by tests (no sockets needed).
    pub fn install_stats(&self, idx: usize, stats: ExecStats) {
        let mut st = self.backends[idx].state.lock().unwrap();
        let sheds = shed_total(&stats);
        let shed_grew = st.snapshot.is_some() && sheds > st.shed_seen;
        st.shed_seen = sheds;
        st.saturated = queue_depth(&stats) >= self.cfg.saturation_depth
            || (self.cfg.shed_saturates && shed_grew);
        st.snapshot = Some(stats);
    }

    /// Fetch fresh stats from every reachable backend (lease → stats
    /// opcode → release); unreachable backends are marked down. Returns
    /// how many backends answered. The gateway runs this on the
    /// [`RouterCfg::refresh`] cadence; tests call it directly for
    /// determinism.
    pub fn refresh_now(&self) -> usize {
        let mut answered = 0;
        for idx in 0..self.backends.len() {
            if !self.is_usable(idx) {
                continue;
            }
            let Ok(mut conn) = self.lease(idx) else {
                continue;
            };
            match fetch_stats(conn.as_mut()) {
                Ok(stats) => {
                    self.release(idx, conn);
                    self.install_stats(idx, stats);
                    answered += 1;
                }
                Err(_) => self.mark_down(idx),
            }
        }
        answered
    }

    /// Merge the latest snapshots into one fleet view ([`merge_stats`]).
    pub fn merged_stats(&self) -> ExecStats {
        let snaps: Vec<ExecStats> = self
            .backends
            .iter()
            .filter_map(|b| b.state.lock().unwrap().snapshot.clone())
            .collect();
        merge_stats(snaps.iter())
    }

    /// Install a telemetry report for backend `idx`. Used by
    /// [`Router::refresh_metrics_now`] and directly by tests.
    pub fn install_metrics(&self, idx: usize, report: MetricsReport) {
        self.backends[idx].state.lock().unwrap().metrics = Some(report);
    }

    /// Fetch fresh telemetry from every reachable backend (lease →
    /// metrics opcode → release). A backend that answers with a
    /// protocol-level error (e.g. predates the opcode) is left healthy
    /// with no report — only health, not metrics support, gates routing.
    /// Returns how many backends answered.
    pub fn refresh_metrics_now(&self) -> usize {
        let mut answered = 0;
        for idx in 0..self.backends.len() {
            if !self.is_usable(idx) {
                continue;
            }
            let Ok(mut conn) = self.lease(idx) else {
                continue;
            };
            match fetch_metrics(conn.as_mut()) {
                Ok(report) => {
                    self.release(idx, conn);
                    self.install_metrics(idx, report);
                    answered += 1;
                }
                // Drop the connection (its stream state is unknown) but
                // do not quarantine: an Err reply proves the peer is up.
                Err(_) => {}
            }
        }
        answered
    }

    /// Merge the latest telemetry reports into one fleet snapshot —
    /// bucket-wise histogram sums, counter/gauge sums, rings dropped
    /// ([`MetricsReport::merged`]). The gateway's answer to the metrics
    /// opcode.
    pub fn merged_metrics(&self) -> MetricsReport {
        let reports: Vec<MetricsReport> = self
            .backends
            .iter()
            .filter_map(|b| b.state.lock().unwrap().metrics.clone())
            .collect();
        MetricsReport::merged(reports.iter())
    }

    /// Resolve (and cache) `model`'s per-request tensor shape by asking
    /// backend `idx` the shape opcode. The connection is dropped rather
    /// than pooled on failure — an Err reply leaves it healthy but a
    /// transport fault does not, and redialing is cheap.
    pub fn shape_of(&self, model: &str, idx: usize) -> Result<(usize, usize)> {
        if let Some(&shape) = self.shapes.lock().unwrap().get(model) {
            return Ok(shape);
        }
        let mut conn = self.lease(idx)?;
        match fetch_shape(conn.as_mut(), model) {
            Ok(shape) => {
                self.release(idx, conn);
                self.shapes.lock().unwrap().insert(model.to_string(), shape);
                Ok(shape)
            }
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lane(model: &str, depth: u32, shed: u64) -> LaneStats {
        LaneStats {
            model: model.to_string(),
            jobs: 1,
            calls: 1,
            svc_ns: 1000,
            depth,
            sealed: [0; N_SEAL_REASONS],
            shed: [shed, 0],
        }
    }

    fn snap(lanes: Vec<LaneStats>) -> ExecStats {
        ExecStats {
            interleaves: 0,
            lanes,
        }
    }

    #[test]
    fn ring_is_deterministic_and_covers_all_backends() {
        let a = HashRing::new(3, DEFAULT_VNODES);
        let b = HashRing::new(3, DEFAULT_VNODES);
        let mut seen = [false; 3];
        for k in 0..200 {
            let model = format!("model-{k}");
            let idx = a.place(&model);
            assert_eq!(idx, b.place(&model), "placement must be pure");
            assert!(idx < 3);
            seen[idx] = true;
        }
        assert!(seen.iter().all(|&s| s), "every backend owns some models");
    }

    #[test]
    fn pick_least_loaded_orders_by_saturation_depth_assignment_index() {
        // Lower depth wins.
        assert_eq!(
            pick_least_loaded(&[Some((false, 5, 0)), Some((false, 2, 0))]),
            Some(1)
        );
        // Saturation loses to any non-saturated backend, even deeper.
        assert_eq!(
            pick_least_loaded(&[Some((true, 0, 0)), Some((false, 9, 0))]),
            Some(1)
        );
        // Depth tie: fewer sticky assignments wins (cold-start spread).
        assert_eq!(
            pick_least_loaded(&[Some((false, 0, 3)), Some((false, 0, 1))]),
            Some(1)
        );
        // Full tie: lowest index wins; down backends are skipped.
        assert_eq!(
            pick_least_loaded(&[None, Some((false, 0, 0)), Some((false, 0, 0))]),
            Some(1)
        );
        assert_eq!(pick_least_loaded(&[None, None]), None);
    }

    #[test]
    fn merge_stats_sums_lanes_by_model() {
        let a = snap(vec![lane("m0", 2, 1), lane("m1", 1, 0)]);
        let b = snap(vec![lane("m1", 3, 2)]);
        let merged = merge_stats([&a, &b]);
        assert_eq!(merged.lanes.len(), 2);
        assert_eq!(merged.lanes[0].model, "m0");
        assert_eq!(merged.lanes[1].model, "m1");
        assert_eq!(merged.lanes[1].jobs, 2);
        assert_eq!(merged.lanes[1].depth, 4);
        assert_eq!(merged.lanes[1].shed[0], 2);
        assert_eq!(queue_depth(&merged), 6);
        assert_eq!(shed_total(&merged), 3);
    }

    #[test]
    fn fit_f32_truncates_repeats_and_rejects() {
        let four = vec![1u8, 2, 3, 4];
        assert_eq!(fit_f32(&four, 1).unwrap(), four);
        // Truncate: 2 elems → 1.
        let eight = vec![1u8, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(fit_f32(&eight, 1).unwrap(), four);
        // Cycle-repeat: 1 elem → 3, including a partial tail repeat.
        assert_eq!(
            fit_f32(&four, 3).unwrap(),
            vec![1, 2, 3, 4, 1, 2, 3, 4, 1, 2, 3, 4]
        );
        assert!(fit_f32(&[], 1).is_err());
        assert!(fit_f32(&[1, 2, 3], 1).is_err(), "not f32-aligned");
        assert!(fit_f32(&four, 0).is_err());
    }

    #[test]
    fn placement_names_round_trip() {
        for p in Placement::all() {
            assert_eq!(Placement::by_name(p.name()), Some(p));
        }
        assert_eq!(Placement::by_name("consistent_hash"), Some(Placement::ConsistentHash));
        assert_eq!(Placement::by_name("bogus"), None);
    }

    #[test]
    fn shed_delta_saturates_and_depth_threshold_applies() {
        let specs = vec![
            BackendSpec::new("a", || bail!("offline test backend")),
            BackendSpec::new("b", || bail!("offline test backend")),
        ];
        let router = Router::new(
            specs,
            RouterCfg {
                placement: Placement::LeastLoaded,
                saturation_depth: 10,
                ..RouterCfg::default()
            },
        );
        // First snapshot only records the shed baseline.
        router.install_stats(0, snap(vec![lane("m", 0, 5)]));
        router.install_stats(1, snap(vec![lane("m", 0, 0)]));
        assert_eq!(router.route("m").unwrap(), 0, "tie breaks to index 0");
        // Backend 0's sheds grow → saturated → sticky assignment moves.
        router.install_stats(0, snap(vec![lane("m", 0, 6)]));
        assert_eq!(router.route("m").unwrap(), 1);
        assert_eq!(router.rebalances(), 1);
        // Depth threshold saturates backend 1; backend 0's flag cleared
        // by a calm snapshot → moves back.
        router.install_stats(0, snap(vec![lane("m", 0, 6)]));
        router.install_stats(1, snap(vec![lane("m", 12, 0)]));
        assert_eq!(router.route("m").unwrap(), 0);
        assert_eq!(router.rebalances(), 2);
    }
}
