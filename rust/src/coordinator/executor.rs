//! The GPU-side execution service: a priority queue of inference jobs,
//! a **cross-request dynamic batcher**, and a pool of execution streams.
//!
//! This is the live-plane mirror of the simulated stream scheduler:
//! `streams` bounds execution concurrency (Fig 15's trade-off), the
//! priority queue implements client priorities (Fig 16), and the
//! batcher exploits the per-batch compiled `_b{2,4,8}` artifacts —
//! batching is the knob that moves the compute/communication ratio the
//! paper's transport comparison turns on.
//!
//! # Request lifecycle
//!
//! 1. **Submit** — [`Executor::submit`] pushes a [`Job`] onto the
//!    priority queue (max-heap on priority, FIFO within a priority) and
//!    returns the caller a reply channel. Each server connection thread
//!    blocks on its own reply channel ([`Executor::infer_sync`]), so
//!    scattering batched outputs back to the right client connection is
//!    just answering each job's channel.
//! 2. **Coalesce** — a dedicated batcher thread, the queue's *only*
//!    consumer, pops the highest-priority head job and gathers
//!    compatible peers (same model, same priority, same payload
//!    length, preprocessed tensors) behind it into one batch. It seals the batch when it
//!    reaches [`BatchCfg::max_batch`] jobs, or when
//!    [`BatchCfg::flush_us`] has elapsed since the head was enqueued —
//!    whichever comes first — so a lone request is never held past the
//!    flush deadline; a higher-priority arrival aborts the gather and
//!    requeues it, so priority clients overtake even a half-built
//!    lower-priority batch. Being the sole consumer makes coalescing
//!    deterministic: no worker can race the batcher for a peer job.
//! 3. **Execute** — sealed batches pass over a rendezvous channel to
//!    the stream workers (the zero-capacity handoff keeps at most one
//!    batch committed ahead of the queue, preserving priority
//!    overtaking). A worker splits the batch greedily onto the largest
//!    batch executables the manifest actually provides (e.g. 7 jobs run
//!    as `_b4` + `_b2` + `_b1`) and scatters the per-request output
//!    rows back through each job's reply channel.
//!
//! PJRT clients are thread-local (`Rc`-based in the xla crate), so each
//! execution stream worker owns a full `Engine` — one PJRT "device
//! context" per stream, like one CUDA stream + TensorRT context each.

use std::collections::BinaryHeap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::models::manifest::Manifest;
use crate::runtime::{Engine, TensorBuf};

use super::protocol::StageNs;

/// One queued inference job.
pub struct Job {
    pub model: String,
    pub raw: bool,
    pub prio: u8,
    pub payload: TensorBuf,
    pub reply: mpsc::Sender<Result<Done>>,
    enqueued: Instant,
    seq: u64,
}

/// Completed job: output plus server-side stage timings and the size of
/// the executed batch this job rode in (1 = ran alone).
#[derive(Debug, Clone)]
pub struct Done {
    pub output: Vec<f32>,
    pub stages: StageNs,
    /// How many requests were fused into the executable call that
    /// produced this output (the `_bN` artifact's N).
    pub batch: usize,
}

struct Queued(Job);

impl PartialEq for Queued {
    fn eq(&self, o: &Self) -> bool {
        self.0.prio == o.0.prio && self.0.seq == o.0.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Queued {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first, then FIFO by sequence.
        (self.0.prio, std::cmp::Reverse(self.0.seq))
            .cmp(&(o.0.prio, std::cmp::Reverse(o.0.seq)))
    }
}

struct Shared {
    queue: Mutex<BinaryHeap<Queued>>,
    cv: Condvar,
    stop: AtomicBool,
    seq: AtomicU64,
    /// Workers currently parked waiting for a batch. The gather loop
    /// seals early when it is sitting on incompatible work while a
    /// stream is idle — holding a flush window only makes sense when
    /// every stream is busy anyway.
    idle_workers: AtomicU64,
    /// Jobs executed (batched or not) — numerator of the mean batch size.
    jobs_run: AtomicU64,
    /// Executable calls issued — denominator of the mean batch size.
    batches_run: AtomicU64,
}

/// Dynamic-batching policy: how aggressively concurrent requests are
/// coalesced onto the `_b{2,4,8}` batch executables.
///
/// The two knobs span the paper's batching-vs-latency tradeoff:
/// `max_batch` caps how much compute is fused per executable call (and
/// therefore how far the compute/communication ratio shifts), and
/// `flush_us` bounds the extra queueing latency a request can pay
/// waiting for peers. `accelserve batchsweep` measures the whole grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchCfg {
    /// Largest batch the coalescer may form (1 disables batching).
    /// Batches are executed on the largest manifest-provided batch
    /// executables that fit, so any value is safe — 6 runs as 4 + 2.
    pub max_batch: usize,
    /// Flush deadline in microseconds: how long the batch head may wait
    /// for peers after being enqueued. 0 = opportunistic only (coalesce
    /// whatever is already queued, never wait). Clamped to 10 minutes
    /// at the point of use; a higher-priority arrival always interrupts
    /// the gather regardless of the deadline.
    pub flush_us: u64,
}

impl Default for BatchCfg {
    fn default() -> BatchCfg {
        BatchCfg::none()
    }
}

impl BatchCfg {
    /// Batching disabled: every request executes alone.
    pub fn none() -> BatchCfg {
        BatchCfg {
            max_batch: 1,
            flush_us: 0,
        }
    }

    /// Coalesce whatever is already queued, up to `max_batch`, without
    /// ever delaying the head request.
    pub fn opportunistic(max_batch: usize) -> BatchCfg {
        BatchCfg {
            max_batch,
            flush_us: 0,
        }
    }

    /// Deadline batching: hold the head up to `flush_us` microseconds
    /// for peers, sealing early the moment the batch fills.
    pub fn deadline(max_batch: usize, flush_us: u64) -> BatchCfg {
        BatchCfg {
            max_batch,
            flush_us,
        }
    }

    /// Compact policy label for tables and CLI output: `b1`, `b8`
    /// (opportunistic), `b8@2000us` (deadline).
    pub fn label(&self) -> String {
        if self.flush_us == 0 {
            format!("b{}", self.max_batch)
        } else {
            format!("b{}@{}us", self.max_batch, self.flush_us)
        }
    }

    /// Parse a CLI policy spec: `"1"`, `"8"` (opportunistic) or
    /// `"8@2000"` (deadline, flush in µs).
    pub fn parse(s: &str) -> Option<BatchCfg> {
        let (b, flush) = match s.split_once('@') {
            None => (s, 0u64),
            Some((b, f)) => (b, f.trim_end_matches("us").parse().ok()?),
        };
        let max_batch: usize = b.trim_start_matches('b').parse().ok()?;
        if max_batch == 0 {
            return None;
        }
        Some(BatchCfg {
            max_batch,
            flush_us: flush,
        })
    }
}

/// Handle to a running executor: the batcher thread plus the stream
/// worker pool (see the module docs for the three-stage lifecycle).
pub struct Executor {
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Start the batcher plus `streams` execution workers over the
    /// artifact directory; each worker eagerly compiles the artifacts
    /// in `warm`.
    pub fn start(
        artifact_dir: impl Into<PathBuf>,
        streams: usize,
        batch: BatchCfg,
        warm: &[&str],
    ) -> Result<Executor> {
        assert!(streams >= 1);
        let dir: PathBuf = artifact_dir.into();
        // The batcher needs the batch-size menu up front to know how
        // long a batch is worth holding; loading the manifest here also
        // fails fast on an unusable artifact directory.
        let manifest = Manifest::load(&dir)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            idle_workers: AtomicU64::new(0),
            jobs_run: AtomicU64::new(0),
            batches_run: AtomicU64::new(0),
        });
        // Rendezvous handoff: the batcher blocks until a worker is free,
        // so at most one sealed batch is committed ahead of the queue
        // and later high-priority arrivals still overtake queued work.
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Vec<Job>>(0);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let warm: Vec<String> = warm.iter().map(|s| s.to_string()).collect();
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for _ in 0..streams {
            let sh = shared.clone();
            let dir = dir.clone();
            let warm = warm.clone();
            let ready = ready_tx.clone();
            let rx = batch_rx.clone();
            workers.push(std::thread::spawn(move || {
                let engine = match Engine::load(&dir).and_then(|e| {
                    let names: Vec<&str> = warm.iter().map(String::as_str).collect();
                    e.warm(&names)?;
                    Ok(e)
                }) {
                    Ok(e) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                worker_loop(sh, engine, rx)
            }));
        }
        drop(ready_tx);
        for _ in 0..streams {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during startup"))??;
        }
        let sh = shared.clone();
        let batcher = std::thread::spawn(move || batcher_loop(sh, manifest, batch, batch_tx));
        Ok(Executor {
            shared,
            batcher: Some(batcher),
            workers,
        })
    }

    /// Submit a job; the reply arrives on the returned channel.
    pub fn submit(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
    ) -> mpsc::Receiver<Result<Done>> {
        let (tx, rx) = mpsc::channel();
        let job = Job {
            model: model.to_string(),
            raw,
            prio,
            payload,
            reply: tx,
            enqueued: Instant::now(),
            seq: self.shared.seq.fetch_add(1, Ordering::Relaxed),
        };
        self.shared.queue.lock().unwrap().push(Queued(job));
        self.shared.cv.notify_one();
        rx
    }

    /// Convenience: submit and wait.
    pub fn infer_sync(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
    ) -> Result<Done> {
        self.submit(model, raw, prio, payload)
            .recv()
            .map_err(|_| anyhow!("executor dropped the job"))?
    }

    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Lifetime execution counters `(jobs, executable_calls)`: the mean
    /// achieved batch size is `jobs / executable_calls`. Observability
    /// for the `batchsweep` experiment.
    pub fn batch_counters(&self) -> (u64, u64) {
        (
            self.shared.jobs_run.load(Ordering::Relaxed),
            self.shared.batches_run.load(Ordering::Relaxed),
        )
    }

    /// Stop the batcher and workers and join them. Jobs still queued
    /// are dropped; their reply channels report the executor as gone.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The coalescing stage: pop the highest-priority head, gather a batch
/// behind it, hand it to a worker. Sole consumer of the job queue.
fn batcher_loop(
    sh: Arc<Shared>,
    manifest: Manifest,
    cfg: BatchCfg,
    tx: mpsc::SyncSender<Vec<Job>>,
) {
    loop {
        let head = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(j) = q.pop() {
                    break j.0;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        let jobs = gather(&sh, &manifest, cfg, head);
        if jobs.is_empty() {
            continue; // gather yielded to a higher-priority arrival
        }
        if tx.send(jobs).is_err() {
            return; // all workers gone
        }
    }
}

/// How many jobs a batch headed by `model` is worth gathering: capped
/// by policy, and 1 when the manifest has no batched executable to
/// exploit (holding jobs would add latency for nothing).
fn gather_cap(manifest: &Manifest, model: &str, raw: bool, cfg: BatchCfg) -> usize {
    if raw || cfg.max_batch <= 1 {
        return 1;
    }
    let has_batched = manifest
        .batch_sizes(model)
        .into_iter()
        .any(|b| b > 1 && b <= cfg.max_batch);
    if has_batched {
        cfg.max_batch
    } else {
        1
    }
}

/// Upper bound on the flush deadline (10 minutes, in µs): keeps an
/// absurd `flush_us` from overflowing the `Instant` arithmetic below
/// while staying far above any sane serving policy.
const FLUSH_US_MAX: u64 = 600_000_000;

/// Coalesce compatible queued jobs behind `head`: same model, same
/// priority, same payload length, `F32` tensors (the only thing the
/// batched executables concatenate — so a malformed request runs, and
/// fails, alone). Seals when the batch fills, when `flush_us` has
/// elapsed since the head was enqueued, or when incompatible work is
/// waiting while a stream sits idle (holding a flush window only pays
/// when every stream is busy). A *higher-priority* arrival instead
/// aborts the gather entirely — the gathered jobs go back on the
/// queue (original sequence numbers restore FIFO) and an empty vec
/// tells the batcher to restart from the new, higher-priority head,
/// so a priority client overtakes even a half-built batch.
/// Incompatible jobs are swept aside once each and pushed back at
/// seal time, in their original priority order.
fn gather(sh: &Shared, manifest: &Manifest, cfg: BatchCfg, head: Job) -> Vec<Job> {
    let batchable = !head.raw && matches!(head.payload, TensorBuf::F32(_));
    let cap = if batchable {
        gather_cap(manifest, &head.model, false, cfg)
    } else {
        1
    };
    let mut jobs = vec![head];
    if cap <= 1 {
        return jobs;
    }
    let flush = Duration::from_micros(cfg.flush_us.min(FLUSH_US_MAX));
    let deadline = jobs[0].enqueued + flush;
    let mut q = sh.queue.lock().unwrap();
    let mut spill: Vec<Queued> = Vec::new();
    let mut preempted = false;
    loop {
        // Each queued job is popped at most once per gather: compatible
        // ones join the batch, the rest wait in `spill` until seal (the
        // batcher is the queue's only consumer, so nothing misses them).
        while jobs.len() < cap {
            match q.pop() {
                None => break,
                Some(Queued(j))
                    if j.model == jobs[0].model
                        && !j.raw
                        && j.prio == jobs[0].prio
                        && j.payload.len() == jobs[0].payload.len()
                        && matches!(j.payload, TensorBuf::F32(_)) =>
                {
                    jobs.push(j)
                }
                Some(other) => {
                    preempted |= other.0.prio > jobs[0].prio;
                    spill.push(other);
                }
            }
        }
        if preempted {
            // A higher-priority job (sitting in `spill`) must run before
            // everything gathered here: abandon the batch — the jobs go
            // back with their original sequence numbers, so FIFO order
            // is restored when they are re-popped after the priority
            // job dispatches. An empty return tells the batcher to
            // start over from the (now higher-priority) queue head.
            for j in jobs.drain(..) {
                q.push(Queued(j));
            }
            break;
        }
        let idle_starved = !spill.is_empty() && sh.idle_workers.load(Ordering::SeqCst) > 0;
        if jobs.len() >= cap || idle_starved || sh.stop.load(Ordering::SeqCst) {
            break;
        }
        let now = Instant::now();
        let Some(wait) = deadline.checked_duration_since(now) else {
            break; // flush deadline reached
        };
        if wait.is_zero() {
            break;
        }
        let (guard, _) = sh.cv.wait_timeout(q, wait).unwrap();
        q = guard;
    }
    for o in spill {
        q.push(o);
    }
    jobs
}

/// The execution stage: take sealed batches off the rendezvous channel
/// and run them. The `Mutex<Receiver>` is the usual shared-consumer
/// pattern — one idle worker holds the lock and blocks in `recv`.
fn worker_loop(sh: Arc<Shared>, engine: Engine, rx: Arc<Mutex<mpsc::Receiver<Vec<Job>>>>) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            sh.idle_workers.fetch_add(1, Ordering::SeqCst);
            let received = guard.recv();
            sh.idle_workers.fetch_sub(1, Ordering::SeqCst);
            match received {
                Ok(b) => b,
                Err(_) => return, // batcher gone: shutdown
            }
        };
        run_jobs(&engine, batch, &sh);
    }
}

/// Largest manifest-provided batch executable size <= `n` for `model`
/// (1 when the model has no batched variants).
fn artifact_chunk(manifest: &Manifest, model: &str, n: usize) -> usize {
    if n <= 1 {
        return 1;
    }
    manifest
        .batch_sizes(model)
        .into_iter()
        .filter(|&b| b <= n)
        .max()
        .unwrap_or(1)
}

/// Split a sealed batch greedily onto the largest available batch
/// executables (a 7-job batch runs as `_b4` + `_b2` + `_b1`).
fn run_jobs(engine: &Engine, mut jobs: Vec<Job>, sh: &Shared) {
    while !jobs.is_empty() {
        let b = if jobs[0].raw {
            1
        } else {
            artifact_chunk(engine.manifest(), &jobs[0].model, jobs.len())
        };
        let chunk: Vec<Job> = jobs.drain(..b).collect();
        sh.jobs_run.fetch_add(chunk.len() as u64, Ordering::Relaxed);
        sh.batches_run.fetch_add(1, Ordering::Relaxed);
        run_chunk(engine, chunk);
    }
}

fn run_chunk(engine: &Engine, jobs: Vec<Job>) {
    let t_deq = Instant::now();
    let queue_ns: Vec<u64> = jobs
        .iter()
        .map(|j| t_deq.duration_since(j.enqueued).as_nanos() as u64)
        .collect();

    if jobs.len() == 1 && jobs[0].raw {
        // Two-stage raw pipeline: preprocess artifact, then batch-1 model
        // (separately timed, like the paper's preprocessing stage).
        let job = &jobs[0];
        let t0 = Instant::now();
        let pre = match &job.payload {
            // U8Region is the GDR zero-copy case: the preprocess
            // artifact reads straight out of the registered region.
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => {
                engine.infer("preprocess", &job.payload)
            }
            TensorBuf::F32(_) => Err(anyhow!("raw job with non-u8 payload")),
        };
        match pre {
            Err(e) => {
                let _ = jobs[0].reply.send(Err(e));
            }
            Ok(pre) => {
                let t1 = Instant::now();
                let name = format!("{}_b1", job.model);
                let out = engine.infer(&name, &TensorBuf::F32(pre));
                let t2 = Instant::now();
                let done = out.map(|output| Done {
                    output,
                    stages: StageNs {
                        queue_ns: queue_ns[0],
                        preproc_ns: (t1 - t0).as_nanos() as u64,
                        infer_ns: (t2 - t1).as_nanos() as u64,
                    },
                    batch: 1,
                });
                let _ = jobs[0].reply.send(done);
            }
        }
        return;
    }

    // Preprocessed path, possibly batched: gather the rows, one
    // executable call, scatter the output rows back per request.
    let b = jobs.len();
    let name = format!("{}_b{}", jobs[0].model, b);
    let mut flat: Vec<f32> = Vec::new();
    for j in &jobs {
        match &j.payload {
            TensorBuf::F32(v) => flat.extend_from_slice(v),
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => {
                // Gather only fuses F32 payloads, so a chunk containing
                // a u8 payload is that single malformed job — but
                // answer every reply channel regardless: dropping a
                // fused peer's sender would fail an innocent request.
                for peer in &jobs {
                    let _ = peer.reply.send(Err(anyhow!("u8 payload without raw flag")));
                }
                return;
            }
        }
    }
    let t1 = Instant::now();
    let res = engine.infer(&name, &TensorBuf::F32(flat));
    let infer_ns = t1.elapsed().as_nanos() as u64;
    match res {
        Err(e) => {
            let msg = format!("{e}");
            for j in &jobs {
                let _ = j.reply.send(Err(anyhow!("{msg}")));
            }
        }
        Ok(out) => {
            let per = out.len() / b;
            for (i, j) in jobs.iter().enumerate() {
                let _ = j.reply.send(Ok(Done {
                    output: out[i * per..(i + 1) * per].to_vec(),
                    stages: StageNs {
                        queue_ns: queue_ns[i],
                        preproc_ns: 0,
                        infer_ns,
                    },
                    batch: b,
                }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A manifest with b1/b2/b4/b8 classifier variants plus an
    /// unbatched model, for exercising the size menu without artifacts.
    fn menu() -> Manifest {
        let mut artifacts = String::new();
        for b in [1usize, 2, 4, 8] {
            artifacts.push_str(&format!(
                r#"{{"name": "m_b{b}", "model": "m", "task": "c", "file": "m_b{b}.hlo.txt",
                    "inputs": [{{"shape": [{b}, 4], "dtype": "f32"}}],
                    "output": {{"shape": [{b}, 2], "dtype": "f32"}}}},"#
            ));
        }
        artifacts.push_str(
            r#"{"name": "solo_b1", "model": "solo", "task": "c", "file": "s.hlo.txt",
                "inputs": [{"shape": [1, 4], "dtype": "f32"}],
                "output": {"shape": [1, 2], "dtype": "f32"}}"#,
        );
        Manifest::parse(
            &format!(r#"{{"format": 1, "artifacts": [{artifacts}]}}"#),
            std::path::PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    #[test]
    fn artifact_chunk_picks_largest_available_leq() {
        let m = menu();
        assert_eq!(artifact_chunk(&m, "m", 1), 1);
        assert_eq!(artifact_chunk(&m, "m", 3), 2);
        assert_eq!(artifact_chunk(&m, "m", 5), 4);
        assert_eq!(artifact_chunk(&m, "m", 8), 8);
        assert_eq!(artifact_chunk(&m, "m", 100), 8);
        // No batched variants: always 1.
        assert_eq!(artifact_chunk(&m, "solo", 8), 1);
        assert_eq!(artifact_chunk(&m, "unknown", 8), 1);
    }

    #[test]
    fn gather_cap_respects_policy_and_menu() {
        let m = menu();
        assert_eq!(gather_cap(&m, "m", false, BatchCfg::none()), 1);
        assert_eq!(gather_cap(&m, "m", false, BatchCfg::opportunistic(8)), 8);
        // Odd caps are allowed — the chunker splits them (6 = 4 + 2).
        assert_eq!(gather_cap(&m, "m", false, BatchCfg::deadline(6, 100)), 6);
        // Raw jobs and menu-less models never wait for peers.
        assert_eq!(gather_cap(&m, "m", true, BatchCfg::opportunistic(8)), 1);
        assert_eq!(gather_cap(&m, "solo", false, BatchCfg::opportunistic(8)), 1);
    }

    #[test]
    fn batch_cfg_parse_and_label_roundtrip() {
        assert_eq!(BatchCfg::parse("1"), Some(BatchCfg::none()));
        assert_eq!(BatchCfg::parse("8"), Some(BatchCfg::opportunistic(8)));
        assert_eq!(BatchCfg::parse("8@2000"), Some(BatchCfg::deadline(8, 2000)));
        assert_eq!(BatchCfg::parse("b4@500us"), Some(BatchCfg::deadline(4, 500)));
        assert_eq!(BatchCfg::parse("0"), None);
        assert_eq!(BatchCfg::parse("x"), None);
        assert_eq!(BatchCfg::none().label(), "b1");
        assert_eq!(BatchCfg::opportunistic(8).label(), "b8");
        assert_eq!(BatchCfg::deadline(8, 2000).label(), "b8@2000us");
        for s in ["1", "8", "8@2000"] {
            let c = BatchCfg::parse(s).unwrap();
            assert_eq!(BatchCfg::parse(&c.label()), Some(c), "label {s}");
        }
    }

    #[test]
    fn priority_queue_orders_jobs() {
        let (tx, _rx) = mpsc::channel();
        let mk = |prio: u8, seq: u64| {
            Queued(Job {
                model: "m".into(),
                raw: false,
                prio,
                payload: TensorBuf::F32(vec![]),
                reply: tx.clone(),
                enqueued: Instant::now(),
                seq,
            })
        };
        let mut h = BinaryHeap::new();
        h.push(mk(0, 0));
        h.push(mk(5, 1));
        h.push(mk(0, 2));
        h.push(mk(5, 3));
        let order: Vec<(u8, u64)> = std::iter::from_fn(|| h.pop())
            .map(|q| (q.0.prio, q.0.seq))
            .collect();
        assert_eq!(order, vec![(5, 1), (5, 3), (0, 0), (0, 2)]);
    }
}
