//! The GPU-side execution service: **continuous multi-model batching**
//! — one bounded priority queue ("lane") per model, a scheduler that
//! seals batches independently per lane, and a shared pool of
//! execution streams.
//!
//! This is the live-plane mirror of the simulated stream scheduler:
//! `streams` bounds execution concurrency (Fig 15's trade-off), the
//! per-lane priority heaps implement client priorities (Fig 16), and
//! the batcher exploits the per-batch compiled `_b{2,4,8}` artifacts —
//! batching is the knob that moves the compute/communication ratio the
//! paper's transport comparison turns on. Unlike a single-batcher
//! pipeline, lanes are *concurrent*: a `tiny_resnet` batch launches on
//! a free stream while a `tiny_mobilenet` gather is still filling, so
//! a mixed workload never serializes behind whichever model currently
//! owns the batcher.
//!
//! # Request lifecycle
//!
//! 1. **Submit** — [`Executor::submit`] routes a [`Job`] to its
//!    model's lane (a bounded max-heap on priority, FIFO within a
//!    priority; overflow is rejected immediately on the reply channel)
//!    and returns the caller a reply channel. Each server connection
//!    thread blocks on its own reply channel
//!    ([`Executor::infer_sync`]), so scattering batched outputs back
//!    to the right client connection is just answering each job's
//!    channel.
//! 2. **Schedule** — a single scheduler thread watches every lane.
//!    A lane's head group (compatible same-priority peers behind the
//!    highest-priority job) seals when it reaches the lane's
//!    [`BatchCfg::max_batch`], when [`BatchCfg::flush_us`] has elapsed
//!    since the head was enqueued, immediately under an opportunistic
//!    policy, or early when incompatible work waits in the same lane
//!    while a stream is idle. Jobs stay in the lane heap until the
//!    moment of sealing, so a higher-priority arrival overtakes a
//!    half-built gather of its own model by construction — it simply
//!    becomes the new head. When several lanes are sealable, a
//!    **weighted round-robin** (per-model `weight`, default 1) picks
//!    the next lane, so no model starves behind a busier one.
//! 3. **Execute** — sealed batches are handed to idle stream workers
//!    (at most one sealed batch per parked worker is ever committed
//!    ahead of the queues, preserving priority overtaking). A worker
//!    splits the batch greedily onto the largest batch executables the
//!    manifest actually provides (e.g. 7 jobs run as `_b4` + `_b2` +
//!    `_b1`) and scatters the per-request output rows back through
//!    each job's reply channel.
//!
//! PJRT clients are thread-local (`Rc`-based in the xla crate), so each
//! execution stream worker owns a full `Engine` — one PJRT "device
//! context" per stream, like one CUDA stream + TensorRT context each.

use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::telemetry::{
    labeled, Counter, Gauge, HistoHandle, MetricsReport, Registry, Sample, Sampler,
    DEFAULT_RING_CAP, DEFAULT_SAMPLE_MS,
};
use crate::models::manifest::{Manifest, TensorSpec};
use crate::runtime::{Engine, TensorBuf};
use crate::trace::{SpanRec, Stamp};

use super::protocol::StageNs;

/// Why admission control rejected a job at submit time — the wire
/// codes of the protocol's `Shed` status and the index into the
/// per-lane shed counters (see [`SHED_REASON_NAMES`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ShedReason {
    /// The model's lane was at [`SchedCfg::queue_cap`].
    QueueFull = 0,
    /// The request's deadline was already unwinnable at submit time
    /// (estimated queue + service time exceeded the remaining budget).
    Deadline = 1,
}

/// Number of shed reasons (width of the per-lane shed counter array).
pub const N_SHED_REASONS: usize = 2;

/// Shed-reason names, indexed like the counters.
pub const SHED_REASON_NAMES: [&str; N_SHED_REASONS] = ["queue_full", "deadline"];

impl ShedReason {
    /// Wire code (protocol `Shed` status reason byte).
    pub fn code(self) -> u8 {
        self as u8
    }

    /// Parse a wire code; `None` for unknown codes.
    pub fn from_code(c: u8) -> Option<ShedReason> {
        match c {
            0 => Some(ShedReason::QueueFull),
            1 => Some(ShedReason::Deadline),
            _ => None,
        }
    }

    /// Human-readable name (counter column label).
    pub fn name(self) -> &'static str {
        SHED_REASON_NAMES[self as usize]
    }
}

/// Typed executor failure: either admission control shed the job (a
/// load signal the client should see as the distinct wire `Shed`
/// status) or execution genuinely failed. Kept as a real enum — not a
/// stringly `anyhow::Error` — so the server can map the two onto
/// different wire statuses without parsing messages.
#[derive(Debug)]
pub enum ExecError {
    /// Admission control rejected the job before it was queued.
    Shed { reason: ShedReason, msg: String },
    /// The job was admitted but execution failed.
    Failed(anyhow::Error),
}

impl ExecError {
    /// Shorthand for a shed error.
    pub fn shed(reason: ShedReason, msg: impl Into<String>) -> ExecError {
        ExecError::Shed {
            reason,
            msg: msg.into(),
        }
    }

    /// The shed reason, if this is a shed (admission) error.
    pub fn shed_reason(&self) -> Option<ShedReason> {
        match self {
            ExecError::Shed { reason, .. } => Some(*reason),
            ExecError::Failed(_) => None,
        }
    }
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Shed { reason, msg } => write!(f, "shed ({}): {msg}", reason.name()),
            ExecError::Failed(e) => write!(f, "{e}"),
        }
    }
}

// Lets `?` lift an `ExecError` into `anyhow::Result` via the blanket
// `From<E: std::error::Error>` impl, so sync callers that don't care
// about the shed/failed distinction keep composing.
impl std::error::Error for ExecError {}

/// One queued inference job.
pub struct Job {
    pub model: String,
    pub raw: bool,
    pub prio: u8,
    pub payload: TensorBuf,
    pub reply: mpsc::Sender<Result<Done, ExecError>>,
    /// The request's trace span (enqueue/gather/seal/dispatch and the
    /// engine stamps are marked as the job moves through the pipeline).
    span: SpanRec,
    /// Absolute SLO deadline (submit time + the request's relative
    /// `deadline_us`); `None` = no SLO, scheduled purely by WRR.
    deadline: Option<Instant>,
    enqueued: Instant,
    seq: u64,
}

/// Completed job: output plus server-side stage timings, the size of
/// the executed batch this job rode in (1 = ran alone), and the
/// request's stamped trace span.
#[derive(Debug, Clone)]
pub struct Done {
    pub output: Vec<f32>,
    pub stages: StageNs,
    /// How many requests were fused into the executable call that
    /// produced this output (the `_bN` artifact's N).
    pub batch: usize,
    /// The span timeline stamped through lane/scheduler/engine; the
    /// server marks [`Stamp::ReplySend`] and ships it to the client
    /// when the request asked for spans (protocol v2).
    pub span: SpanRec,
}

/// Why a lane's head group sealed — the per-lane counters the stats
/// opcode reports, indexed in this order (see [`SEAL_REASON_NAMES`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum SealReason {
    /// Unbatchable head (raw / no batched artifact / `max_batch` 1).
    Single = 0,
    /// The gather reached the policy cap.
    Full = 1,
    /// Opportunistic policy (`flush_us` 0): took what was queued.
    Opportunistic = 2,
    /// The head's flush deadline expired.
    Deadline = 3,
    /// Incompatible work waited in the lane while a stream sat idle.
    Blocked = 4,
    /// Waiting any longer would have blown the head's SLO deadline
    /// (estimated service time ate the remaining budget).
    Slo = 5,
}

/// Number of seal reasons (width of the per-lane counter array).
pub const N_SEAL_REASONS: usize = 6;

/// Reason names, indexed like the counters.
pub const SEAL_REASON_NAMES: [&str; N_SEAL_REASONS] =
    ["single", "full", "opportunistic", "deadline", "blocked", "slo"];

/// One lane's counter snapshot (the stats opcode's per-lane row).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneStats {
    pub model: String,
    /// Jobs executed for this model.
    pub jobs: u64,
    /// Executable calls issued for this model (`jobs / calls` = mean
    /// achieved batch).
    pub calls: u64,
    /// Cumulative execution-stream time spent on this model, in ns —
    /// `svc_ns / jobs` is the per-job service estimate admission
    /// control prices deadlines with.
    pub svc_ns: u64,
    /// Jobs currently queued in the lane, not yet sealed.
    pub depth: u32,
    /// Sealed-batch counts by [`SealReason`].
    pub sealed: [u64; N_SEAL_REASONS],
    /// Jobs shed at submit by [`ShedReason`].
    pub shed: [u64; N_SHED_REASONS],
}

/// Executor-wide counter snapshot ([`Executor::stats`], carried over
/// the wire by the stats opcode).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Dispatches that switched model vs the previous dispatch.
    pub interleaves: u64,
    /// Per-lane counters, sorted by model name.
    pub lanes: Vec<LaneStats>,
}

/// Proactive-backpressure advice for one lane, computed by
/// [`Executor::credit_hint`] from the same per-lane counters admission
/// control prices deadlines with, and carried to credits-opted-in
/// clients in the protocol's status-5 envelope
/// (`protocol::encode_with_credit`). A well-behaved client that honours
/// the hint slows its closed loop *before* the submit edge would have
/// to shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CreditHint {
    /// In-flight window the client may keep: how many requests the lane
    /// has queue headroom for right now. `0` means back off — the lane
    /// shed since the last hint on this connection's model.
    pub credits: u16,
    /// Suggested inter-request gap in ns; `0` means no pacing needed
    /// (the lane is draining faster than it fills).
    pub pace_ns: u64,
}

struct Queued(Job);

impl PartialEq for Queued {
    fn eq(&self, o: &Self) -> bool {
        self.0.prio == o.0.prio && self.0.seq == o.0.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Queued {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first, then FIFO by sequence.
        (self.0.prio, std::cmp::Reverse(self.0.seq))
            .cmp(&(o.0.prio, std::cmp::Reverse(o.0.seq)))
    }
}

/// Dynamic-batching policy: how aggressively concurrent requests are
/// coalesced onto the `_b{2,4,8}` batch executables.
///
/// The two knobs span the paper's batching-vs-latency tradeoff:
/// `max_batch` caps how much compute is fused per executable call (and
/// therefore how far the compute/communication ratio shifts), and
/// `flush_us` bounds the extra queueing latency a request can pay
/// waiting for peers. `accelserve batchsweep` measures the whole grid;
/// `accelserve mixsweep` crosses it with multi-model traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchCfg {
    /// Largest batch the coalescer may form (1 disables batching).
    /// Batches are executed on the largest manifest-provided batch
    /// executables that fit, so any value is safe — 6 runs as 4 + 2.
    pub max_batch: usize,
    /// Flush deadline in microseconds: how long the batch head may wait
    /// for peers after being enqueued. 0 = opportunistic only (coalesce
    /// whatever is already queued, never wait). Clamped to 10 minutes
    /// at the point of use; a higher-priority arrival always overtakes
    /// the gather regardless of the deadline.
    pub flush_us: u64,
}

impl Default for BatchCfg {
    fn default() -> BatchCfg {
        BatchCfg::none()
    }
}

impl BatchCfg {
    /// Batching disabled: every request executes alone.
    pub fn none() -> BatchCfg {
        BatchCfg {
            max_batch: 1,
            flush_us: 0,
        }
    }

    /// Coalesce whatever is already queued, up to `max_batch`, without
    /// ever delaying the head request.
    pub fn opportunistic(max_batch: usize) -> BatchCfg {
        BatchCfg {
            max_batch,
            flush_us: 0,
        }
    }

    /// Deadline batching: hold the head up to `flush_us` microseconds
    /// for peers, sealing early the moment the batch fills.
    pub fn deadline(max_batch: usize, flush_us: u64) -> BatchCfg {
        BatchCfg {
            max_batch,
            flush_us,
        }
    }

    /// Compact policy label for tables and CLI output: `b1`, `b8`
    /// (opportunistic), `b8@2000us` (deadline).
    pub fn label(&self) -> String {
        if self.flush_us == 0 {
            format!("b{}", self.max_batch)
        } else {
            format!("b{}@{}us", self.max_batch, self.flush_us)
        }
    }

    /// Parse a CLI policy spec: `"1"`, `"8"` (opportunistic) or
    /// `"8@2000"` (deadline, flush in µs).
    pub fn parse(s: &str) -> Option<BatchCfg> {
        let (b, flush) = match s.split_once('@') {
            None => (s, 0u64),
            Some((b, f)) => (b, f.trim_end_matches("us").parse().ok()?),
        };
        let max_batch: usize = b.trim_start_matches('b').parse().ok()?;
        if max_batch == 0 {
            return None;
        }
        Some(BatchCfg {
            max_batch,
            flush_us: flush,
        })
    }
}

/// Per-model scheduling policy: a [`BatchCfg`] plus the lane's
/// round-robin `weight` (how many batches the lane may dispatch per
/// weighted-round-robin cycle relative to the other lanes; default 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelPolicy {
    /// Batching policy for this model's lane.
    pub cfg: BatchCfg,
    /// Weighted-round-robin share (clamped to >= 1 at the point of use).
    pub weight: u32,
}

impl ModelPolicy {
    /// Weight-1 policy around `cfg`.
    pub fn new(cfg: BatchCfg) -> ModelPolicy {
        ModelPolicy { cfg, weight: 1 }
    }

    /// Policy with an explicit round-robin weight.
    pub fn weighted(cfg: BatchCfg, weight: u32) -> ModelPolicy {
        ModelPolicy { cfg, weight }
    }

    /// Parse a policy spec: a [`BatchCfg::parse`] spec with an optional
    /// `*W` round-robin weight suffix — `"8@2000"`, `"4*2"`,
    /// `"8@500us*3"`.
    pub fn parse_spec(s: &str) -> Option<ModelPolicy> {
        let (cfg, weight) = match s.rsplit_once('*') {
            None => (s, 1u32),
            Some((c, w)) => (c, w.parse().ok().filter(|&w| w >= 1)?),
        };
        Some(ModelPolicy {
            cfg: BatchCfg::parse(cfg)?,
            weight,
        })
    }

    /// Parse a `model=SPEC` CLI entry (the repeatable `--model-batch`
    /// flag): `"tiny_resnet=8@2000"`, `"tiny_mobilenet=4*2"`.
    pub fn parse_entry(s: &str) -> Option<(String, ModelPolicy)> {
        let (model, spec) = s.split_once('=')?;
        if model.is_empty() {
            return None;
        }
        Some((model.to_string(), ModelPolicy::parse_spec(spec)?))
    }

    /// Compact label: the [`BatchCfg::label`] plus a `*W` suffix when
    /// the weight is not 1.
    pub fn label(&self) -> String {
        if self.weight <= 1 {
            self.cfg.label()
        } else {
            format!("{}*{}", self.cfg.label(), self.weight)
        }
    }
}

/// Default bound on each model lane's queue length.
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// Scheduler configuration: the global default [`BatchCfg`], per-model
/// overrides, and the per-lane queue bound.
#[derive(Debug, Clone)]
pub struct SchedCfg {
    /// Policy for models without a `per_model` override.
    pub default: BatchCfg,
    /// Per-model `(name, policy)` overrides — the scenario
    /// `model_batch` key / `--model-batch` CLI flag.
    pub per_model: Vec<(String, ModelPolicy)>,
    /// Max queued (not-yet-dispatched) jobs per lane; overflow is
    /// rejected immediately on the job's reply channel.
    pub queue_cap: usize,
}

impl SchedCfg {
    /// Every model gets `default`; no overrides.
    pub fn uniform(default: BatchCfg) -> SchedCfg {
        SchedCfg {
            default,
            per_model: Vec::new(),
            queue_cap: DEFAULT_QUEUE_CAP,
        }
    }

    /// Builder: add a per-model override.
    pub fn with_model(mut self, model: impl Into<String>, policy: ModelPolicy) -> SchedCfg {
        self.per_model.push((model.into(), policy));
        self
    }

    /// The policy a lane for `model` would run under.
    pub fn policy_for(&self, model: &str) -> ModelPolicy {
        self.per_model
            .iter()
            .find(|(m, _)| m == model)
            .map(|(_, p)| *p)
            .unwrap_or(ModelPolicy::new(self.default))
    }
}

/// One model's queue ("lane"): a bounded priority heap plus the lane's
/// resolved policy and its weighted-round-robin credit state.
struct Lane {
    heap: BinaryHeap<Queued>,
    cfg: BatchCfg,
    weight: u32,
    credits: u32,
    /// Sealed-batch counts by [`SealReason`] (stats opcode).
    sealed: [u64; N_SEAL_REASONS],
    /// Jobs shed at submit by [`ShedReason`] (stats opcode).
    shed: [u64; N_SHED_REASONS],
    /// Shed total as of the last [`Executor::credit_hint`] call: a
    /// nonzero delta means the lane shed since the last hint, so the
    /// next hint demands a hard back-off (zero credits).
    hint_shed_mark: u64,
}

impl Lane {
    /// Earliest SLO deadline among the lane's queued jobs; the EDF key.
    fn min_deadline(&self) -> Option<Instant> {
        self.heap.iter().filter_map(|q| q.0.deadline).min()
    }
}

/// Mutable scheduler state (behind `Shared::sched`): the lanes, the
/// sealed-batch handoff queue, and the worker-idle accounting.
struct Sched {
    lanes: HashMap<String, Lane>,
    /// Lane visit order for the weighted round-robin (insertion order).
    order: Vec<String>,
    /// Next lane the round-robin considers.
    cursor: usize,
    /// Sealed batches awaiting a worker. Invariant: never longer than
    /// `idle_workers`, so a sealed batch always has a parked worker —
    /// the N-worker generalization of a rendezvous handoff.
    ready: VecDeque<Vec<Job>>,
    /// Workers currently parked waiting for a batch.
    idle_workers: usize,
}

/// Pre-resolved handles into the always-on telemetry registry, built
/// once at startup so every hot-path event is an O(atomic add) — no
/// name lookup, no registry lock. Stage histograms are fed from the
/// same [`SpanRec`] stamps the trace plane uses, so the telemetry
/// plane's latency decomposition and an exported timeline agree by
/// construction.
struct ExecMetrics {
    reg: Arc<Registry>,
    /// `accel_jobs_total` — jobs executed.
    jobs: Counter,
    /// `accel_batches_total` — executable calls issued.
    batches: Counter,
    /// `accel_interleaves_total` — dispatches that switched model.
    interleaves: Counter,
    /// `accel_queue_depth` — jobs queued across all lanes right now.
    depth: Gauge,
    /// `accel_batch_size` — executed chunk size in jobs.
    batch_size: HistoHandle,
    /// `accel_svc_ns` — stream time per executable call.
    svc_ns: HistoHandle,
    /// `accel_seal_total{reason=…}`, indexed by [`SealReason`].
    sealed: [Counter; N_SEAL_REASONS],
    /// `accel_shed_total{reason=…}`, indexed by [`ShedReason`].
    shed: [Counter; N_SHED_REASONS],
    /// `accel_credit_grants_total` — credit hints computed.
    credit_grants: Counter,
    /// `accel_credit_tokens_total` — credit tokens granted.
    credit_tokens: Counter,
    /// `accel_stage_ns{stage=…}` — executor-visible pipeline stages.
    lane_queue_ns: HistoHandle,
    gather_wait_ns: HistoHandle,
    dispatch_wait_ns: HistoHandle,
    copy_h2d_ns: HistoHandle,
    preproc_ns: HistoHandle,
    infer_ns: HistoHandle,
    copy_d2h_ns: HistoHandle,
    /// `accel_exec_ns{model=…}` — enqueue→device-done latency per
    /// model, resolved lazily (once per model, per-chunk lookup).
    exec_ns: Mutex<HashMap<String, HistoHandle>>,
}

impl ExecMetrics {
    fn new(reg: Arc<Registry>) -> ExecMetrics {
        let stage = |s: &str| reg.histo(&labeled("accel_stage_ns", "stage", s));
        ExecMetrics {
            jobs: reg.counter("accel_jobs_total"),
            batches: reg.counter("accel_batches_total"),
            interleaves: reg.counter("accel_interleaves_total"),
            depth: reg.gauge("accel_queue_depth"),
            batch_size: reg.histo("accel_batch_size"),
            svc_ns: reg.histo("accel_svc_ns"),
            sealed: std::array::from_fn(|i| {
                reg.counter(&labeled("accel_seal_total", "reason", SEAL_REASON_NAMES[i]))
            }),
            shed: std::array::from_fn(|i| {
                reg.counter(&labeled("accel_shed_total", "reason", SHED_REASON_NAMES[i]))
            }),
            credit_grants: reg.counter("accel_credit_grants_total"),
            credit_tokens: reg.counter("accel_credit_tokens_total"),
            lane_queue_ns: stage("lane-queue"),
            gather_wait_ns: stage("gather-wait"),
            dispatch_wait_ns: stage("dispatch-wait"),
            copy_h2d_ns: stage("copy-h2d"),
            preproc_ns: stage("preproc"),
            infer_ns: stage("infer"),
            copy_d2h_ns: stage("copy-d2h"),
            exec_ns: Mutex::new(HashMap::new()),
            reg,
        }
    }

    /// The per-model end-to-end histogram, resolved once per model.
    fn exec_histo(&self, model: &str) -> HistoHandle {
        let mut m = self.exec_ns.lock().unwrap();
        if let Some(h) = m.get(model) {
            return Arc::clone(h);
        }
        let h = self.reg.histo(&labeled("accel_exec_ns", "model", model));
        m.insert(model.to_string(), Arc::clone(&h));
        h
    }

    /// Feed a completed job's span stamps into the stage histograms.
    /// Every interval is between stamps the executor itself marks, so
    /// a fully-run job observes all of them (preproc only on the raw
    /// path, where the stamp exists).
    fn observe_span(&self, exec_h: &HistoHandle, span: &SpanRec) {
        let g = |s: Stamp| span.get(s);
        let iv = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(a), Some(b)) => Some(b.saturating_sub(a)),
            _ => None,
        };
        if let Some(d) = iv(g(Stamp::Enqueue), g(Stamp::GatherStart)) {
            self.lane_queue_ns.observe(d);
        }
        if let Some(d) = iv(g(Stamp::GatherStart), g(Stamp::Seal)) {
            self.gather_wait_ns.observe(d);
        }
        if let Some(d) = iv(g(Stamp::Seal), g(Stamp::Dispatch)) {
            self.dispatch_wait_ns.observe(d);
        }
        if let Some(d) = iv(g(Stamp::Dispatch), g(Stamp::H2dDone)) {
            self.copy_h2d_ns.observe(d);
        }
        if let Some(d) = iv(g(Stamp::H2dDone), g(Stamp::PreprocDone)) {
            self.preproc_ns.observe(d);
        }
        let pre_infer = g(Stamp::PreprocDone).or_else(|| g(Stamp::H2dDone));
        if let Some(d) = iv(pre_infer, g(Stamp::InferDone)) {
            self.infer_ns.observe(d);
        }
        if let Some(d) = iv(g(Stamp::InferDone), g(Stamp::D2hDone)) {
            self.copy_d2h_ns.observe(d);
        }
        if let Some(d) = iv(g(Stamp::Enqueue), g(Stamp::D2hDone)) {
            exec_h.observe(d);
        }
    }
}

struct Shared {
    sched: Mutex<Sched>,
    /// Wakes the scheduler: new submission, or a worker went idle.
    sched_cv: Condvar,
    /// Wakes a parked worker: a sealed batch was pushed to `ready`.
    work_cv: Condvar,
    stop: AtomicBool,
    seq: AtomicU64,
    cfg: SchedCfg,
    /// Jobs executed (batched or not) — numerator of the mean batch size.
    jobs_run: AtomicU64,
    /// Executable calls issued — denominator of the mean batch size.
    batches_run: AtomicU64,
    /// Consecutive dispatches that switched model — the mixsweep's
    /// measure of cross-model concurrency.
    interleaves: AtomicU64,
    /// Per-model `(jobs, executable_calls, svc_ns)` counters; `svc_ns /
    /// jobs` is the per-job service estimate admission control and the
    /// SLO seal both price deadlines with.
    counters: Mutex<HashMap<String, (u64, u64, u64)>>,
    /// Execution-stream count: how many jobs drain concurrently, the
    /// divisor in the admission-control queue-delay estimate.
    streams: usize,
    /// Always-on telemetry handles (registry + pre-resolved series).
    tm: ExecMetrics,
}

impl Shared {
    /// The lane for `model`, created on first use with the resolved
    /// per-model policy. Caller holds the `sched` lock.
    fn lane<'a>(&self, s: &'a mut Sched, model: &str) -> &'a mut Lane {
        let Sched { lanes, order, .. } = s;
        lanes.entry(model.to_string()).or_insert_with(|| {
            order.push(model.to_string());
            let pol = self.cfg.policy_for(model);
            Lane {
                heap: BinaryHeap::new(),
                cfg: pol.cfg,
                weight: pol.weight.max(1),
                credits: pol.weight.max(1),
                sealed: [0; N_SEAL_REASONS],
                shed: [0; N_SHED_REASONS],
                hint_shed_mark: 0,
            }
        })
    }

    /// Per-job service-time estimate for `model` in ns (`svc_ns /
    /// jobs`), 0 until the lane has executed anything. Caller may hold
    /// the `sched` lock — the lock order is always sched → counters.
    fn svc_estimate_ns(&self, model: &str) -> u64 {
        let c = self.counters.lock().unwrap();
        match c.get(model) {
            Some(&(jobs, _, svc_ns)) if jobs > 0 => svc_ns / jobs,
            _ => 0,
        }
    }

    /// Snapshot every lane's per-job service estimate (scheduler-side
    /// batch of [`Shared::svc_estimate_ns`]).
    fn svc_estimates(&self) -> HashMap<String, u64> {
        let c = self.counters.lock().unwrap();
        c.iter()
            .filter(|(_, &(jobs, _, _))| jobs > 0)
            .map(|(m, &(jobs, _, svc_ns))| (m.clone(), svc_ns / jobs))
            .collect()
    }
}

/// Handle to a running executor: the scheduler thread plus the stream
/// worker pool (see the module docs for the three-stage lifecycle).
pub struct Executor {
    shared: Arc<Shared>,
    scheduler: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The artifact menu, kept for shape queries ([`Executor::shape`],
    /// the answer to the wire's `OP_SHAPE`) — the scheduler thread owns
    /// its own copy.
    manifest: Manifest,
    /// Background telemetry sampler feeding the counter-track ring
    /// (joined in [`Executor::shutdown`], or on drop).
    sampler: Option<Sampler>,
}

impl Executor {
    /// Start the scheduler plus `streams` execution workers over the
    /// artifact directory with one global batching policy; each worker
    /// eagerly compiles the artifacts in `warm`.
    pub fn start(
        artifact_dir: impl Into<PathBuf>,
        streams: usize,
        batch: BatchCfg,
        warm: &[&str],
    ) -> Result<Executor> {
        Executor::start_with(artifact_dir, streams, SchedCfg::uniform(batch), warm)
    }

    /// Start with a full [`SchedCfg`] — per-model policy overrides and
    /// a per-lane queue bound on top of the global default. Telemetry
    /// samples at the default period ([`DEFAULT_SAMPLE_MS`]).
    pub fn start_with(
        artifact_dir: impl Into<PathBuf>,
        streams: usize,
        sched: SchedCfg,
        warm: &[&str],
    ) -> Result<Executor> {
        Executor::start_full(artifact_dir, streams, sched, warm, DEFAULT_SAMPLE_MS)
    }

    /// [`Executor::start_with`] plus the telemetry sampler period in
    /// milliseconds (the CLI's `--sample-ms`).
    pub fn start_full(
        artifact_dir: impl Into<PathBuf>,
        streams: usize,
        sched: SchedCfg,
        warm: &[&str],
        sample_ms: u64,
    ) -> Result<Executor> {
        assert!(streams >= 1);
        let dir: PathBuf = artifact_dir.into();
        // The scheduler needs the batch-size menu up front to know how
        // long a gather is worth holding; loading the manifest here
        // also fails fast on an unusable artifact directory.
        let manifest = Manifest::load(&dir)?;
        let telemetry = Arc::new(Registry::new());
        let shared = Arc::new(Shared {
            sched: Mutex::new(Sched {
                lanes: HashMap::new(),
                order: Vec::new(),
                cursor: 0,
                ready: VecDeque::new(),
                idle_workers: 0,
            }),
            sched_cv: Condvar::new(),
            work_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            cfg: sched,
            jobs_run: AtomicU64::new(0),
            batches_run: AtomicU64::new(0),
            interleaves: AtomicU64::new(0),
            counters: Mutex::new(HashMap::new()),
            streams,
            tm: ExecMetrics::new(Arc::clone(&telemetry)),
        });
        let warm: Vec<String> = warm.iter().map(|s| s.to_string()).collect();
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for _ in 0..streams {
            let sh = shared.clone();
            let dir = dir.clone();
            let warm = warm.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                let engine = match Engine::load(&dir).and_then(|e| {
                    let names: Vec<&str> = warm.iter().map(String::as_str).collect();
                    e.warm(&names)?;
                    Ok(e)
                }) {
                    Ok(e) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                worker_loop(sh, engine)
            }));
        }
        drop(ready_tx);
        for _ in 0..streams {
            let up = ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during startup"))
                .and_then(|r| r);
            if let Err(e) = up {
                // A worker failed to load its engine. The siblings that
                // already succeeded are parked in worker_loop — without
                // a stop signal they (and their engines) would leak
                // forever, since no scheduler will ever feed them.
                shared.stop.store(true, Ordering::SeqCst);
                shared.work_cv.notify_all();
                for w in workers {
                    let _ = w.join();
                }
                return Err(e);
            }
        }
        let sh = shared.clone();
        let sched_manifest = manifest.clone();
        let scheduler = std::thread::spawn(move || scheduler_loop(sh, sched_manifest));
        let sampler = Sampler::start(telemetry, sample_ms, DEFAULT_RING_CAP);
        Ok(Executor {
            shared,
            scheduler: Some(scheduler),
            workers,
            manifest,
            sampler: Some(sampler),
        })
    }

    /// Per-request tensor shape of `model`: `(input elems, output
    /// elems)` for one sample, from the model's single-sample (`_b1`)
    /// artifact (falling back to an exact artifact name). This is what
    /// the server answers `OP_SHAPE` with; the routing gateway uses it
    /// to size the inter-stage tensor bridge when chaining pipeline
    /// stages.
    pub fn shape(&self, model: &str) -> Result<(usize, usize)> {
        let entry = self
            .manifest
            .get(&format!("{model}_b1"))
            .or_else(|| self.manifest.get(model))
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let in_elems = entry
            .inputs
            .first()
            .map(TensorSpec::elems)
            .ok_or_else(|| anyhow!("model {model} has no input spec"))?;
        Ok((in_elems, entry.output.elems()))
    }

    /// Submit a job; the reply arrives on the returned channel. A full
    /// lane (more than [`SchedCfg::queue_cap`] queued jobs for this
    /// model) sheds the job immediately on that channel instead of
    /// queueing it. The job gets a fresh trace span starting now; use
    /// [`Executor::submit_traced`] to carry server-side receive stamps
    /// into the executor.
    pub fn submit(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
    ) -> mpsc::Receiver<Result<Done, ExecError>> {
        self.submit_traced(model, raw, prio, payload, SpanRec::begin())
    }

    /// [`Executor::submit`] with a caller-provided trace span (the
    /// server passes the span it began at the transport boundary, so
    /// the timeline covers receive + parse as well).
    pub fn submit_traced(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
        span: SpanRec,
    ) -> mpsc::Receiver<Result<Done, ExecError>> {
        self.submit_deadline(model, raw, prio, payload, None, span)
    }

    /// Full submit: [`Executor::submit_traced`] plus an optional SLO
    /// budget (relative µs from now, the wire `FLAG_DEADLINE` field).
    /// Admission control runs here: the job is shed on its reply
    /// channel — never queued — when the lane is at `queue_cap`
    /// ([`ShedReason::QueueFull`]) or when the deadline is already
    /// unwinnable ([`ShedReason::Deadline`]: estimated queue + service
    /// time from the per-lane counters exceeds the budget). Shedding at
    /// the submit edge is the cheap failure the paper's overload story
    /// wants — the client learns in one RTT instead of a deadline blown
    /// deep in the pipeline.
    pub fn submit_deadline(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
        deadline_us: Option<u64>,
        mut span: SpanRec,
    ) -> mpsc::Receiver<Result<Done, ExecError>> {
        let (tx, rx) = mpsc::channel();
        span.mark(Stamp::Enqueue);
        let now = Instant::now();
        let deadline =
            deadline_us.map(|us| now + Duration::from_micros(us.min(FLUSH_US_MAX)));
        let job = Job {
            model: model.to_string(),
            raw,
            prio,
            payload,
            reply: tx,
            span,
            deadline,
            enqueued: now,
            seq: self.shared.seq.fetch_add(1, Ordering::Relaxed),
        };
        {
            let mut s = self.shared.sched.lock().unwrap();
            // Estimate before the lane borrow; lock order sched → counters.
            let est_ns = self.shared.svc_estimate_ns(model);
            let lane = self.shared.lane(&mut s, model);
            if lane.heap.len() >= self.shared.cfg.queue_cap {
                lane.shed[ShedReason::QueueFull as usize] += 1;
                self.shared.tm.shed[ShedReason::QueueFull as usize].inc();
                let msg = format!(
                    "lane for model {model} is full ({} queued jobs)",
                    lane.heap.len()
                );
                let _ = job
                    .reply
                    .send(Err(ExecError::shed(ShedReason::QueueFull, msg)));
                return rx;
            }
            if let (Some(d), true) = (job.deadline, est_ns > 0) {
                // Queue delay: the jobs ahead drain `streams`-wide, then
                // this job itself must still run.
                let ahead = lane.heap.len() as u64;
                let streams = self.shared.streams.max(1) as u64;
                let wait_ns = admission_wait_ns(est_ns, ahead, streams);
                if now + Duration::from_nanos(wait_ns) > d {
                    lane.shed[ShedReason::Deadline as usize] += 1;
                    self.shared.tm.shed[ShedReason::Deadline as usize].inc();
                    let msg = format!(
                        "deadline unwinnable for model {model}: budget {}us < estimated {}us \
                         ({} queued ahead)",
                        deadline_us.unwrap_or(0),
                        wait_ns / 1_000,
                        ahead
                    );
                    let _ = job
                        .reply
                        .send(Err(ExecError::shed(ShedReason::Deadline, msg)));
                    return rx;
                }
            }
            lane.heap.push(Queued(job));
            self.shared.tm.depth.add(1);
        }
        self.shared.sched_cv.notify_one();
        rx
    }

    /// Convenience: submit and wait.
    pub fn infer_sync(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
    ) -> Result<Done, ExecError> {
        self.submit(model, raw, prio, payload)
            .recv()
            .map_err(|_| ExecError::Failed(anyhow!("executor dropped the job")))?
    }

    /// Submit with a caller-provided trace span and wait.
    pub fn infer_traced(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
        span: SpanRec,
    ) -> Result<Done, ExecError> {
        self.infer_deadline(model, raw, prio, payload, None, span)
    }

    /// Submit with a trace span and an SLO budget, and wait.
    pub fn infer_deadline(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
        deadline_us: Option<u64>,
        span: SpanRec,
    ) -> Result<Done, ExecError> {
        self.submit_deadline(model, raw, prio, payload, deadline_us, span)
            .recv()
            .map_err(|_| ExecError::Failed(anyhow!("executor dropped the job")))?
    }

    /// Jobs queued across all lanes, not yet sealed into a batch.
    pub fn queue_len(&self) -> usize {
        let s = self.shared.sched.lock().unwrap();
        s.lanes.values().map(|l| l.heap.len()).sum()
    }

    /// Lifetime execution counters `(jobs, executable_calls)` summed
    /// over every model: the mean achieved batch size is
    /// `jobs / executable_calls`. Observability for `batchsweep`.
    pub fn batch_counters(&self) -> (u64, u64) {
        (
            self.shared.jobs_run.load(Ordering::Relaxed),
            self.shared.batches_run.load(Ordering::Relaxed),
        )
    }

    /// Per-model `(model, jobs, executable_calls)` counters, sorted by
    /// model name. Observability for `mixsweep`'s per-model avg-batch
    /// column.
    pub fn model_batch_counters(&self) -> Vec<(String, u64, u64)> {
        let c = self.shared.counters.lock().unwrap();
        let mut v: Vec<(String, u64, u64)> = c
            .iter()
            .map(|(m, &(jobs, calls, _))| (m.clone(), jobs, calls))
            .collect();
        v.sort();
        v
    }

    /// How many dispatches switched model relative to the previous
    /// dispatch — nonzero means two models were genuinely served
    /// concurrently from the shared stream pool rather than run as two
    /// serialized phases.
    pub fn interleave_count(&self) -> u64 {
        self.shared.interleaves.load(Ordering::Relaxed)
    }

    /// Snapshot every per-lane counter (jobs, executable calls, queue
    /// depth, sealed-batch reasons) plus the interleave count — what
    /// the stats opcode serves over the wire. Lanes are sorted by model
    /// name; the per-model job/call counters are consistent with
    /// [`Executor::model_batch_counters`] by construction (same map).
    pub fn stats(&self) -> ExecStats {
        let s = self.shared.sched.lock().unwrap();
        let counters = self.shared.counters.lock().unwrap();
        let mut lanes: Vec<LaneStats> = s
            .lanes
            .iter()
            .map(|(model, lane)| {
                let (jobs, calls, svc_ns) = counters.get(model).copied().unwrap_or((0, 0, 0));
                LaneStats {
                    model: model.clone(),
                    jobs,
                    calls,
                    svc_ns,
                    depth: lane.heap.len() as u32,
                    sealed: lane.sealed,
                    shed: lane.shed,
                }
            })
            .collect();
        lanes.sort_by(|a, b| a.model.cmp(&b.model));
        ExecStats {
            interleaves: self.shared.interleaves.load(Ordering::Relaxed),
            lanes,
        }
    }

    /// Compute the proactive-backpressure hint for `model`'s lane (the
    /// payload of the protocol's status-5 credit envelope, attached by
    /// the server to every response of a `FLAG_CREDITS` request).
    ///
    /// The hint is priced from the same signals admission control uses:
    /// * **credits** — queue headroom, capped at twice the stream count
    ///   (a deeper in-flight window only grows the queue);
    /// * **pace** — zero while the streams are hungry (`depth <
    ///   streams`), else `est × depth / streams`: sending faster than
    ///   the backlog drains is pure queueing;
    /// * **shed pressure** — if the lane shed since the last hint, the
    ///   hint collapses to zero credits and a pace well below the
    ///   service rate, so the backlog actually drains before the client
    ///   resumes. The shed delta is consumed by whichever connection's
    ///   response is encoded next — hints are advisory and per-response,
    ///   not a distributed reservation.
    ///
    /// Locking: takes `sched`, then `counters` (via the service
    /// estimate) — the executor-wide lock order.
    pub fn credit_hint(&self, model: &str) -> CreditHint {
        let mut s = self.shared.sched.lock().unwrap();
        // Estimate before the lane borrow; lock order sched → counters.
        let est_ns = self.shared.svc_estimate_ns(model);
        let streams = self.shared.streams.max(1) as u64;
        let queue_cap = self.shared.cfg.queue_cap as u64;
        let lane = self.shared.lane(&mut s, model);
        let depth = lane.heap.len() as u64;
        let shed_total: u64 = lane.shed.iter().sum();
        let shed_delta = shed_total - lane.hint_shed_mark;
        lane.hint_shed_mark = shed_total;
        if shed_delta > 0 {
            self.shared.tm.credit_grants.inc();
            return CreditHint {
                credits: 0,
                pace_ns: 2 * est_ns.max(MIN_BACKOFF_PACE_NS),
            };
        }
        let headroom = queue_cap.saturating_sub(depth);
        let credits = headroom.min(2 * streams).min(u16::MAX as u64) as u16;
        let pace_ns = if depth < streams {
            0
        } else {
            est_ns.saturating_mul(depth) / streams
        };
        self.shared.tm.credit_grants.inc();
        self.shared.tm.credit_tokens.add(credits as u64);
        CreditHint { credits, pace_ns }
    }

    /// Shared handle to the always-on telemetry registry — counters,
    /// gauges and mergeable histograms stamped on the live execution
    /// path. Experiments read it directly; the wire serves it through
    /// [`Executor::metrics_report`].
    pub fn telemetry(&self) -> Arc<Registry> {
        Arc::clone(&self.shared.tm.reg)
    }

    /// The sampler's ring of timestamped counter deltas (oldest first),
    /// feeding `"ph":"C"` counter tracks in timeline exports. Empty if
    /// the sampler has not ticked yet.
    pub fn sample_ring(&self) -> Vec<Sample> {
        self.sampler.as_ref().map(|s| s.ring()).unwrap_or_default()
    }

    /// What the metrics opcode serves over the wire: the registry
    /// snapshot plus the sampler ring.
    pub fn metrics_report(&self) -> MetricsReport {
        MetricsReport {
            snap: self.shared.tm.reg.snapshot(),
            ring: self.sample_ring(),
        }
    }

    /// Stop the scheduler and workers and join them. Sealed batches
    /// already handed to workers finish; jobs still queued in lanes are
    /// dropped and their reply channels report the executor as gone.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.sched_cv.notify_all();
        self.shared.work_cv.notify_all();
        if let Some(b) = self.scheduler.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(mut sm) = self.sampler.take() {
            sm.stop();
        }
    }
}

/// Floor for the post-shed back-off pace in ns, used by
/// [`Executor::credit_hint`]: keeps the back-off meaningful when a lane
/// sheds before any service-time history exists (queue-full on a cold
/// lane).
const MIN_BACKOFF_PACE_NS: u64 = 100_000;

/// Admission-control wait estimate in ns: the `ahead` queued jobs drain
/// `streams`-wide in *ceil(ahead / streams)* service-time waves — a
/// partial last wave still costs a full service time — and then the job
/// itself must run (+1). Flooring here (the pre-fix behaviour) admitted
/// requests whose deadlines were already unwinnable: 3 ahead on 2
/// streams was priced at 2 service times instead of 3.
fn admission_wait_ns(est_ns: u64, ahead: u64, streams: u64) -> u64 {
    let streams = streams.max(1);
    est_ns.saturating_mul(ahead.div_ceil(streams) + 1)
}

/// How many jobs a batch headed by `model` is worth gathering: capped
/// by policy, and 1 when the manifest has no batched executable to
/// exploit (holding jobs would add latency for nothing).
fn gather_cap(manifest: &Manifest, model: &str, raw: bool, cfg: BatchCfg) -> usize {
    if raw || cfg.max_batch <= 1 {
        return 1;
    }
    let has_batched = manifest
        .batch_sizes(model)
        .into_iter()
        .any(|b| b > 1 && b <= cfg.max_batch);
    if has_batched {
        cfg.max_batch
    } else {
        1
    }
}

/// Upper bound on the flush deadline (10 minutes, in µs): keeps an
/// absurd `flush_us` from overflowing the `Instant` arithmetic below
/// while staying far above any sane serving policy.
const FLUSH_US_MAX: u64 = 600_000_000;

fn flush_deadline(head: &Job, cfg: BatchCfg) -> Instant {
    head.enqueued + Duration::from_micros(cfg.flush_us.min(FLUSH_US_MAX))
}

/// The continuous scheduler: seal sealable lanes onto idle workers —
/// earliest-deadline-first over lanes holding SLO work, then weighted
/// round-robin over the rest; when every remaining lane is holding a
/// gather for peers, sleep until the earliest flush or SLO deadline
/// (or until a submission / worker-idle notification).
fn scheduler_loop(sh: Arc<Shared>, manifest: Manifest) {
    let mut last_model: Option<String> = None;
    let mut s = sh.sched.lock().unwrap();
    loop {
        if sh.stop.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        // Per-job service estimates for the SLO seal decisions this
        // round (lock order sched → counters, same as `stats`).
        let est = sh.svc_estimates();
        // Dispatch until workers run out or nothing is sealable.
        while s.ready.len() < s.idle_workers {
            let Some(batch) = pick_and_seal(&mut s, &manifest, now, &est, &sh.tm) else {
                break;
            };
            if let Some(prev) = &last_model {
                if *prev != batch[0].model {
                    sh.interleaves.fetch_add(1, Ordering::Relaxed);
                    sh.tm.interleaves.inc();
                }
            }
            last_model = Some(batch[0].model.clone());
            s.ready.push_back(batch);
            sh.work_cv.notify_one();
        }
        // With spare workers, every nonempty lane is holding for peers
        // (anything sealable was sealed above): sleep to the earliest
        // flush/SLO deadline. With no spare worker, sleep until one frees.
        let wait = if s.ready.len() < s.idle_workers {
            earliest_deadline(&s, now, &est)
        } else {
            None
        };
        s = match wait {
            Some(d) => sh.sched_cv.wait_timeout(s, d).unwrap().0,
            None => sh.sched_cv.wait(s).unwrap(),
        };
    }
}

/// Earliest wake-up over all nonempty lanes — the head's flush deadline
/// or, for lanes holding SLO work, the earliest job deadline minus the
/// lane's estimated service time (the last moment an SLO seal can still
/// win) — as a wait duration from `now` (floored at 100µs so a
/// just-expired deadline cannot spin the scheduler).
fn earliest_deadline(s: &Sched, now: Instant, est: &HashMap<String, u64>) -> Option<Duration> {
    s.lanes
        .iter()
        .filter_map(|(name, lane)| {
            let head = lane.heap.peek()?;
            let mut t = flush_deadline(&head.0, lane.cfg);
            if let Some(d) = lane.min_deadline() {
                let svc = Duration::from_nanos(est.get(name).copied().unwrap_or(0));
                t = t.min(d.checked_sub(svc).unwrap_or(now));
            }
            Some(t)
        })
        .min()
        .map(|d| {
            d.saturating_duration_since(now)
                .max(Duration::from_micros(100))
        })
}

/// Pick the next batch to seal. Lanes holding SLO work are tried first,
/// **earliest deadline first** — a tight-deadline lane preempts the
/// round-robin cursor and does not need credits, so deadline traffic is
/// never starved behind a heavier deadline-free lane. Deadline-free
/// lanes then go through the weighted round-robin: starting at the
/// cursor, seal the first sealable lane that still has round-robin
/// credits; if no sealable lane has credits left, refill every lane to
/// its weight and retry once. A lane keeps the cursor until its credits
/// run out, so a weight-2 lane dispatches two batches per cycle.
fn pick_and_seal(
    s: &mut Sched,
    manifest: &Manifest,
    now: Instant,
    est: &HashMap<String, u64>,
    tm: &ExecMetrics,
) -> Option<Vec<Job>> {
    let n = s.order.len();
    if n == 0 {
        return None;
    }
    // EDF pass over lanes with queued SLO work.
    let mut slo_lanes: Vec<(Instant, String)> = s
        .lanes
        .iter()
        .filter_map(|(name, lane)| lane.min_deadline().map(|d| (d, name.clone())))
        .collect();
    slo_lanes.sort_by_key(|(d, _)| *d);
    for (_, name) in slo_lanes {
        let est_ns = est.get(&name).copied().unwrap_or(0);
        let lane = s.lanes.get_mut(&name).unwrap();
        if let Some(batch) = try_seal(lane, manifest, now, est_ns, tm) {
            lane.credits = lane.credits.saturating_sub(1);
            return Some(batch);
        }
    }
    // WRR pass over everything else.
    for pass in 0..2 {
        for k in 0..n {
            let i = (s.cursor + k) % n;
            let name = &s.order[i];
            let est_ns = est.get(name).copied().unwrap_or(0);
            let lane = s.lanes.get_mut(name).unwrap();
            if pass == 0 && lane.credits == 0 {
                continue;
            }
            if let Some(batch) = try_seal(lane, manifest, now, est_ns, tm) {
                lane.credits = lane.credits.saturating_sub(1);
                s.cursor = if lane.credits == 0 { (i + 1) % n } else { i };
                return Some(batch);
            }
        }
        if pass == 0 {
            for l in s.lanes.values_mut() {
                l.credits = l.weight.max(1);
            }
        }
    }
    None
}

/// Try to seal the lane's head group. The group is the run of
/// compatible jobs at the head's priority (same payload length, `F32`,
/// non-raw — the only thing the batched executables concatenate, so a
/// malformed request runs, and fails, alone). It seals when it fills
/// the policy cap, under an opportunistic (`flush_us == 0`) policy,
/// at the head's flush deadline, when waiting any longer would blow
/// the group's earliest SLO deadline (`est_ns` is the lane's per-job
/// service estimate — the batch needs `est_ns × len` more ns to land),
/// or early when other work waits in this lane (the caller only
/// attempts a seal while a stream is idle — holding a flush window
/// while blocking queued work on an idle stream would buy latency for
/// nothing). Otherwise every popped job goes back on the heap —
/// nothing is held outside the lane, which is what lets a later
/// higher-priority arrival become the new head and overtake the
/// gather.
fn try_seal(
    lane: &mut Lane,
    manifest: &Manifest,
    now: Instant,
    est_ns: u64,
    tm: &ExecMetrics,
) -> Option<Vec<Job>> {
    let head_prio = lane.heap.peek()?.0.prio;
    let mut head = lane.heap.pop().unwrap().0;
    // First consideration for a gather: the trace boundary between
    // lane-queue and gather-wait (first write wins, so an aborted
    // gather that re-forms later keeps the original stamp).
    head.span.mark(Stamp::GatherStart);
    let batchable = !head.raw && matches!(head.payload, TensorBuf::F32(_));
    let cap = if batchable {
        gather_cap(manifest, &head.model, false, lane.cfg)
    } else {
        1
    };
    if cap <= 1 {
        head.span.mark(Stamp::Seal);
        lane.sealed[SealReason::Single as usize] += 1;
        tm.sealed[SealReason::Single as usize].inc();
        tm.depth.sub(1);
        return Some(vec![head]);
    }
    let mut group = vec![head];
    let mut spill: Vec<Queued> = Vec::new();
    // The heap pops in priority order, so once the priority drops below
    // the head's there are no more compatible jobs to find.
    while group.len() < cap {
        match lane.heap.peek() {
            Some(q) if q.0.prio == head_prio => {
                let mut j = lane.heap.pop().unwrap().0;
                j.span.mark(Stamp::GatherStart);
                if !j.raw
                    && j.payload.len() == group[0].payload.len()
                    && matches!(j.payload, TensorBuf::F32(_))
                {
                    group.push(j);
                } else {
                    spill.push(Queued(j));
                }
            }
            _ => break,
        }
    }
    let blocked_work = !spill.is_empty() || !lane.heap.is_empty();
    // Earliest SLO deadline in the gathered group: waiting past
    // `slo_latest` (deadline minus the time the batch itself needs to
    // execute) guarantees a blown deadline, so seal there.
    let slo_latest = group
        .iter()
        .filter_map(|j| j.deadline)
        .min()
        .map(|d| {
            let run = Duration::from_nanos(est_ns.saturating_mul(group.len() as u64));
            d.checked_sub(run).unwrap_or(now)
        });
    let reason = if group.len() >= cap {
        Some(SealReason::Full)
    } else if lane.cfg.flush_us == 0 {
        Some(SealReason::Opportunistic)
    } else if now >= flush_deadline(&group[0], lane.cfg) {
        Some(SealReason::Deadline)
    } else if slo_latest.is_some_and(|t| now >= t) {
        Some(SealReason::Slo)
    } else if blocked_work {
        Some(SealReason::Blocked)
    } else {
        None
    };
    for q in spill {
        lane.heap.push(q);
    }
    match reason {
        Some(r) => {
            lane.sealed[r as usize] += 1;
            tm.sealed[r as usize].inc();
            tm.depth.sub(group.len() as u64);
            let t_seal = Instant::now();
            for j in &mut group {
                j.span.mark_at(Stamp::Seal, t_seal);
            }
            Some(group)
        }
        None => {
            for j in group {
                lane.heap.push(Queued(j));
            }
            None
        }
    }
}

/// The execution stage: park until the scheduler hands over a sealed
/// batch, run it, repeat. The `idle_workers` count is what lets the
/// scheduler seal exactly as many batches as there are streams to run
/// them on.
fn worker_loop(sh: Arc<Shared>, engine: Engine) {
    loop {
        let batch = {
            let mut s = sh.sched.lock().unwrap();
            s.idle_workers += 1;
            // A stream just became available: lanes holding jobs may
            // now be worth sealing.
            sh.sched_cv.notify_one();
            let b = loop {
                if let Some(b) = s.ready.pop_front() {
                    break Some(b);
                }
                if sh.stop.load(Ordering::SeqCst) {
                    break None;
                }
                s = sh.work_cv.wait(s).unwrap();
            };
            s.idle_workers -= 1;
            b
        };
        match batch {
            Some(b) => run_jobs(&engine, b, &sh),
            None => return, // shutdown: lanes drained or abandoned
        }
    }
}

/// Largest manifest-provided batch executable size <= `n` for `model`
/// (1 when the model has no batched variants).
fn artifact_chunk(manifest: &Manifest, model: &str, n: usize) -> usize {
    if n <= 1 {
        return 1;
    }
    manifest
        .batch_sizes(model)
        .into_iter()
        .filter(|&b| b <= n)
        .max()
        .unwrap_or(1)
}

/// Split a sealed batch greedily onto the largest available batch
/// executables (a 7-job batch runs as `_b4` + `_b2` + `_b1`).
fn run_jobs(engine: &Engine, mut jobs: Vec<Job>, sh: &Shared) {
    let model = jobs[0].model.clone();
    let exec_h = sh.tm.exec_histo(&model);
    while !jobs.is_empty() {
        let b = if jobs[0].raw {
            1
        } else {
            artifact_chunk(engine.manifest(), &jobs[0].model, jobs.len())
        };
        let chunk: Vec<Job> = jobs.drain(..b).collect();
        let chunk_len = chunk.len() as u64;
        sh.jobs_run.fetch_add(chunk_len, Ordering::Relaxed);
        sh.batches_run.fetch_add(1, Ordering::Relaxed);
        sh.tm.jobs.add(chunk_len);
        sh.tm.batches.inc();
        sh.tm.batch_size.observe(chunk_len);
        {
            let mut c = sh.counters.lock().unwrap();
            let e = c.entry(model.clone()).or_insert((0, 0, 0));
            e.0 += chunk_len;
            e.1 += 1;
        }
        let t0 = Instant::now();
        run_chunk(engine, chunk, &sh.tm, &exec_h);
        // Stream time accrues after the chunk so the estimate reflects
        // completed work; the job/call counters above stay visible the
        // moment a reply lands (tests rely on that ordering).
        let svc_ns = t0.elapsed().as_nanos() as u64;
        sh.tm.svc_ns.observe(svc_ns);
        {
            let mut c = sh.counters.lock().unwrap();
            let e = c.entry(model.clone()).or_insert((0, 0, 0));
            e.2 += svc_ns;
        }
    }
}

fn run_chunk(engine: &Engine, mut jobs: Vec<Job>, tm: &ExecMetrics, exec_h: &HistoHandle) {
    // Chunk execution starts now: the trace boundary between
    // dispatch-wait (rendezvous + earlier chunks of the same sealed
    // batch) and the engine stages.
    let t_deq = Instant::now();
    let queue_ns: Vec<u64> = jobs
        .iter_mut()
        .map(|j| {
            j.span.mark_at(Stamp::Dispatch, t_deq);
            t_deq.duration_since(j.enqueued).as_nanos() as u64
        })
        .collect();

    if jobs.len() == 1 && jobs[0].raw {
        // Two-stage raw pipeline: preprocess artifact, then batch-1 model
        // (separately timed, like the paper's preprocessing stage).
        let Job {
            model,
            payload,
            reply,
            mut span,
            ..
        } = jobs.pop().expect("one raw job");
        let t0 = Instant::now();
        let pre = match &payload {
            // U8Region is the GDR zero-copy case: the preprocess
            // artifact reads straight out of the registered region.
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => {
                engine.infer_timed("preprocess", &payload)
            }
            TensorBuf::F32(_) => Err(anyhow!("raw job with non-u8 payload")),
        };
        match pre {
            Err(e) => {
                let _ = reply.send(Err(ExecError::Failed(e)));
            }
            Ok((pre, tm_pre)) => {
                // Staging the raw frame onto the device is the
                // preprocess call's literal build.
                span.mark_after(Stamp::H2dDone, t0, tm_pre.h2d_ns);
                let t1 = Instant::now();
                span.mark_at(Stamp::PreprocDone, t1);
                let name = format!("{model}_b1");
                let out = engine.infer_timed(&name, &TensorBuf::F32(pre));
                let t2 = Instant::now();
                let done = out.map_err(ExecError::Failed).map(|(output, tm)| {
                    span.mark_after(Stamp::InferDone, t1, tm.h2d_ns + tm.compute_ns);
                    span.mark_at(Stamp::D2hDone, t2);
                    Done {
                        output,
                        stages: StageNs {
                            queue_ns: queue_ns[0],
                            preproc_ns: (t1 - t0).as_nanos() as u64,
                            infer_ns: (t2 - t1).as_nanos() as u64,
                        },
                        batch: 1,
                        span,
                    }
                });
                if let Ok(d) = &done {
                    tm.observe_span(exec_h, &d.span);
                }
                let _ = reply.send(done);
            }
        }
        return;
    }

    // Preprocessed path, possibly batched: gather the rows, one
    // executable call, scatter the output rows back per request.
    let b = jobs.len();
    let name = format!("{}_b{}", jobs[0].model, b);
    let mut flat: Vec<f32> = Vec::new();
    for j in &jobs {
        match &j.payload {
            TensorBuf::F32(v) => flat.extend_from_slice(v),
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => {
                // The seal only fuses F32 payloads, so a chunk containing
                // a u8 payload is that single malformed job — but
                // answer every reply channel regardless: dropping a
                // fused peer's sender would fail an innocent request.
                for peer in &jobs {
                    let _ = peer
                        .reply
                        .send(Err(ExecError::Failed(anyhow!("u8 payload without raw flag"))));
                }
                return;
            }
        }
    }
    let t1 = Instant::now();
    let res = engine.infer_timed(&name, &TensorBuf::F32(flat));
    let infer_ns = t1.elapsed().as_nanos() as u64;
    match res {
        Err(e) => {
            let msg = format!("{e}");
            for j in &jobs {
                let _ = j.reply.send(Err(ExecError::Failed(anyhow!("{msg}"))));
            }
        }
        Ok((out, tm)) => {
            // Row gather (dispatch -> t1) plus the literal build is the
            // chunk's H2D stage; the fetch-and-scatter end is D2H.
            let t_h2d = t1 + Duration::from_nanos(tm.h2d_ns);
            let t_infer = t_h2d + Duration::from_nanos(tm.compute_ns);
            let t_d2h = Instant::now();
            let per = out.len() / b;
            for (i, j) in jobs.into_iter().enumerate() {
                let Job {
                    reply, mut span, ..
                } = j;
                span.mark_at(Stamp::H2dDone, t_h2d);
                span.mark_at(Stamp::InferDone, t_infer);
                span.mark_at(Stamp::D2hDone, t_d2h);
                tm.observe_span(exec_h, &span);
                let _ = reply.send(Ok(Done {
                    output: out[i * per..(i + 1) * per].to_vec(),
                    stages: StageNs {
                        queue_ns: queue_ns[i],
                        preproc_ns: 0,
                        infer_ns,
                    },
                    batch: b,
                    span,
                }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A manifest with b1/b2/b4/b8 classifier variants plus an
    /// unbatched model, for exercising the size menu without artifacts.
    fn menu() -> Manifest {
        let mut artifacts = String::new();
        for b in [1usize, 2, 4, 8] {
            artifacts.push_str(&format!(
                r#"{{"name": "m_b{b}", "model": "m", "task": "c", "file": "m_b{b}.hlo.txt",
                    "inputs": [{{"shape": [{b}, 4], "dtype": "f32"}}],
                    "output": {{"shape": [{b}, 2], "dtype": "f32"}}}},"#
            ));
        }
        artifacts.push_str(
            r#"{"name": "solo_b1", "model": "solo", "task": "c", "file": "s.hlo.txt",
                "inputs": [{"shape": [1, 4], "dtype": "f32"}],
                "output": {"shape": [1, 2], "dtype": "f32"}}"#,
        );
        Manifest::parse(
            &format!(r#"{{"format": 1, "artifacts": [{artifacts}]}}"#),
            std::path::PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    /// A standalone telemetry sink for tests that call `try_seal` /
    /// `pick_and_seal` outside a running executor.
    fn test_tm() -> ExecMetrics {
        ExecMetrics::new(Arc::new(Registry::new()))
    }

    #[test]
    fn artifact_chunk_picks_largest_available_leq() {
        let m = menu();
        assert_eq!(artifact_chunk(&m, "m", 1), 1);
        assert_eq!(artifact_chunk(&m, "m", 3), 2);
        assert_eq!(artifact_chunk(&m, "m", 5), 4);
        assert_eq!(artifact_chunk(&m, "m", 8), 8);
        assert_eq!(artifact_chunk(&m, "m", 100), 8);
        // No batched variants: always 1.
        assert_eq!(artifact_chunk(&m, "solo", 8), 1);
        assert_eq!(artifact_chunk(&m, "unknown", 8), 1);
    }

    #[test]
    fn gather_cap_respects_policy_and_menu() {
        let m = menu();
        assert_eq!(gather_cap(&m, "m", false, BatchCfg::none()), 1);
        assert_eq!(gather_cap(&m, "m", false, BatchCfg::opportunistic(8)), 8);
        // Odd caps are allowed — the chunker splits them (6 = 4 + 2).
        assert_eq!(gather_cap(&m, "m", false, BatchCfg::deadline(6, 100)), 6);
        // Raw jobs and menu-less models never wait for peers.
        assert_eq!(gather_cap(&m, "m", true, BatchCfg::opportunistic(8)), 1);
        assert_eq!(gather_cap(&m, "solo", false, BatchCfg::opportunistic(8)), 1);
    }

    #[test]
    fn batch_cfg_parse_and_label_roundtrip() {
        assert_eq!(BatchCfg::parse("1"), Some(BatchCfg::none()));
        assert_eq!(BatchCfg::parse("8"), Some(BatchCfg::opportunistic(8)));
        assert_eq!(BatchCfg::parse("8@2000"), Some(BatchCfg::deadline(8, 2000)));
        assert_eq!(BatchCfg::parse("b4@500us"), Some(BatchCfg::deadline(4, 500)));
        assert_eq!(BatchCfg::parse("0"), None);
        assert_eq!(BatchCfg::parse("x"), None);
        assert_eq!(BatchCfg::none().label(), "b1");
        assert_eq!(BatchCfg::opportunistic(8).label(), "b8");
        assert_eq!(BatchCfg::deadline(8, 2000).label(), "b8@2000us");
        for s in ["1", "8", "8@2000"] {
            let c = BatchCfg::parse(s).unwrap();
            assert_eq!(BatchCfg::parse(&c.label()), Some(c), "label {s}");
        }
    }

    #[test]
    fn model_policy_parse_and_label() {
        assert_eq!(
            ModelPolicy::parse_spec("8@2000"),
            Some(ModelPolicy::new(BatchCfg::deadline(8, 2000)))
        );
        assert_eq!(
            ModelPolicy::parse_spec("4*2"),
            Some(ModelPolicy::weighted(BatchCfg::opportunistic(4), 2))
        );
        assert_eq!(
            ModelPolicy::parse_spec("8@500us*3"),
            Some(ModelPolicy::weighted(BatchCfg::deadline(8, 500), 3))
        );
        assert_eq!(ModelPolicy::parse_spec("8*0"), None);
        assert_eq!(ModelPolicy::parse_spec(""), None);
        assert_eq!(
            ModelPolicy::parse_entry("tiny_resnet=8@2000"),
            Some((
                "tiny_resnet".to_string(),
                ModelPolicy::new(BatchCfg::deadline(8, 2000))
            ))
        );
        assert_eq!(ModelPolicy::parse_entry("=8"), None);
        assert_eq!(ModelPolicy::parse_entry("tiny_resnet"), None);
        assert_eq!(
            ModelPolicy::weighted(BatchCfg::deadline(8, 2000), 2).label(),
            "b8@2000us*2"
        );
        assert_eq!(ModelPolicy::new(BatchCfg::none()).label(), "b1");
    }

    #[test]
    fn sched_cfg_resolves_overrides() {
        let cfg = SchedCfg::uniform(BatchCfg::opportunistic(8)).with_model(
            "tiny_resnet",
            ModelPolicy::weighted(BatchCfg::deadline(4, 500), 2),
        );
        assert_eq!(
            cfg.policy_for("tiny_resnet"),
            ModelPolicy::weighted(BatchCfg::deadline(4, 500), 2)
        );
        assert_eq!(
            cfg.policy_for("tiny_mobilenet"),
            ModelPolicy::new(BatchCfg::opportunistic(8))
        );
    }

    #[test]
    fn priority_queue_orders_jobs() {
        let (tx, _rx) = mpsc::channel();
        let mk = |prio: u8, seq: u64| {
            Queued(Job {
                model: "m".into(),
                raw: false,
                prio,
                payload: TensorBuf::F32(vec![]),
                reply: tx.clone(),
                span: SpanRec::begin(),
                deadline: None,
                enqueued: Instant::now(),
                seq,
            })
        };
        let mut h = BinaryHeap::new();
        h.push(mk(0, 0));
        h.push(mk(5, 1));
        h.push(mk(0, 2));
        h.push(mk(5, 3));
        let order: Vec<(u8, u64)> = std::iter::from_fn(|| h.pop())
            .map(|q| (q.0.prio, q.0.seq))
            .collect();
        assert_eq!(order, vec![(5, 1), (5, 3), (0, 0), (0, 2)]);
    }

    /// Seal reasons and span stamps without an engine: drive `try_seal`
    /// directly and watch the lane counters plus the per-job stamps.
    #[test]
    fn try_seal_counts_reasons_and_stamps_spans() {
        let manifest = menu();
        let (tx, _rx) = mpsc::channel();
        let mut seq = 0u64;
        let mut mk = |enq: Instant| {
            seq += 1;
            Queued(Job {
                model: "m".to_string(),
                raw: false,
                prio: 0,
                payload: TensorBuf::F32(vec![0.0; 4]),
                reply: tx.clone(),
                span: SpanRec::begin_at(enq),
                deadline: None,
                enqueued: enq,
                seq,
            })
        };
        let mut lane = Lane {
            heap: BinaryHeap::new(),
            cfg: BatchCfg::deadline(4, 1_000_000), // 1s: never expires here
            weight: 1,
            credits: 1,
            sealed: [0; N_SEAL_REASONS],
            shed: [0; N_SHED_REASONS],
            hint_shed_mark: 0,
        };
        let now = Instant::now();
        // A lone job far from its deadline holds for peers: no seal,
        // and the job goes back without a Seal stamp.
        lane.heap.push(mk(now));
        assert!(try_seal(&mut lane, &manifest, now, 0, &test_tm()).is_none());
        assert_eq!(lane.heap.len(), 1);
        assert!(!lane.heap.peek().unwrap().0.span.is_set(Stamp::Seal));
        assert!(
            lane.heap.peek().unwrap().0.span.is_set(Stamp::GatherStart),
            "considered once: gather stamp taken"
        );
        // Filling to the cap seals Full and stamps every member.
        for _ in 0..3 {
            lane.heap.push(mk(now));
        }
        let batch = try_seal(&mut lane, &manifest, now, 0, &test_tm()).expect("full group seals");
        assert_eq!(batch.len(), 4);
        assert_eq!(lane.sealed[SealReason::Full as usize], 1);
        for j in &batch {
            let gather = j.span.get(Stamp::GatherStart).unwrap();
            let seal = j.span.get(Stamp::Seal).unwrap();
            assert!(gather <= seal, "gather {gather} > seal {seal}");
        }
        // An expired deadline seals a partial group as Deadline.
        lane.cfg = BatchCfg::deadline(4, 1); // 1µs flush
        lane.heap.push(mk(now));
        std::thread::sleep(Duration::from_millis(2));
        assert!(try_seal(&mut lane, &manifest, Instant::now(), 0, &test_tm()).is_some());
        assert_eq!(lane.sealed[SealReason::Deadline as usize], 1);
        // An unbatchable policy seals Single.
        lane.cfg = BatchCfg::none();
        lane.heap.push(mk(now));
        assert!(try_seal(&mut lane, &manifest, now, 0, &test_tm()).is_some());
        assert_eq!(lane.sealed[SealReason::Single as usize], 1);
        // Opportunistic policy seals whatever is queued.
        lane.cfg = BatchCfg::opportunistic(4);
        lane.heap.push(mk(now));
        lane.heap.push(mk(now));
        assert_eq!(
            try_seal(&mut lane, &manifest, now, 0, &test_tm()).expect("seals").len(),
            2
        );
        assert_eq!(lane.sealed[SealReason::Opportunistic as usize], 1);
    }

    /// WRR fairness without an engine: drive `pick_and_seal` directly
    /// over two saturated lanes and check the dispatch pattern.
    #[test]
    fn weighted_round_robin_alternates_lanes() {
        let manifest = menu();
        let (tx, _rx) = mpsc::channel();
        let mut s = Sched {
            lanes: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            ready: VecDeque::new(),
            idle_workers: 0,
        };
        let mut seq = 0u64;
        for (model, n) in [("m", 8usize), ("solo", 4)] {
            s.order.push(model.to_string());
            let mut heap = BinaryHeap::new();
            for _ in 0..n {
                heap.push(Queued(Job {
                    model: model.to_string(),
                    raw: false,
                    prio: 0,
                    payload: TensorBuf::F32(vec![0.0; 4]),
                    reply: tx.clone(),
                    span: SpanRec::begin(),
                    deadline: None,
                    enqueued: Instant::now(),
                    seq,
                }));
                seq += 1;
            }
            s.lanes.insert(
                model.to_string(),
                Lane {
                    heap,
                    cfg: BatchCfg::opportunistic(2),
                    weight: 1,
                    credits: 1,
                    sealed: [0; N_SEAL_REASONS],
                    shed: [0; N_SHED_REASONS],
                    hint_shed_mark: 0,
                },
            );
        }
        let now = Instant::now();
        let mut dispatch = Vec::new();
        while let Some(batch) = pick_and_seal(&mut s, &manifest, now, &HashMap::new(), &test_tm()) {
            dispatch.push(batch[0].model.clone());
        }
        // "m" seals pairs (cap 2), "solo" has no batched variants and
        // seals singles; round-robin must alternate them, not drain one.
        assert_eq!(
            dispatch,
            vec!["m", "solo", "m", "solo", "m", "solo", "m", "solo"],
            "round-robin must interleave the lanes"
        );
    }

    /// A weight-2 lane gets two dispatches per cycle; weight-1 gets one.
    #[test]
    fn wrr_weight_biases_dispatch_share() {
        let manifest = menu();
        let (tx, _rx) = mpsc::channel();
        let mut s = Sched {
            lanes: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            ready: VecDeque::new(),
            idle_workers: 0,
        };
        for (model, weight, n) in [("m", 2u32, 6usize), ("solo", 1, 3)] {
            s.order.push(model.to_string());
            let mut heap = BinaryHeap::new();
            for i in 0..n {
                heap.push(Queued(Job {
                    model: model.to_string(),
                    raw: false,
                    prio: 0,
                    payload: TensorBuf::F32(vec![0.0; 4]),
                    reply: tx.clone(),
                    span: SpanRec::begin(),
                    deadline: None,
                    enqueued: Instant::now(),
                    seq: i as u64,
                }));
            }
            s.lanes.insert(
                model.to_string(),
                Lane {
                    heap,
                    cfg: BatchCfg::none(),
                    weight,
                    credits: weight,
                    sealed: [0; N_SEAL_REASONS],
                    shed: [0; N_SHED_REASONS],
                    hint_shed_mark: 0,
                },
            );
        }
        let now = Instant::now();
        let mut dispatch = Vec::new();
        while let Some(batch) = pick_and_seal(&mut s, &manifest, now, &HashMap::new(), &test_tm()) {
            dispatch.push(batch[0].model.clone());
        }
        assert_eq!(
            dispatch,
            vec!["m", "m", "solo", "m", "m", "solo", "m", "m", "solo"],
            "weight-2 lane should dispatch twice per cycle"
        );
    }

    #[test]
    fn admission_wait_estimate_uses_ceiling_division() {
        // The boundary the floor bug got wrong: 3 queued ahead on 2
        // streams drain in ceil(3/2) = 2 waves, plus the job itself —
        // 3 service times, not the floored 2 that admitted requests
        // with already-unwinnable deadlines.
        assert_eq!(admission_wait_ns(1_000, 3, 2), 3_000);
        // Exact multiples are unchanged by the fix.
        assert_eq!(admission_wait_ns(1_000, 4, 2), 3_000);
        assert_eq!(admission_wait_ns(1_000, 0, 2), 1_000);
        // Single stream: every queued job is a full wave.
        assert_eq!(admission_wait_ns(500, 3, 1), 2_000);
        // streams=0 is defensively treated as 1, and huge estimates
        // saturate instead of wrapping.
        assert_eq!(admission_wait_ns(1_000, 2, 0), 3_000);
        assert_eq!(admission_wait_ns(u64::MAX, 5, 2), u64::MAX);
    }

    #[test]
    fn shed_reason_codes_roundtrip() {
        for (i, name) in SHED_REASON_NAMES.iter().enumerate() {
            let r = ShedReason::from_code(i as u8).unwrap();
            assert_eq!(r.code(), i as u8);
            assert_eq!(r.name(), *name);
        }
        assert_eq!(ShedReason::from_code(N_SHED_REASONS as u8), None);
        let shed = ExecError::shed(ShedReason::QueueFull, "lane full");
        assert_eq!(shed.shed_reason(), Some(ShedReason::QueueFull));
        assert!(shed.to_string().contains("queue_full"));
        assert!(shed.to_string().contains("full"));
        let failed = ExecError::Failed(anyhow!("boom"));
        assert_eq!(failed.shed_reason(), None);
        assert_eq!(failed.to_string(), "boom");
    }

    /// EDF lane selection without an engine: a later-submitted lane
    /// whose job carries a tight deadline seals ahead of an earlier
    /// deadline-free lane that the round-robin cursor would otherwise
    /// pick first.
    #[test]
    fn edf_lane_overtakes_round_robin_order() {
        let manifest = menu();
        let (tx, _rx) = mpsc::channel();
        let mut s = Sched {
            lanes: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            ready: VecDeque::new(),
            idle_workers: 0,
        };
        let now = Instant::now();
        let mut seq = 0u64;
        let mut mk = |model: &str, deadline: Option<Instant>| {
            seq += 1;
            Queued(Job {
                model: model.to_string(),
                raw: false,
                prio: 0,
                payload: TensorBuf::F32(vec![0.0; 4]),
                reply: tx.clone(),
                span: SpanRec::begin(),
                deadline,
                enqueued: now,
                seq,
            })
        };
        // Lane "m" is first in round-robin order, deadline-free.
        for (model, deadline) in [
            ("m", None),
            ("m", None),
            ("solo", Some(now + Duration::from_micros(200))),
        ] {
            s.order.push(model.to_string());
            s.order.dedup();
            let job = mk(model, deadline);
            let lane = s.lanes.entry(model.to_string()).or_insert(Lane {
                heap: BinaryHeap::new(),
                cfg: BatchCfg::opportunistic(4),
                weight: 1,
                credits: 1,
                sealed: [0; N_SEAL_REASONS],
                shed: [0; N_SHED_REASONS],
                hint_shed_mark: 0,
            });
            lane.heap.push(job);
        }
        let first =
            pick_and_seal(&mut s, &manifest, now, &HashMap::new(), &test_tm()).expect("seals");
        assert_eq!(
            first[0].model, "solo",
            "the tight-deadline lane must seal first, ahead of the cursor"
        );
        let second =
            pick_and_seal(&mut s, &manifest, now, &HashMap::new(), &test_tm()).expect("seals");
        assert_eq!(second[0].model, "m", "WRR resumes once SLO work drains");
    }

    /// When two lanes both hold SLO work, the earlier deadline wins
    /// regardless of submission or round-robin order.
    #[test]
    fn edf_orders_slo_lanes_by_deadline() {
        let manifest = menu();
        let (tx, _rx) = mpsc::channel();
        let mut s = Sched {
            lanes: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            ready: VecDeque::new(),
            idle_workers: 0,
        };
        let now = Instant::now();
        for (i, (model, deadline_us)) in [("m", 5_000u64), ("solo", 300)].into_iter().enumerate()
        {
            s.order.push(model.to_string());
            let mut heap = BinaryHeap::new();
            heap.push(Queued(Job {
                model: model.to_string(),
                raw: false,
                prio: 0,
                payload: TensorBuf::F32(vec![0.0; 4]),
                reply: tx.clone(),
                span: SpanRec::begin(),
                deadline: Some(now + Duration::from_micros(deadline_us)),
                enqueued: now,
                seq: i as u64,
            }));
            s.lanes.insert(
                model.to_string(),
                Lane {
                    heap,
                    cfg: BatchCfg::opportunistic(4),
                    weight: 1,
                    credits: 1,
                    sealed: [0; N_SEAL_REASONS],
                    shed: [0; N_SHED_REASONS],
                    hint_shed_mark: 0,
                },
            );
        }
        let first =
            pick_and_seal(&mut s, &manifest, now, &HashMap::new(), &test_tm()).expect("seals");
        assert_eq!(first[0].model, "solo", "earliest deadline first");
        let second =
            pick_and_seal(&mut s, &manifest, now, &HashMap::new(), &test_tm()).expect("seals");
        assert_eq!(second[0].model, "m");
    }

    /// The SLO seal: a gather that would otherwise hold for its flush
    /// window seals early (reason `Slo`) when the head's deadline minus
    /// the estimated batch service time has arrived.
    #[test]
    fn slo_deadline_seals_gather_early() {
        let manifest = menu();
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let mk = |seq: u64, deadline: Option<Instant>| {
            Queued(Job {
                model: "m".to_string(),
                raw: false,
                prio: 0,
                payload: TensorBuf::F32(vec![0.0; 4]),
                reply: tx.clone(),
                span: SpanRec::begin_at(now),
                deadline,
                enqueued: now,
                seq,
            })
        };
        let mut lane = Lane {
            heap: BinaryHeap::new(),
            cfg: BatchCfg::deadline(4, 1_000_000), // 1s flush: never expires here
            weight: 1,
            credits: 1,
            sealed: [0; N_SEAL_REASONS],
            shed: [0; N_SHED_REASONS],
            hint_shed_mark: 0,
        };
        // Plenty of budget left (10ms) and no service estimate: hold.
        lane.heap.push(mk(0, Some(now + Duration::from_millis(10))));
        assert!(try_seal(&mut lane, &manifest, now, 0, &test_tm()).is_none());
        assert_eq!(lane.sealed[SealReason::Slo as usize], 0);
        // With a 6ms/job estimate the 10ms budget is already critical
        // (one more µs of gathering guarantees a miss): seal as Slo.
        let est_ns = 6_000_000u64;
        let batch =
            try_seal(&mut lane, &manifest, now + Duration::from_millis(5), est_ns, &test_tm())
                .expect("critical SLO budget must seal");
        assert_eq!(batch.len(), 1);
        assert_eq!(lane.sealed[SealReason::Slo as usize], 1);
        // A deadline-free gather never Slo-seals, whatever the estimate.
        lane.heap.push(mk(1, None));
        assert!(try_seal(&mut lane, &manifest, now, est_ns, &test_tm()).is_none());
        assert_eq!(lane.sealed[SealReason::Slo as usize], 1);
    }
}
