//! The GPU-side execution service: a priority queue of inference jobs
//! drained by a pool of execution streams, with optional dynamic
//! batching onto the `_b{2,4,8}` artifacts.
//!
//! This is the live-plane mirror of the simulated stream scheduler:
//! `streams` bounds execution concurrency (Fig 15's trade-off), the
//! priority queue implements client priorities (Fig 16), and the
//! batcher exploits the per-batch compiled executables.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::runtime::{Engine, TensorBuf};

use super::protocol::StageNs;

/// One queued inference job.
pub struct Job {
    pub model: String,
    pub raw: bool,
    pub prio: u8,
    pub payload: TensorBuf,
    pub reply: mpsc::Sender<Result<Done>>,
    enqueued: Instant,
    seq: u64,
}

/// Completed job: output plus server-side stage timings.
#[derive(Debug, Clone)]
pub struct Done {
    pub output: Vec<f32>,
    pub stages: StageNs,
}

struct Queued(Job);

impl PartialEq for Queued {
    fn eq(&self, o: &Self) -> bool {
        self.0.prio == o.0.prio && self.0.seq == o.0.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Queued {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first, then FIFO by sequence.
        (self.0.prio, std::cmp::Reverse(self.0.seq))
            .cmp(&(o.0.prio, std::cmp::Reverse(o.0.seq)))
    }
}

struct Shared {
    queue: Mutex<BinaryHeap<Queued>>,
    cv: Condvar,
    stop: AtomicBool,
    seq: AtomicU64,
}

/// Handle to a running executor.
///
/// PJRT clients are thread-local (`Rc`-based in the xla crate), so each
/// execution stream worker owns a full `Engine` — one PJRT "device
/// context" per stream, like one CUDA stream + TensorRT context each.
pub struct Executor {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Dynamic-batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatchCfg {
    /// Largest batch artifact to use (1 disables batching).
    pub max_batch: usize,
}

impl Executor {
    /// Start `streams` execution workers over the artifact directory;
    /// each worker eagerly compiles the artifacts in `warm`.
    pub fn start(
        artifact_dir: impl Into<PathBuf>,
        streams: usize,
        batch: BatchCfg,
        warm: &[&str],
    ) -> Result<Executor> {
        assert!(streams >= 1);
        let dir: PathBuf = artifact_dir.into();
        let shared = Arc::new(Shared {
            queue: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            seq: AtomicU64::new(0),
        });
        let warm: Vec<String> = warm.iter().map(|s| s.to_string()).collect();
        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for _ in 0..streams {
            let sh = shared.clone();
            let dir = dir.clone();
            let warm = warm.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                let engine = match Engine::load(&dir).and_then(|e| {
                    let names: Vec<&str> = warm.iter().map(String::as_str).collect();
                    e.warm(&names)?;
                    Ok(e)
                }) {
                    Ok(e) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                worker_loop(sh, engine, batch)
            }));
        }
        drop(ready_tx);
        for _ in 0..streams {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during startup"))??;
        }
        Ok(Executor { shared, workers })
    }

    /// Submit a job; the reply arrives on the returned channel.
    pub fn submit(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
    ) -> mpsc::Receiver<Result<Done>> {
        let (tx, rx) = mpsc::channel();
        let job = Job {
            model: model.to_string(),
            raw,
            prio,
            payload,
            reply: tx,
            enqueued: Instant::now(),
            seq: self.shared.seq.fetch_add(1, Ordering::Relaxed),
        };
        self.shared.queue.lock().unwrap().push(Queued(job));
        self.shared.cv.notify_one();
        rx
    }

    /// Convenience: submit and wait.
    pub fn infer_sync(
        &self,
        model: &str,
        raw: bool,
        prio: u8,
        payload: TensorBuf,
    ) -> Result<Done> {
        self.submit(model, raw, prio, payload)
            .recv()
            .map_err(|_| anyhow!("executor dropped the job"))?
    }

    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Stop workers and join them.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>, engine: Engine, batch: BatchCfg) {
    loop {
        // Pop the highest-priority job (blocking).
        let head = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(j) = q.pop() {
                    break j.0;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        // Opportunistic batching: grab more queued jobs for the same
        // model/mode without waiting (no added latency; exploits bursts).
        let mut batch_jobs = vec![head];
        if batch.max_batch > 1 && !batch_jobs[0].raw {
            let mut q = sh.queue.lock().unwrap();
            let mut rest: Vec<Queued> = Vec::new();
            while batch_jobs.len() < batch.max_batch {
                match q.pop() {
                    None => break,
                    Some(Queued(j))
                        if j.model == batch_jobs[0].model
                            && !j.raw
                            && j.prio == batch_jobs[0].prio =>
                    {
                        batch_jobs.push(j)
                    }
                    Some(other) => rest.push(other),
                }
            }
            for o in rest {
                q.push(o);
            }
        }
        run_jobs(&engine, batch_jobs);
    }
}

/// Largest artifact batch size <= n among the compiled {1,2,4,8}.
fn artifact_batch(n: usize) -> usize {
    [8usize, 4, 2, 1].into_iter().find(|&b| b <= n).unwrap_or(1)
}

fn run_jobs(engine: &Engine, mut jobs: Vec<Job>) {
    while !jobs.is_empty() {
        let b = artifact_batch(jobs.len());
        let chunk: Vec<Job> = jobs.drain(..b).collect();
        run_chunk(engine, chunk);
    }
}

fn run_chunk(engine: &Engine, jobs: Vec<Job>) {
    let t_deq = Instant::now();
    let queue_ns: Vec<u64> = jobs
        .iter()
        .map(|j| t_deq.duration_since(j.enqueued).as_nanos() as u64)
        .collect();

    if jobs.len() == 1 && jobs[0].raw {
        // Two-stage raw pipeline: preprocess artifact, then batch-1 model
        // (separately timed, like the paper's preprocessing stage).
        let job = &jobs[0];
        let t0 = Instant::now();
        let pre = match &job.payload {
            // U8Region is the GDR zero-copy case: the preprocess
            // artifact reads straight out of the registered region.
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => {
                engine.infer("preprocess", &job.payload)
            }
            TensorBuf::F32(_) => Err(anyhow!("raw job with non-u8 payload")),
        };
        match pre {
            Err(e) => {
                let _ = jobs[0].reply.send(Err(e));
            }
            Ok(pre) => {
                let t1 = Instant::now();
                let name = format!("{}_b1", job.model);
                let out = engine.infer(&name, &TensorBuf::F32(pre));
                let t2 = Instant::now();
                let done = out.map(|output| Done {
                    output,
                    stages: StageNs {
                        queue_ns: queue_ns[0],
                        preproc_ns: (t1 - t0).as_nanos() as u64,
                        infer_ns: (t2 - t1).as_nanos() as u64,
                    },
                });
                let _ = jobs[0].reply.send(done);
            }
        }
        return;
    }

    // Preprocessed path, possibly batched.
    let b = jobs.len();
    let name = format!("{}_b{}", jobs[0].model, b);
    let mut flat: Vec<f32> = Vec::new();
    for j in &jobs {
        match &j.payload {
            TensorBuf::F32(v) => flat.extend_from_slice(v),
            TensorBuf::U8(_) | TensorBuf::U8Region(_) => {
                let _ = j.reply.send(Err(anyhow!("u8 payload without raw flag")));
                return;
            }
        }
    }
    let t1 = Instant::now();
    let res = engine.infer(&name, &TensorBuf::F32(flat));
    let infer_ns = t1.elapsed().as_nanos() as u64;
    match res {
        Err(e) => {
            let msg = format!("{e}");
            for j in &jobs {
                let _ = j.reply.send(Err(anyhow!("{msg}")));
            }
        }
        Ok(out) => {
            let per = out.len() / b;
            for (i, j) in jobs.iter().enumerate() {
                let _ = j.reply.send(Ok(Done {
                    output: out[i * per..(i + 1) * per].to_vec(),
                    stages: StageNs {
                        queue_ns: queue_ns[i],
                        preproc_ns: 0,
                        infer_ns,
                    },
                }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_batch_picks_largest_leq() {
        assert_eq!(artifact_batch(1), 1);
        assert_eq!(artifact_batch(3), 2);
        assert_eq!(artifact_batch(5), 4);
        assert_eq!(artifact_batch(8), 8);
        assert_eq!(artifact_batch(100), 8);
    }

    #[test]
    fn priority_queue_orders_jobs() {
        let (tx, _rx) = mpsc::channel();
        let mk = |prio: u8, seq: u64| {
            Queued(Job {
                model: "m".into(),
                raw: false,
                prio,
                payload: TensorBuf::F32(vec![]),
                reply: tx.clone(),
                enqueued: Instant::now(),
                seq,
            })
        };
        let mut h = BinaryHeap::new();
        h.push(mk(0, 0));
        h.push(mk(5, 1));
        h.push(mk(0, 2));
        h.push(mk(5, 3));
        let order: Vec<(u8, u64)> = std::iter::from_fn(|| h.pop())
            .map(|q| (q.0.prio, q.0.seq))
            .collect();
        assert_eq!(order, vec![(5, 1), (5, 3), (0, 0), (0, 2)]);
    }
}
