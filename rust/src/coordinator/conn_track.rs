//! Per-connection thread tracking for the server and gateway accept
//! loops. Before this existed, `ServeLoop::stop`/`GatewayLoop::stop`
//! joined only the accept thread while handler/relay threads stayed
//! parked forever in `recv()` on idle peers — `stop()` did not actually
//! stop serving. The tracker records every spawned connection thread
//! together with the transport shutdown hooks
//! ([`crate::transport::MsgTransport::shutdown_hook`]) that can unblock
//! it, and `stop_all` fires the hooks and joins.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A fired-once closure that unblocks a transport's parked `recv`.
pub(crate) type ShutdownHook = Box<dyn FnOnce() + Send>;

/// One tracked connection thread plus the hooks that unblock it (a
/// relay thread has two — the client and upstream legs).
struct TrackedConn {
    handle: JoinHandle<()>,
    hooks: Vec<ShutdownHook>,
}

/// Shared registry of live connection threads. Clone-cheap (an `Arc`):
/// the accept thread pushes, `stop_all` drains.
#[derive(Clone, Default)]
pub(crate) struct ConnTracker {
    conns: Arc<Mutex<Vec<TrackedConn>>>,
}

impl ConnTracker {
    pub(crate) fn new() -> ConnTracker {
        ConnTracker::default()
    }

    /// Register a spawned connection thread and the shutdown hooks for
    /// the transports it blocks on (`None` hooks are simply dropped).
    pub(crate) fn track(
        &self,
        handle: JoinHandle<()>,
        hooks: impl IntoIterator<Item = Option<ShutdownHook>>,
    ) {
        self.conns.lock().unwrap().push(TrackedConn {
            handle,
            hooks: hooks.into_iter().flatten().collect(),
        });
    }

    /// Unblock and join every tracked connection thread. A thread whose
    /// transports provided no hook is joined only if it already
    /// finished; otherwise it is left detached to exit on peer close
    /// (the pre-tracking behaviour) rather than wedging `stop()`.
    pub(crate) fn stop_all(&self) {
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for conn in conns {
            let hooked = !conn.hooks.is_empty();
            for hook in conn.hooks {
                hook();
            }
            if hooked || conn.handle.is_finished() {
                let _ = conn.handle.join();
            }
        }
    }
}
