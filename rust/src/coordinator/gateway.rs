//! Router–Dealer gateway: the frontend proxy of the paper's proxied
//! connection mode (§IV-B), in two flavours.
//!
//! * **Relay mode** ([`gateway_on`], [`gateway_tcp`]): one fixed
//!   upstream, one dealer connection per client, frames forwarded
//!   verbatim — the store-and-forward + protocol-translation hop the
//!   paper measures in isolation.
//! * **Routing mode** ([`routed_gateway_on`], [`gateway_tcp_multi`]):
//!   a [`Router`] places each model on one of N coordinator backends
//!   (consistent-hash or least-loaded placement over live stats),
//!   pools upstream connections, fails over when a backend dies
//!   (`Err`-before-drop preserved through the tier), and chains
//!   [`FLAG_PIPELINE`](super::protocol::FLAG_PIPELINE) requests stage
//!   to stage across backends with **no client round-trip** between
//!   stages — the paper's multi-node proxy-hop pipeline (§I, §V-B).
//!
//! Both faces stay transport-generic: any [`Acceptor`] downstream, any
//! connector upstream, so a TCP-facing gateway can dealer into an
//! RDMA/GDR fabric — the paper's "accelerate the last hop" deployment.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::transport::tcp::{TcpAcceptor, TcpTransport};
use crate::transport::{Acceptor, MsgTransport};

use super::conn_track::ConnTracker;
use super::protocol::{self, PipelineStage, Request, RequestMeta, Response, StageNs};
use super::router::{fit_f32, BackendSpec, Router, RouterCfg};

/// A running transport-generic gateway loop.
pub struct GatewayLoop {
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Background stats refresher (routing mode only).
    aux_thread: Option<std::thread::JoinHandle<()>>,
    conns: ConnTracker,
    /// Frames forwarded (both directions) — observability hook.
    pub forwarded: Arc<AtomicU64>,
}

impl GatewayLoop {
    /// Stop accepting, then unblock and join the relay threads (both
    /// legs of each relay are shut down via
    /// [`crate::transport::MsgTransport::shutdown_hook`], so a relay
    /// parked in `recv` on an idle client returns promptly). Before the
    /// tracker existed only the accept thread was joined and `stop()`
    /// left relays forwarding forever.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.aux_thread.take() {
            let _ = t.join();
        }
        self.conns.stop_all();
    }
}

/// Start a gateway: every connection accepted from the [`Acceptor`]
/// gets a dedicated upstream dealer [`MsgTransport`] connection from
/// `connect_upstream` and a relay thread.
pub fn gateway_on<A, U, F>(mut acceptor: A, connect_upstream: F) -> GatewayLoop
where
    A: Acceptor,
    U: MsgTransport + 'static,
    F: Fn() -> Result<U> + Send + 'static,
{
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let forwarded = Arc::new(AtomicU64::new(0));
    let fwd2 = forwarded.clone();
    let conns = ConnTracker::new();
    let conns2 = conns.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match acceptor.poll_accept() {
                Ok(Some(client)) => match connect_upstream() {
                    Ok(upstream) => {
                        let fwd = fwd2.clone();
                        let hooks = [client.shutdown_hook(), upstream.shutdown_hook()];
                        let handle =
                            std::thread::spawn(move || relay(client, upstream, &fwd));
                        conns2.track(handle, hooks);
                    }
                    Err(e) => {
                        // Upstream down: tell the client why before the
                        // connection drops, instead of a silent EOF it
                        // cannot diagnose. The client may not have sent
                        // its request yet — an unsolicited Err frame is
                        // still well-formed protocol, and the next recv
                        // on the client side surfaces it.
                        let hook = client.shutdown_hook();
                        let handle = std::thread::spawn(move || {
                            let mut client = client;
                            let resp =
                                Response::Err(format!("gateway: upstream unavailable: {e}"));
                            let _ = client.send(&resp.encode());
                        });
                        conns2.track(handle, [hook]);
                    }
                },
                Ok(None) => std::thread::sleep(Duration::from_millis(2)),
                Err(_) => break,
            }
        }
    });
    GatewayLoop {
        stop,
        accept_thread: Some(accept_thread),
        aux_thread: None,
        conns,
        forwarded,
    }
}

/// A running TCP-facing gateway.
pub struct GatewayHandle {
    pub addr: SocketAddr,
    inner: GatewayLoop,
}

impl GatewayHandle {
    /// Frames forwarded (both directions) — observability hook.
    pub fn forwarded(&self) -> &Arc<AtomicU64> {
        &self.inner.forwarded
    }

    pub fn stop(self) {
        self.inner.stop();
    }
}

/// Start a TCP-facing gateway forwarding every connection to
/// `upstream_addr` over a dedicated dealer connection.
pub fn gateway_tcp(addr: &str, upstream_addr: SocketAddr) -> Result<GatewayHandle> {
    let listener = TcpTransport::listen(addr)?;
    let acceptor = TcpAcceptor::new(listener)?;
    let local = acceptor.local_addr()?;
    let inner = gateway_on(acceptor, move || TcpTransport::connect(upstream_addr));
    Ok(GatewayHandle { addr: local, inner })
}

/// Synchronous request/response relay (closed-loop clients: one frame
/// outstanding per connection, exactly the Router-Dealer pattern). When
/// the upstream leg fails mid-request, the client gets a protocol `Err`
/// frame naming the failure before its connection closes — never a
/// silent EOF with a request outstanding.
fn relay(mut client: impl MsgTransport, mut upstream: impl MsgTransport, fwd: &AtomicU64) {
    loop {
        let Ok(req) = client.recv() else { return };
        if let Err(e) = upstream.send(&req) {
            let resp = Response::Err(format!("gateway: upstream send failed: {e}"));
            let _ = client.send(&resp.encode());
            return;
        }
        fwd.fetch_add(1, Ordering::Relaxed);
        let resp = match upstream.recv() {
            Ok(resp) => resp,
            Err(e) => {
                let resp = Response::Err(format!("gateway: upstream recv failed: {e}"));
                let _ = client.send(&resp.encode());
                return;
            }
        };
        if client.send(&resp).is_err() {
            return;
        }
        fwd.fetch_add(1, Ordering::Relaxed);
    }
}

/// Start a routing-mode gateway over `router`'s backends: accepted
/// clients get a routed request loop ([`handle_routed_conn`]) instead
/// of a fixed relay, and a background thread refreshes backend stats on
/// the [`RouterCfg::refresh`] cadence (the least-loaded/saturation
/// signal). If no backend is reachable at accept time the client gets
/// the same unsolicited `Err` frame as relay mode — never a silent EOF.
pub fn routed_gateway_on<A: Acceptor>(mut acceptor: A, router: Arc<Router>) -> GatewayLoop {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let forwarded = Arc::new(AtomicU64::new(0));
    let fwd2 = forwarded.clone();
    let conns = ConnTracker::new();
    let conns2 = conns.clone();
    let refresh_router = router.clone();
    let stop3 = stop.clone();
    let aux_thread = std::thread::spawn(move || {
        let interval = refresh_router.cfg().refresh;
        while !stop3.load(Ordering::SeqCst) {
            refresh_router.refresh_now();
            // Sleep in slices so stop() never waits a full interval.
            let woke = Instant::now();
            while woke.elapsed() < interval && !stop3.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    });
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match acceptor.poll_accept() {
                Ok(Some(client)) => {
                    if probe_any(&router) {
                        let fwd = fwd2.clone();
                        let r = router.clone();
                        let hook = client.shutdown_hook();
                        let handle =
                            std::thread::spawn(move || handle_routed_conn(client, &r, &fwd));
                        conns2.track(handle, [hook]);
                    } else {
                        let n = router.n_backends();
                        let hook = client.shutdown_hook();
                        let handle = std::thread::spawn(move || {
                            let mut client = client;
                            let resp = Response::Err(format!(
                                "gateway: upstream unavailable: all {n} backend(s) down"
                            ));
                            let _ = client.send(&resp.encode());
                        });
                        conns2.track(handle, [hook]);
                    }
                }
                Ok(None) => std::thread::sleep(Duration::from_millis(2)),
                Err(_) => break,
            }
        }
    });
    GatewayLoop {
        stop,
        accept_thread: Some(accept_thread),
        aux_thread: Some(aux_thread),
        conns,
        forwarded,
    }
}

/// Start a TCP-facing routing gateway over TCP backends at
/// `backend_addrs` (the CLI's repeatable `--backend`).
pub fn gateway_tcp_multi(
    addr: &str,
    backend_addrs: &[SocketAddr],
    cfg: RouterCfg,
) -> Result<GatewayHandle> {
    let listener = TcpTransport::listen(addr)?;
    let acceptor = TcpAcceptor::new(listener)?;
    let local = acceptor.local_addr()?;
    let specs = backend_addrs.iter().copied().map(BackendSpec::tcp).collect();
    let router = Arc::new(Router::new(specs, cfg));
    let inner = routed_gateway_on(acceptor, router);
    Ok(GatewayHandle { addr: local, inner })
}

/// Can any backend be reached right now? (Leases and returns a pooled
/// connection, so a positive probe also warms the pool.)
fn probe_any(router: &Router) -> bool {
    for idx in 0..router.n_backends() {
        if !router.is_usable(idx) {
            continue;
        }
        if let Ok(conn) = router.lease(idx) {
            router.release(idx, conn);
            return true;
        }
    }
    false
}

/// Routed request loop for one client connection: recv → place →
/// forward (or chain) → reply, until the client hangs up. Unlike relay
/// mode, an upstream failure answers this request with `Err` and keeps
/// the connection open — the next request re-routes to a survivor.
pub fn handle_routed_conn(mut client: impl MsgTransport, router: &Router, fwd: &AtomicU64) {
    loop {
        let Ok(frame) = client.recv() else { return };
        let reply = routed_reply(&frame, router, fwd);
        if client.send(&reply).is_err() {
            return;
        }
    }
}

/// Build the reply for one routed request frame.
fn routed_reply(frame: &[u8], router: &Router, fwd: &AtomicU64) -> Vec<u8> {
    match protocol::request_opcode(frame) {
        Err(e) => Response::Err(format!("gateway: bad request: {e}")).encode(),
        Ok(protocol::OP_STATS) => {
            // Fleet view: refresh every reachable backend, then merge
            // lanes by model so one stats frame covers the whole tier.
            router.refresh_now();
            Response::Stats(router.merged_stats()).encode()
        }
        Ok(protocol::OP_METRICS) => {
            // Fleet telemetry: bucket-wise histogram sums across every
            // backend that speaks the metrics opcode (rings dropped —
            // per-backend cadences don't merge meaningfully).
            router.refresh_metrics_now();
            Response::Metrics(router.merged_metrics()).encode()
        }
        Ok(protocol::OP_SHAPE) => match protocol::decode_shape_request(frame) {
            Err(e) => Response::Err(format!("gateway: bad request: {e}")).encode(),
            Ok(model) => {
                let shape = router
                    .route(&model)
                    .and_then(|idx| router.shape_of(&model, idx));
                match shape {
                    Ok((in_elems, out_elems)) => Response::Ok {
                        stages: StageNs::default(),
                        span: None,
                        payload: protocol::shape_payload(in_elems, out_elems),
                    }
                    .encode(),
                    Err(e) => Response::Err(format!("gateway: shape of {model}: {e}")).encode(),
                }
            }
        },
        Ok(_) => match protocol::split_header(frame) {
            Err(e) => Response::Err(format!("gateway: bad request: {e}")).encode(),
            Ok((meta, payload_off)) if !meta.pipeline.is_empty() => {
                run_pipeline(router, &meta, &frame[payload_off..], fwd)
            }
            Ok((meta, _)) => match router.route(&meta.model) {
                Err(e) => {
                    Response::Err(format!("gateway: no backend for {}: {e}", meta.model)).encode()
                }
                // Forward the client's frame verbatim — the routed hop
                // never re-encodes a single-stage request.
                Ok(idx) => match exchange(router, idx, frame, fwd) {
                    Ok(resp) => resp,
                    Err(e) => Response::Err(format!("gateway: {e}")).encode(),
                },
            },
        },
    }
}

/// One request/response exchange with backend `idx` over a pooled
/// connection. Any transport failure quarantines the backend
/// ([`Router::mark_down`]) and surfaces the same `upstream …` error
/// text relay mode uses, so failure reporting is uniform across modes.
fn exchange(router: &Router, idx: usize, frame: &[u8], fwd: &AtomicU64) -> Result<Vec<u8>, String> {
    let mut conn = router
        .lease(idx)
        .map_err(|e| format!("upstream unavailable: {e}"))?;
    if let Err(e) = conn.send(frame) {
        router.mark_down(idx);
        return Err(format!("upstream send failed: {e}"));
    }
    fwd.fetch_add(1, Ordering::Relaxed);
    match conn.recv() {
        Ok(resp) => {
            router.release(idx, conn);
            router.note_job(idx);
            fwd.fetch_add(1, Ordering::Relaxed);
            Ok(resp)
        }
        Err(e) => {
            router.mark_down(idx);
            Err(format!("upstream recv failed: {e}"))
        }
    }
}

/// Run a pipeline chain entirely inside the gateway: stage 0 is
/// `meta.model`, stages 1.. are `meta.pipeline`, each placed by the
/// router and fed the previous stage's output tensor (refit via
/// [`fit_f32`] to the stage's input shape) with **no client
/// round-trip** between stages. Stage timestamps (`sent_ns`/`recv_ns`)
/// share one gateway clock starting at request receipt, so the
/// returned windows are provably back-to-back. `FLAG_RAW` applies to
/// stage 0 only (later stages eat f32 tensors); `FLAG_CREDITS` is
/// ignored — pacing hints are per-backend and meaningless for a chain.
/// A deadline is forwarded to every stage (budget from each backend's
/// receipt). A stage `Shed` propagates to the client verbatim.
fn run_pipeline(router: &Router, meta: &RequestMeta, payload: &[u8], fwd: &AtomicU64) -> Vec<u8> {
    let t0 = Instant::now();
    let mut stages_out: Vec<PipelineStage> = Vec::with_capacity(1 + meta.pipeline.len());
    let mut tensor = payload.to_vec();
    let models: Vec<&str> = std::iter::once(meta.model.as_str())
        .chain(meta.pipeline.iter().map(String::as_str))
        .collect();
    for (k, model) in models.iter().enumerate() {
        let idx = match router.route(model) {
            Ok(idx) => idx,
            Err(e) => return stage_err(k, model, &e.to_string()),
        };
        if k > 0 {
            let (in_elems, _) = match router.shape_of(model, idx) {
                Ok(shape) => shape,
                Err(e) => return stage_err(k, model, &format!("shape: {e}")),
            };
            tensor = match fit_f32(&tensor, in_elems) {
                Ok(t) => t,
                Err(e) => return stage_err(k, model, &e.to_string()),
            };
        }
        let req = Request {
            model: (*model).to_string(),
            raw: meta.raw && k == 0,
            spans: meta.spans,
            prio: meta.prio,
            deadline_us: meta.deadline_us,
            credits: false,
            pipeline: vec![],
            payload: std::mem::take(&mut tensor),
        };
        let sent_ns = t0.elapsed().as_nanos() as u64;
        let raw_resp = match exchange(router, idx, &req.encode(), fwd) {
            Ok(resp) => resp,
            Err(e) => return stage_err(k, model, &e),
        };
        let recv_ns = (t0.elapsed().as_nanos() as u64).max(sent_ns);
        match Response::decode(&raw_resp) {
            Ok(Response::Ok { span, payload, .. }) => {
                tensor = payload;
                stages_out.push(PipelineStage {
                    model: (*model).to_string(),
                    sent_ns,
                    recv_ns,
                    span: span.unwrap_or_default(),
                });
            }
            Ok(Response::Shed { .. }) => return raw_resp,
            Ok(Response::Err(e)) => return stage_err(k, model, &e),
            Ok(other) => {
                return stage_err(k, model, &format!("unexpected upstream response {other:?}"))
            }
            Err(e) => return stage_err(k, model, &format!("bad upstream frame: {e}")),
        }
    }
    Response::Pipeline {
        stages: stages_out,
        payload: tensor,
    }
    .encode()
}

fn stage_err(k: usize, model: &str, msg: &str) -> Vec<u8> {
    Response::Err(format!("gateway: pipeline stage {k} ({model}): {msg}")).encode()
}
