//! Router–Dealer gateway: the frontend proxy of the paper's proxied
//! connection mode (§IV-B). Clients connect to the gateway; the gateway
//! opens one upstream (dealer) connection per client and forwards
//! frames verbatim — the store-and-forward + protocol-translation hop.
//! To isolate networking effects it always forwards to one fixed
//! upstream (as the paper configures it).
//!
//! `gateway_on` is transport-generic on both faces: any [`Acceptor`]
//! downstream, any connector closure upstream — so a TCP-facing
//! gateway can dealer into an RDMA/GDR fabric, the paper's
//! "accelerate the last hop" deployment (§V-B).

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::transport::tcp::{TcpAcceptor, TcpTransport};
use crate::transport::{Acceptor, MsgTransport};

use super::conn_track::ConnTracker;
use super::protocol::Response;

/// A running transport-generic gateway loop.
pub struct GatewayLoop {
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    conns: ConnTracker,
    /// Frames forwarded (both directions) — observability hook.
    pub forwarded: Arc<AtomicU64>,
}

impl GatewayLoop {
    /// Stop accepting, then unblock and join the relay threads (both
    /// legs of each relay are shut down via
    /// [`crate::transport::MsgTransport::shutdown_hook`], so a relay
    /// parked in `recv` on an idle client returns promptly). Before the
    /// tracker existed only the accept thread was joined and `stop()`
    /// left relays forwarding forever.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.conns.stop_all();
    }
}

/// Start a gateway: every connection accepted from the [`Acceptor`]
/// gets a dedicated upstream dealer [`MsgTransport`] connection from
/// `connect_upstream` and a relay thread.
pub fn gateway_on<A, U, F>(mut acceptor: A, connect_upstream: F) -> GatewayLoop
where
    A: Acceptor,
    U: MsgTransport + 'static,
    F: Fn() -> Result<U> + Send + 'static,
{
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let forwarded = Arc::new(AtomicU64::new(0));
    let fwd2 = forwarded.clone();
    let conns = ConnTracker::new();
    let conns2 = conns.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match acceptor.poll_accept() {
                Ok(Some(client)) => match connect_upstream() {
                    Ok(upstream) => {
                        let fwd = fwd2.clone();
                        let hooks = [client.shutdown_hook(), upstream.shutdown_hook()];
                        let handle =
                            std::thread::spawn(move || relay(client, upstream, &fwd));
                        conns2.track(handle, hooks);
                    }
                    Err(e) => {
                        // Upstream down: tell the client why before the
                        // connection drops, instead of a silent EOF it
                        // cannot diagnose. The client may not have sent
                        // its request yet — an unsolicited Err frame is
                        // still well-formed protocol, and the next recv
                        // on the client side surfaces it.
                        let hook = client.shutdown_hook();
                        let handle = std::thread::spawn(move || {
                            let mut client = client;
                            let resp =
                                Response::Err(format!("gateway: upstream unavailable: {e}"));
                            let _ = client.send(&resp.encode());
                        });
                        conns2.track(handle, [hook]);
                    }
                },
                Ok(None) => std::thread::sleep(Duration::from_millis(2)),
                Err(_) => break,
            }
        }
    });
    GatewayLoop {
        stop,
        accept_thread: Some(accept_thread),
        conns,
        forwarded,
    }
}

/// A running TCP-facing gateway.
pub struct GatewayHandle {
    pub addr: SocketAddr,
    inner: GatewayLoop,
}

impl GatewayHandle {
    /// Frames forwarded (both directions) — observability hook.
    pub fn forwarded(&self) -> &Arc<AtomicU64> {
        &self.inner.forwarded
    }

    pub fn stop(self) {
        self.inner.stop();
    }
}

/// Start a TCP-facing gateway forwarding every connection to
/// `upstream_addr` over a dedicated dealer connection.
pub fn gateway_tcp(addr: &str, upstream_addr: SocketAddr) -> Result<GatewayHandle> {
    let listener = TcpTransport::listen(addr)?;
    let acceptor = TcpAcceptor::new(listener)?;
    let local = acceptor.local_addr()?;
    let inner = gateway_on(acceptor, move || TcpTransport::connect(upstream_addr));
    Ok(GatewayHandle { addr: local, inner })
}

/// Synchronous request/response relay (closed-loop clients: one frame
/// outstanding per connection, exactly the Router-Dealer pattern). When
/// the upstream leg fails mid-request, the client gets a protocol `Err`
/// frame naming the failure before its connection closes — never a
/// silent EOF with a request outstanding.
fn relay(mut client: impl MsgTransport, mut upstream: impl MsgTransport, fwd: &AtomicU64) {
    loop {
        let Ok(req) = client.recv() else { return };
        if let Err(e) = upstream.send(&req) {
            let resp = Response::Err(format!("gateway: upstream send failed: {e}"));
            let _ = client.send(&resp.encode());
            return;
        }
        fwd.fetch_add(1, Ordering::Relaxed);
        let resp = match upstream.recv() {
            Ok(resp) => resp,
            Err(e) => {
                let resp = Response::Err(format!("gateway: upstream recv failed: {e}"));
                let _ = client.send(&resp.encode());
                return;
            }
        };
        if client.send(&resp).is_err() {
            return;
        }
        fwd.fetch_add(1, Ordering::Relaxed);
    }
}
