//! Router–Dealer gateway: the frontend proxy of the paper's proxied
//! connection mode (§IV-B). Clients connect to the gateway; the gateway
//! opens one upstream (dealer) connection per client and forwards
//! frames verbatim — the store-and-forward + protocol-translation hop.
//! To isolate networking effects it always forwards to one fixed server
//! (as the paper configures it).

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::transport::tcp::TcpTransport;
use crate::transport::MsgTransport;

/// A running gateway.
pub struct GatewayHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Frames forwarded (both directions) — observability hook.
    pub forwarded: Arc<AtomicU64>,
}

impl GatewayHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Start a TCP-facing gateway forwarding every connection to
/// `upstream_addr` over a dedicated dealer connection.
pub fn gateway_tcp(addr: &str, upstream_addr: SocketAddr) -> Result<GatewayHandle> {
    let listener = TcpTransport::listen(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let forwarded = Arc::new(AtomicU64::new(0));
    let fwd2 = forwarded.clone();
    let accept_thread = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let fwd = fwd2.clone();
                    std::thread::spawn(move || {
                        let client = TcpTransport::from_stream(stream);
                        match TcpTransport::connect(upstream_addr) {
                            Ok(upstream) => relay(client, upstream, &fwd),
                            Err(_) => { /* upstream down: drop client */ }
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    });
    Ok(GatewayHandle {
        addr: local,
        stop,
        accept_thread: Some(accept_thread),
        forwarded,
    })
}

/// Synchronous request/response relay (closed-loop clients: one frame
/// outstanding per connection, exactly the Router-Dealer pattern).
fn relay(mut client: impl MsgTransport, mut upstream: impl MsgTransport, fwd: &AtomicU64) {
    loop {
        let Ok(req) = client.recv() else { return };
        if upstream.send(&req).is_err() {
            return;
        }
        fwd.fetch_add(1, Ordering::Relaxed);
        let Ok(resp) = upstream.recv() else { return };
        if client.send(&resp).is_err() {
            return;
        }
        fwd.fetch_add(1, Ordering::Relaxed);
    }
}
