//! accelserve — a model-serving framework with hardware-accelerated
//! communication (TCP / RDMA / GPUDirect RDMA), reproducing Hanafy et
//! al., "Understanding the Benefits of Hardware-Accelerated
//! Communication in Model-Serving Applications".
//!
//! Two execution planes share the coordinator code (DESIGN.md §3):
//! a deterministic discrete-event **sim plane** that regenerates every
//! figure of the paper on a modeled A2 + 25 GbE testbed, and a **live
//! plane** that serves real AOT-compiled JAX/Pallas models through PJRT
//! over real sockets.

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gpu;
pub mod metrics;
pub mod models;
pub mod net;
pub mod rdmasim;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod transport;
