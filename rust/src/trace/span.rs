//! Per-request span records: monotonic-clock stamps taken as a request
//! passes through the live serving pipeline.
//!
//! A [`SpanRec`] is created by the server when the request frame is
//! complete at the transport boundary (the base instant, the live
//! analogue of an RDMA WR timestamp) and travels with the job through
//! the executor and engine; each component marks its [`Stamp`] as an
//! offset in nanoseconds from the base. Marking is first-write-wins,
//! so re-considering a job (a gather that aborts and re-forms) cannot
//! move an already-taken stamp backwards, and a fixed-size array plus a
//! bitmask keeps the hot-path cost to one `Instant::now()` and two
//! stores per stamp.

use std::time::{Duration, Instant};

/// Stamp events, in pipeline order. The discriminant is the wire id of
/// the stamp in a response span block (see [`crate::trace::wire`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stamp {
    /// Request frame complete at the transport boundary (ring slot /
    /// socket), before any host bounce copy. Offset 0 by construction.
    RecvRing = 0,
    /// Request parsed, payload materialized for the executor.
    RecvDone = 1,
    /// Job entered its model lane.
    Enqueue = 2,
    /// Scheduler first pulled the job into a candidate gather.
    GatherStart = 3,
    /// The job's batch sealed.
    Seal = 4,
    /// A stream worker started executing the job's chunk.
    Dispatch = 5,
    /// Input staged on the device (row gather + literal build done).
    H2dDone = 6,
    /// GPU preprocessing finished (raw inputs only).
    PreprocDone = 7,
    /// Compute finished.
    InferDone = 8,
    /// Output fetched back to the host, rows scattered.
    D2hDone = 9,
    /// Server began building the reply frame.
    ReplySend = 10,
}

/// Number of stamp slots in a span.
pub const N_STAMPS: usize = 11;

impl Stamp {
    /// Every stamp, in pipeline order.
    pub const ALL: [Stamp; N_STAMPS] = [
        Stamp::RecvRing,
        Stamp::RecvDone,
        Stamp::Enqueue,
        Stamp::GatherStart,
        Stamp::Seal,
        Stamp::Dispatch,
        Stamp::H2dDone,
        Stamp::PreprocDone,
        Stamp::InferDone,
        Stamp::D2hDone,
        Stamp::ReplySend,
    ];

    /// Wire id of the stamp.
    pub fn id(self) -> u8 {
        self as u8
    }

    /// Stamp for a wire id, if known.
    pub fn from_id(id: u8) -> Option<Stamp> {
        Stamp::ALL.get(id as usize).copied()
    }

    /// Human-readable stamp name.
    pub fn name(self) -> &'static str {
        match self {
            Stamp::RecvRing => "recv-ring",
            Stamp::RecvDone => "recv-done",
            Stamp::Enqueue => "enqueue",
            Stamp::GatherStart => "gather-start",
            Stamp::Seal => "seal",
            Stamp::Dispatch => "dispatch",
            Stamp::H2dDone => "h2d-done",
            Stamp::PreprocDone => "preproc-done",
            Stamp::InferDone => "infer-done",
            Stamp::D2hDone => "d2h-done",
            Stamp::ReplySend => "reply-send",
        }
    }
}

/// The span timeline of one live request: a base instant plus up to
/// [`N_STAMPS`] nanosecond offsets (see the module docs).
#[derive(Debug, Clone)]
pub struct SpanRec {
    base: Instant,
    off: [u64; N_STAMPS],
    set: u16,
}

impl SpanRec {
    /// Begin a span now (marks [`Stamp::RecvRing`] at offset 0).
    pub fn begin() -> SpanRec {
        SpanRec::begin_at(Instant::now())
    }

    /// Begin a span at a transport-provided boundary instant (marks
    /// [`Stamp::RecvRing`] at offset 0).
    pub fn begin_at(base: Instant) -> SpanRec {
        let mut s = SpanRec {
            base,
            off: [0; N_STAMPS],
            set: 0,
        };
        s.mark_at(Stamp::RecvRing, base);
        s
    }

    /// The span's base instant (the [`Stamp::RecvRing`] event).
    pub fn base(&self) -> Instant {
        self.base
    }

    /// Mark `stamp` at the current instant (first write wins).
    pub fn mark(&mut self, stamp: Stamp) {
        self.mark_at(stamp, Instant::now());
    }

    /// Mark `stamp` at an explicit instant (first write wins; instants
    /// before the base clamp to offset 0).
    pub fn mark_at(&mut self, stamp: Stamp, t: Instant) {
        let bit = 1u16 << stamp.id();
        if self.set & bit != 0 {
            return;
        }
        self.off[stamp.id() as usize] =
            t.saturating_duration_since(self.base).as_nanos() as u64;
        self.set |= bit;
    }

    /// Offset of `stamp` in nanoseconds from the base, if marked.
    pub fn get(&self, stamp: Stamp) -> Option<u64> {
        if self.set & (1u16 << stamp.id()) != 0 {
            Some(self.off[stamp.id() as usize])
        } else {
            None
        }
    }

    /// Is `stamp` marked?
    pub fn is_set(&self, stamp: Stamp) -> bool {
        self.set & (1u16 << stamp.id()) != 0
    }

    /// Marked stamps in pipeline (= wire id) order.
    pub fn stamps(&self) -> impl Iterator<Item = (Stamp, u64)> + '_ {
        Stamp::ALL
            .iter()
            .filter_map(move |&s| self.get(s).map(|o| (s, o)))
    }

    /// Number of marked stamps.
    pub fn len(&self) -> usize {
        self.set.count_ones() as usize
    }

    /// True when no stamp is marked (never the case after `begin`).
    pub fn is_empty(&self) -> bool {
        self.set == 0
    }

    /// Convenience for stamping an event a known duration after another
    /// instant (e.g. engine-reported copy/compute durations).
    pub fn mark_after(&mut self, stamp: Stamp, from: Instant, ns: u64) {
        self.mark_at(stamp, from + Duration::from_nanos(ns));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamp_ids_roundtrip() {
        for (i, s) in Stamp::ALL.iter().enumerate() {
            assert_eq!(s.id() as usize, i);
            assert_eq!(Stamp::from_id(s.id()), Some(*s), "{}", s.name());
        }
        assert_eq!(Stamp::from_id(N_STAMPS as u8), None);
    }

    #[test]
    fn begin_marks_ring_at_zero() {
        let s = SpanRec::begin();
        assert_eq!(s.get(Stamp::RecvRing), Some(0));
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
        assert!(!s.is_set(Stamp::Enqueue));
    }

    #[test]
    fn first_write_wins() {
        let base = Instant::now();
        let mut s = SpanRec::begin_at(base);
        s.mark_at(Stamp::Seal, base + Duration::from_nanos(100));
        s.mark_at(Stamp::Seal, base + Duration::from_nanos(999));
        assert_eq!(s.get(Stamp::Seal), Some(100));
    }

    #[test]
    fn pre_base_instants_clamp_to_zero() {
        let base = Instant::now();
        let mut s = SpanRec::begin_at(base + Duration::from_millis(1));
        s.mark_at(Stamp::RecvDone, base);
        assert_eq!(s.get(Stamp::RecvDone), Some(0));
    }

    #[test]
    fn stamps_iterate_in_pipeline_order() {
        let base = Instant::now();
        let mut s = SpanRec::begin_at(base);
        s.mark_at(Stamp::Dispatch, base + Duration::from_nanos(50));
        s.mark_at(Stamp::Enqueue, base + Duration::from_nanos(10));
        let got: Vec<(Stamp, u64)> = s.stamps().collect();
        assert_eq!(
            got,
            vec![
                (Stamp::RecvRing, 0),
                (Stamp::Enqueue, 10),
                (Stamp::Dispatch, 50)
            ]
        );
    }

    #[test]
    fn mark_after_offsets_from_given_instant() {
        let base = Instant::now();
        let mut s = SpanRec::begin_at(base);
        s.mark_after(Stamp::H2dDone, base + Duration::from_nanos(100), 40);
        assert_eq!(s.get(Stamp::H2dDone), Some(140));
    }
}
