//! Live-plane stage tracing: a compact per-request span timeline
//! threaded through the whole serving pipeline, carried back to the
//! client inside the wire protocol (v2 responses).
//!
//! The paper's headline contribution is *visibility*: CUDA-event /
//! WR-timestamp profiling that decomposes model-serving latency into
//! per-stage overheads (§III-B, Table I, Figs 5–9), which is what shows
//! where RDMA/GPUDirect actually help. The sim plane always had that
//! breakdown (`metrics::stats::ReqRecord`); this module gives the live
//! plane the same thing: every component stamps a monotonic-clock
//! offset into the request's [`SpanRec`] as the request passes through
//! — the transport at the ring boundary, the server at parse, the
//! executor at lane enqueue / gather / seal / dispatch, the engine
//! around its staging copies and compute — and the server returns the
//! stamps to the client in the response's span block.
//!
//! # Stage taxonomy
//!
//! Nine derived stages, the shared vocabulary of both planes ([`Stage`];
//! the sim's `ReqRecord` fields map onto the same names):
//!
//! | stage              | live-plane interval                  | paper analogue        |
//! |--------------------|--------------------------------------|-----------------------|
//! | request-transport  | client wire half + ring→parse bounce | req transfer (Fig 2)  |
//! | lane-queue         | parse → first gather consideration   | server queueing       |
//! | gather-wait        | gather start → batch sealed          | batching delay        |
//! | dispatch-wait      | sealed → chunk execution starts      | stream-slot queueing  |
//! | copy-h2d           | dispatch → input staged on device    | H2D copy (Table I)    |
//! | preproc            | staging → preprocessing done         | preprocessing         |
//! | infer              | preprocess → compute finished        | inference             |
//! | copy-d2h           | compute → output back on host        | D2H copy (Table I)    |
//! | response-transport | reply build + client wire half       | resp transfer         |
//!
//! Raw stamps are the finer-grained [`Stamp`] events; a
//! [`StageBreakdown`] collapses consecutive stamp intervals onto the
//! nine stages so the components sum to the client-observed end-to-end
//! latency *exactly* (`accelserve stagebreak` asserts this).

pub mod breakdown;
pub mod export;
pub mod span;
pub mod wire;

pub use breakdown::{BreakdownAgg, StageBreakdown};
pub use export::{ArgVal, ChromeTrace};
pub use span::{SpanRec, Stamp, N_STAMPS};
pub use wire::{decode_span_block, encode_span_block, SpanBlock, SPAN_VER};

/// The fixed nine-stage taxonomy shared by the live and sim planes
/// (see the module docs for the live-plane interval each stage covers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Client-to-server transport, incl. the receive-side host bounce.
    RequestXfer,
    /// Waiting in the model lane before the scheduler first considers
    /// the job for a gather.
    LaneQueue,
    /// Waiting while the job's batch gathers peers (the flush window).
    GatherWait,
    /// Sealed batch waiting for its execution stream (rendezvous plus
    /// any earlier chunks of the same sealed batch).
    DispatchWait,
    /// Staging the input onto the device (row gather + literal build).
    CopyH2d,
    /// GPU preprocessing (raw inputs only; zero otherwise).
    Preproc,
    /// Compute: the executable call itself.
    Infer,
    /// Fetching the output back to the host and scattering rows.
    CopyD2h,
    /// Reply build plus server-to-client transport.
    ResponseXfer,
}

/// Number of stages in the taxonomy.
pub const N_STAGES: usize = 9;

impl Stage {
    /// Every stage, in pipeline order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::RequestXfer,
        Stage::LaneQueue,
        Stage::GatherWait,
        Stage::DispatchWait,
        Stage::CopyH2d,
        Stage::Preproc,
        Stage::Infer,
        Stage::CopyD2h,
        Stage::ResponseXfer,
    ];

    /// Human-readable stage name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::RequestXfer => "request-transport",
            Stage::LaneQueue => "lane-queue",
            Stage::GatherWait => "gather-wait",
            Stage::DispatchWait => "dispatch-wait",
            Stage::CopyH2d => "copy-h2d",
            Stage::Preproc => "preproc",
            Stage::Infer => "infer",
            Stage::CopyD2h => "copy-d2h",
            Stage::ResponseXfer => "response-transport",
        }
    }

    /// Short column label for result tables (`accelserve stagebreak`).
    pub fn column(self) -> &'static str {
        match self {
            Stage::RequestXfer => "req_ms",
            Stage::LaneQueue => "queue_ms",
            Stage::GatherWait => "gather_ms",
            Stage::DispatchWait => "disp_ms",
            Stage::CopyH2d => "h2d_ms",
            Stage::Preproc => "pre_ms",
            Stage::Infer => "infer_ms",
            Stage::CopyD2h => "d2h_ms",
            Stage::ResponseXfer => "resp_ms",
        }
    }

    /// Index into [`Stage::ALL`]-ordered arrays.
    pub fn idx(self) -> usize {
        self as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_indices_match_order() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.idx(), i, "{}", s.name());
        }
        assert_eq!(Stage::ALL.len(), N_STAGES);
    }

    #[test]
    fn stage_labels_unique() {
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        let mut cols: Vec<&str> = Stage::ALL.iter().map(|s| s.column()).collect();
        names.sort();
        names.dedup();
        cols.sort();
        cols.dedup();
        assert_eq!(names.len(), N_STAGES);
        assert_eq!(cols.len(), N_STAGES);
    }
}
