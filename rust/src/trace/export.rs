//! Chrome trace-event export: serialize collected span timelines as a
//! JSON file loadable in `ui.perfetto.dev` (or `chrome://tracing`).
//!
//! The paper's profiling story (§III-B) is a *timeline* story — CUDA
//! events and WR timestamps bracketing every stage of every request —
//! but our reporting so far collapses those stamps into aggregate
//! tables. This module keeps the per-request resolution: each request
//! becomes nine complete events (`"ph":"X"`, one per [`Stage`]) tiled
//! back to back so the track reads exactly like Fig 2's pipeline
//! diagram, one track (`tid`) per lane/stream/transport ring.
//!
//! The JSON is hand-rolled: the tree is offline/vendored (no serde) and
//! the golden-fixture test wants byte-stable output, so timestamps are
//! formatted with pure integer math (`ns/1000.ns%1000` microseconds,
//! three fixed decimals) — no float formatting is involved anywhere.
//!
//! Both planes feed the same exporter: the live plane via
//! [`ChromeTrace::block`] (a wire [`SpanBlock`] collapsed through
//! [`StageBreakdown::from_span`]) and the sim plane via
//! [`ChromeTrace::record`] (a [`ReqRecord`] whose fields already *are*
//! the stage durations).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::stats::ReqRecord;
use crate::trace::{SpanBlock, Stage, StageBreakdown};

/// One typed event argument (the `args` object of a trace event).
#[derive(Debug, Clone)]
pub enum ArgVal {
    U64(u64),
    Str(String),
}

/// Trace-event phase: complete tiles (`"X"`), counter-track samples
/// (`"C"`), and flow arrows (`"s"` start / `"f"` finish) tying one
/// request's tiles together across tracks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Complete,
    Counter,
    FlowStart,
    FlowFinish,
}

/// One event on one track.
#[derive(Debug, Clone)]
struct EvRec {
    name: String,
    cat: &'static str,
    tid: usize,
    ts_ns: u64,
    dur_ns: u64,
    ph: Phase,
    /// Flow-binding id (`"ph":"s"`/`"f"` pairs share it); unused by
    /// complete and counter events.
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
}

/// A Chrome trace-event document under construction: interned tracks
/// (each becomes a named thread via a `thread_name` metadata event) and
/// a flat list of complete events.
#[derive(Debug, Clone, Default)]
pub struct ChromeTrace {
    tracks: Vec<String>,
    events: Vec<EvRec>,
}

impl ChromeTrace {
    pub fn new() -> ChromeTrace {
        ChromeTrace::default()
    }

    /// Intern a track by exact name; the returned id is the event `tid`.
    /// Repeated calls with the same name return the same id.
    pub fn track(&mut self, name: &str) -> usize {
        if let Some(i) = self.tracks.iter().position(|t| t == name) {
            return i;
        }
        self.tracks.push(name.to_string());
        self.tracks.len() - 1
    }

    /// Append one complete event to `track` (a [`ChromeTrace::track`] id).
    pub fn event(
        &mut self,
        track: usize,
        name: &str,
        cat: &'static str,
        ts_ns: u64,
        dur_ns: u64,
        args: &[(&'static str, ArgVal)],
    ) {
        self.events.push(EvRec {
            name: name.to_string(),
            cat,
            tid: track,
            ts_ns,
            dur_ns,
            ph: Phase::Complete,
            id: 0,
            args: args.to_vec(),
        });
    }

    /// Append one counter-track sample (`"ph":"C"`): at `ts_ns` the
    /// series `name` has `value`. Consecutive samples on the same name
    /// render as a stacked area chart in the trace viewer — the standard
    /// presentation for sampler-ring deltas (queue depth, sheds/s).
    pub fn counter(&mut self, track: usize, name: &str, ts_ns: u64, value: u64) {
        self.events.push(EvRec {
            name: name.to_string(),
            cat: "counter",
            tid: track,
            ts_ns,
            dur_ns: 0,
            ph: Phase::Counter,
            id: 0,
            args: vec![("value", ArgVal::U64(value))],
        });
    }

    /// Open a flow arrow `id` at `ts_ns` on `track` (`"ph":"s"`). The
    /// viewer draws an arrow from here to the matching
    /// [`ChromeTrace::flow_finish`] — used to link one pipeline
    /// request's tiles across the gateway and backend tracks.
    pub fn flow_start(&mut self, track: usize, name: &str, ts_ns: u64, id: u64) {
        self.events.push(EvRec {
            name: name.to_string(),
            cat: "flow",
            tid: track,
            ts_ns,
            dur_ns: 0,
            ph: Phase::FlowStart,
            id,
            args: Vec::new(),
        });
    }

    /// Close flow arrow `id` at `ts_ns` on `track` (`"ph":"f"`, binding
    /// point `"e"` so the arrow lands on the enclosing tile).
    pub fn flow_finish(&mut self, track: usize, name: &str, ts_ns: u64, id: u64) {
        self.events.push(EvRec {
            name: name.to_string(),
            cat: "flow",
            tid: track,
            ts_ns,
            dur_ns: 0,
            ph: Phase::FlowFinish,
            id,
            args: Vec::new(),
        });
    }

    /// Tile one request's nine-stage breakdown onto `track` starting at
    /// `start_ns`. Zero-duration stages are emitted too (every [`Stage`]
    /// name appears on every request), and because a breakdown
    /// partitions the end-to-end latency exactly, the tiles end at
    /// `start_ns + total`.
    pub fn stages(
        &mut self,
        track: usize,
        start_ns: u64,
        b: &StageBreakdown,
        args: &[(&'static str, ArgVal)],
    ) {
        let mut t = start_ns;
        for s in Stage::ALL {
            let d = b.get(s);
            self.event(track, s.name(), "stage", t, d, args);
            t += d;
        }
    }

    /// Live-plane entry point: collapse a wire span block onto the nine
    /// stages and tile it (see [`StageBreakdown::from_span`]).
    pub fn block(
        &mut self,
        track: usize,
        start_ns: u64,
        span: &SpanBlock,
        total_ns: u64,
        args: &[(&'static str, ArgVal)],
    ) {
        let b = StageBreakdown::from_span(span, total_ns);
        self.stages(track, start_ns, &b, args);
    }

    /// Sim-plane entry point: a [`ReqRecord`]'s fields map onto the
    /// stage taxonomy directly (same order, same names), so the sim's
    /// timelines export in the identical format as live span blocks.
    pub fn record(
        &mut self,
        track: usize,
        start_ns: u64,
        r: &ReqRecord,
        args: &[(&'static str, ArgVal)],
    ) {
        let durs: [u64; super::N_STAGES] = [
            r.request.0,
            r.lane_queue.0,
            r.gather_wait.0,
            r.dispatch_wait.0,
            r.copy_h2d.0,
            r.preproc.0,
            r.infer.0,
            r.copy_d2h.0,
            r.response.0,
        ];
        let mut t = start_ns;
        for (s, d) in Stage::ALL.iter().zip(durs) {
            self.event(track, s.name(), "stage", t, d, args);
            t += d;
        }
    }

    /// Number of data events collected (metadata events not counted).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Sanity-check the document: within each track, complete events
    /// must not overlap (`ts + dur <= next ts` in append order). The
    /// exporters above append per-request tiles in request-start order
    /// per track, so a violation means a caller interleaved concurrent
    /// requests on one track. Counter samples and flow endpoints are
    /// instants layered over the tiles — exempt by design.
    pub fn validate(&self) -> Result<()> {
        let mut last_end = vec![0u64; self.tracks.len()];
        for e in &self.events {
            if e.ph != Phase::Complete {
                continue;
            }
            if e.ts_ns < last_end[e.tid] {
                bail!(
                    "track '{}': event '{}' starts at {}ns before previous end {}ns",
                    self.tracks[e.tid],
                    e.name,
                    e.ts_ns,
                    last_end[e.tid]
                );
            }
            last_end[e.tid] = e.ts_ns + e.dur_ns;
        }
        Ok(())
    }

    /// Serialize to Chrome trace-event JSON (deterministic, one event
    /// per line): a `process_name` metadata event, one `thread_name`
    /// metadata event per track, then every data event in append order.
    pub fn to_json(&self) -> String {
        let mut lines = Vec::with_capacity(1 + self.tracks.len() + self.events.len());
        lines.push(
            r#"{"name":"process_name","ph":"M","pid":1,"tid":0,"args":{"name":"accelserve"}}"#
                .to_string(),
        );
        for (tid, name) in self.tracks.iter().enumerate() {
            lines.push(format!(
                r#"{{"name":"thread_name","ph":"M","pid":1,"tid":{tid},"args":{{"name":"{}"}}}}"#,
                escape(name)
            ));
        }
        for e in &self.events {
            let mut args = String::new();
            for (i, (k, v)) in e.args.iter().enumerate() {
                if i > 0 {
                    args.push(',');
                }
                match v {
                    ArgVal::U64(n) => args.push_str(&format!(r#""{k}":{n}"#)),
                    ArgVal::Str(s) => args.push_str(&format!(r#""{k}":"{}""#, escape(s))),
                }
            }
            lines.push(match e.ph {
                Phase::Complete => format!(
                    r#"{{"name":"{}","cat":"{}","ph":"X","ts":{},"dur":{},"pid":1,"tid":{},"args":{{{args}}}}}"#,
                    escape(&e.name),
                    e.cat,
                    fmt_us(e.ts_ns),
                    fmt_us(e.dur_ns),
                    e.tid,
                ),
                Phase::Counter => format!(
                    r#"{{"name":"{}","cat":"{}","ph":"C","ts":{},"pid":1,"tid":{},"args":{{{args}}}}}"#,
                    escape(&e.name),
                    e.cat,
                    fmt_us(e.ts_ns),
                    e.tid,
                ),
                Phase::FlowStart => format!(
                    r#"{{"name":"{}","cat":"{}","ph":"s","ts":{},"pid":1,"tid":{},"id":{},"args":{{{args}}}}}"#,
                    escape(&e.name),
                    e.cat,
                    fmt_us(e.ts_ns),
                    e.tid,
                    e.id,
                ),
                Phase::FlowFinish => format!(
                    r#"{{"name":"{}","cat":"{}","ph":"f","bp":"e","ts":{},"pid":1,"tid":{},"id":{},"args":{{{args}}}}}"#,
                    escape(&e.name),
                    e.cat,
                    fmt_us(e.ts_ns),
                    e.tid,
                    e.id,
                ),
            });
        }
        format!(
            "{{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n{}\n]}}\n",
            lines.join(",\n")
        )
    }

    /// Validate and write the document to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

/// Nanoseconds as fixed-point microseconds (`ts`/`dur` are in us in the
/// trace-event format); integer math keeps the output byte-stable.
fn fmt_us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Minimal JSON string escaper (quotes, backslashes, control chars).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::Ns;
    use crate::trace::{SpanRec, Stamp};
    use std::time::{Duration, Instant};

    #[test]
    fn fmt_us_is_fixed_point() {
        assert_eq!(fmt_us(0), "0.000");
        assert_eq!(fmt_us(999), "0.999");
        assert_eq!(fmt_us(1_000), "1.000");
        assert_eq!(fmt_us(1_234_567), "1234.567");
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape("x\ny\u{1}"), "x\\ny\\u0001");
    }

    #[test]
    fn tracks_intern_by_name() {
        let mut t = ChromeTrace::new();
        let a = t.track("lane/m0");
        let b = t.track("lane/m1");
        let a2 = t.track("lane/m0");
        assert_eq!(a, a2);
        assert_ne!(a, b);
    }

    #[test]
    fn stage_tiles_cover_total_and_validate() {
        let base = Instant::now();
        let mut span = SpanRec::begin_at(base);
        for (stamp, off) in [
            (Stamp::RecvDone, 1_000u64),
            (Stamp::Dispatch, 2_000),
            (Stamp::InferDone, 5_000),
            (Stamp::ReplySend, 6_000),
        ] {
            span.mark_at(stamp, base + Duration::from_nanos(off));
        }
        let block = SpanBlock::of(&span);
        let mut t = ChromeTrace::new();
        let track = t.track("ring/tcp/c0");
        t.block(track, 500, &block, 8_000, &[("req", ArgVal::U64(0))]);
        // nine tiles, ending exactly at start + total
        assert_eq!(t.len(), crate::trace::N_STAGES);
        let last = t.events.last().unwrap();
        assert_eq!(last.ts_ns + last.dur_ns, 500 + 8_000);
        t.validate().unwrap();
        // every stage name serialized
        let json = t.to_json();
        for s in Stage::ALL {
            assert!(json.contains(s.name()), "missing {}", s.name());
        }
    }

    #[test]
    fn record_tiles_match_stage_order() {
        let r = ReqRecord {
            request: Ns(1_000),
            lane_queue: Ns(500),
            gather_wait: Ns(250),
            dispatch_wait: Ns(250),
            infer: Ns(2_000),
            response: Ns(1_000),
            total: Ns(5_000),
            ..Default::default()
        };
        let mut t = ChromeTrace::new();
        let track = t.track("sim/c0");
        t.record(track, 0, &r, &[]);
        assert_eq!(t.len(), crate::trace::N_STAGES);
        let last = t.events.last().unwrap();
        assert_eq!(last.ts_ns + last.dur_ns, 5_000);
        t.validate().unwrap();
    }

    #[test]
    fn counter_events_pin_json_and_skip_validation() {
        let mut t = ChromeTrace::new();
        let track = t.track("counters/batch");
        t.counter(track, "accel_queue_depth", 1_500, 4);
        t.counter(track, "accel_queue_depth", 2_500, 2);
        // Counters are instants: two at ascending ts validate even with
        // a complete tile spanning them.
        t.event(track, "infer", "stage", 0, 10_000, &[]);
        t.validate().unwrap();
        let json = t.to_json();
        assert!(json.contains(
            r#"{"name":"accel_queue_depth","cat":"counter","ph":"C","ts":1.500,"pid":1,"tid":0,"args":{"value":4}}"#
        ));
        assert!(json.contains(
            r#"{"name":"accel_queue_depth","cat":"counter","ph":"C","ts":2.500,"pid":1,"tid":0,"args":{"value":2}}"#
        ));
        assert_eq!(json, t.to_json(), "deterministic");
    }

    #[test]
    fn flow_pair_pins_json_and_links_tracks() {
        let mut t = ChromeTrace::new();
        let gw = t.track("gateway/pipe");
        let be = t.track("backend/m0");
        t.event(gw, "stage0", "stage", 0, 1_000, &[]);
        t.flow_start(gw, "req0", 500, 7);
        t.event(be, "infer", "stage", 600, 300, &[]);
        t.flow_finish(be, "req0", 700, 7);
        t.validate().unwrap();
        let json = t.to_json();
        assert!(json.contains(
            r#"{"name":"req0","cat":"flow","ph":"s","ts":0.500,"pid":1,"tid":0,"id":7,"args":{}}"#
        ));
        assert!(json.contains(
            r#"{"name":"req0","cat":"flow","ph":"f","bp":"e","ts":0.700,"pid":1,"tid":1,"id":7,"args":{}}"#
        ));
    }

    #[test]
    fn validate_rejects_overlap() {
        let mut t = ChromeTrace::new();
        let track = t.track("x");
        t.event(track, "a", "stage", 0, 100, &[]);
        t.event(track, "b", "stage", 50, 10, &[]);
        assert!(t.validate().is_err());
    }

    #[test]
    fn json_shape_is_wellformed() {
        let mut t = ChromeTrace::new();
        let track = t.track("lane/\"odd\"");
        let args = [("req", ArgVal::U64(3))];
        t.event(track, "infer", "stage", 1_500, 250, &args);
        let json = t.to_json();
        assert!(json.starts_with("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n"));
        assert!(json.ends_with("\n]}\n"));
        assert!(json.contains(r#""ts":1.500,"dur":0.250"#));
        assert!(json.contains(r#"\"odd\""#));
        // balanced braces (no string content interferes after escaping)
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
    }
}
