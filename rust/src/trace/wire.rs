//! Wire encoding of a span timeline: the versioned span block a v2
//! response carries between the stage-timing header and the payload.
//!
//! ```text
//! [ver u8][count u8]([id u8][off_ns u64 LE]) * count
//! ```
//!
//! Stamps are encoded in strictly increasing id order, which makes the
//! block canonical, cheap to validate, and forward-compatible: a
//! decoder keeps ids it does not recognize (a newer server may stamp
//! finer events) but rejects structural damage — truncation, a bad
//! version, an oversized count, or out-of-order/duplicate ids.

use anyhow::{bail, Result};

use super::span::{SpanRec, Stamp};

/// Span block wire version.
pub const SPAN_VER: u8 = 1;

/// Upper bound on stamps per block (wire ids are one byte; 32 leaves
/// room for finer taxonomies without unbounded allocation).
pub const MAX_BLOCK_STAMPS: usize = 32;

/// Bytes one encoded stamp occupies.
const STAMP_BYTES: usize = 9;

/// A decoded span block: `(wire id, ns offset)` pairs in increasing id
/// order. Unknown ids are preserved (forward compatibility).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpanBlock {
    pub stamps: Vec<(u8, u64)>,
}

impl SpanBlock {
    /// The block form of a live span record. `SpanRec::stamps`
    /// iterates in wire-id order, so the result is canonical by
    /// construction.
    pub fn of(span: &SpanRec) -> SpanBlock {
        SpanBlock {
            stamps: span.stamps().map(|(s, off)| (s.id(), off)).collect(),
        }
    }

    /// Encode the block in its canonical byte form — the single
    /// byte-level encoder of the format ([`encode_span_block`] and the
    /// protocol's response encoding both route through here).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + self.stamps.len() * STAMP_BYTES);
        out.push(SPAN_VER);
        debug_assert!(self.stamps.len() <= MAX_BLOCK_STAMPS);
        out.push(self.stamps.len() as u8);
        for &(id, off) in &self.stamps {
            out.push(id);
            out.extend_from_slice(&off.to_le_bytes());
        }
        out
    }

    /// Offset of a known stamp, if present.
    pub fn get(&self, stamp: Stamp) -> Option<u64> {
        let id = stamp.id();
        self.stamps
            .iter()
            .find(|&&(i, _)| i == id)
            .map(|&(_, off)| off)
    }

    /// Number of stamps in the block.
    pub fn len(&self) -> usize {
        self.stamps.len()
    }

    /// True when the block carries no stamps.
    pub fn is_empty(&self) -> bool {
        self.stamps.is_empty()
    }
}

/// Encode a span record as a wire block (see the module docs) — the
/// same bytes the server emits for a v2 response ([`SpanBlock::of`] +
/// [`SpanBlock::encode`]).
pub fn encode_span_block(span: &SpanRec) -> Vec<u8> {
    SpanBlock::of(span).encode()
}

/// Decode a span block from the front of `buf`, returning the block
/// and the number of bytes consumed. Rejects truncated or malformed
/// blocks (see the module docs for what counts as malformed).
pub fn decode_span_block(buf: &[u8]) -> Result<(SpanBlock, usize)> {
    if buf.len() < 2 {
        bail!("span block truncated: {} bytes", buf.len());
    }
    if buf[0] != SPAN_VER {
        bail!("unknown span block version {}", buf[0]);
    }
    let count = buf[1] as usize;
    if count > MAX_BLOCK_STAMPS {
        bail!("span block claims {count} stamps (cap {MAX_BLOCK_STAMPS})");
    }
    let need = 2 + count * STAMP_BYTES;
    if buf.len() < need {
        bail!("span block truncated: {} of {need} bytes", buf.len());
    }
    let mut stamps = Vec::with_capacity(count);
    let mut prev_id: Option<u8> = None;
    for k in 0..count {
        let at = 2 + k * STAMP_BYTES;
        let id = buf[at];
        if prev_id.is_some_and(|p| id <= p) {
            bail!("span block ids not strictly increasing at stamp {k}");
        }
        prev_id = Some(id);
        let off = u64::from_le_bytes(
            buf[at + 1..at + STAMP_BYTES].try_into().expect("8 bytes"),
        );
        stamps.push((id, off));
    }
    Ok((SpanBlock { stamps }, need))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    fn sample_span() -> SpanRec {
        let base = Instant::now();
        let mut s = SpanRec::begin_at(base);
        for (stamp, ns) in [
            (Stamp::RecvDone, 1_000u64),
            (Stamp::Enqueue, 2_000),
            (Stamp::Seal, 5_000),
            (Stamp::Dispatch, 6_000),
            (Stamp::InferDone, 50_000),
            (Stamp::ReplySend, 60_000),
        ] {
            s.mark_at(stamp, base + Duration::from_nanos(ns));
        }
        s
    }

    #[test]
    fn roundtrip() {
        let span = sample_span();
        let wire = encode_span_block(&span);
        let (block, used) = decode_span_block(&wire).unwrap();
        assert_eq!(used, wire.len());
        assert_eq!(block.len(), span.len());
        for (stamp, off) in span.stamps() {
            assert_eq!(block.get(stamp), Some(off), "{}", stamp.name());
        }
        assert_eq!(block.get(Stamp::PreprocDone), None);
    }

    #[test]
    fn decode_consumes_only_the_block() {
        let mut wire = encode_span_block(&sample_span());
        let block_len = wire.len();
        wire.extend_from_slice(&[0xAB; 100]); // trailing payload
        let (_, used) = decode_span_block(&wire).unwrap();
        assert_eq!(used, block_len);
    }

    #[test]
    fn rejects_truncation_at_every_length() {
        let wire = encode_span_block(&sample_span());
        for cut in 0..wire.len() {
            assert!(
                decode_span_block(&wire[..cut]).is_err(),
                "decoded a {cut}-byte prefix of a {}-byte block",
                wire.len()
            );
        }
    }

    #[test]
    fn rejects_malformed_blocks() {
        // Bad version.
        let mut bad_ver = encode_span_block(&sample_span());
        bad_ver[0] = 99;
        assert!(decode_span_block(&bad_ver).is_err());
        // Count beyond the cap.
        let huge = [SPAN_VER, (MAX_BLOCK_STAMPS + 1) as u8];
        assert!(decode_span_block(&huge).is_err());
        // Duplicate / out-of-order ids.
        let mut dup = vec![SPAN_VER, 2];
        for id in [3u8, 3u8] {
            dup.push(id);
            dup.extend_from_slice(&7u64.to_le_bytes());
        }
        assert!(decode_span_block(&dup).is_err());
        let mut rev = vec![SPAN_VER, 2];
        for id in [5u8, 2u8] {
            rev.push(id);
            rev.extend_from_slice(&7u64.to_le_bytes());
        }
        assert!(decode_span_block(&rev).is_err());
    }

    #[test]
    fn keeps_unknown_ids() {
        // A future server stamping id 31 still decodes.
        let mut wire = vec![SPAN_VER, 1, 31];
        wire.extend_from_slice(&42u64.to_le_bytes());
        let (block, _) = decode_span_block(&wire).unwrap();
        assert_eq!(block.stamps, vec![(31, 42)]);
    }

    #[test]
    fn empty_block_roundtrips() {
        let (block, used) = decode_span_block(&[SPAN_VER, 0]).unwrap();
        assert!(block.is_empty());
        assert_eq!(used, 2);
    }
}
