//! Collapsing a span timeline onto the nine-stage taxonomy.
//!
//! A [`StageBreakdown`] assigns every consecutive stamp interval of a
//! decoded span block to one [`Stage`], plus the client-side network
//! share (client-observed total minus the server span, split evenly
//! between the request and response paths — the paper's ZeroMQ
//! accounting, §III-B). Missing stamps inherit the previous stamp's
//! offset, so an absent stage (e.g. preproc for preprocessed inputs)
//! contributes exactly zero and the components always sum to the
//! client-observed total.

use crate::metrics::stats::Series;

use super::span::Stamp;
use super::wire::SpanBlock;
use super::{Stage, N_STAGES};

/// Per-request stage durations (ns), indexed by [`Stage`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageBreakdown {
    ns: [u64; N_STAGES],
}

impl StageBreakdown {
    /// Derive the breakdown from a server span block and the
    /// client-observed end-to-end latency. With monotone stamps the
    /// stage components sum to `total_ns` exactly.
    pub fn from_span(span: &SpanBlock, total_ns: u64) -> StageBreakdown {
        // Fall-forward chain: a missing stamp inherits its predecessor,
        // so the interval it would bound contributes zero.
        let ring = span.get(Stamp::RecvRing).unwrap_or(0);
        let recv_done = span.get(Stamp::RecvDone).unwrap_or(ring).max(ring);
        let mut prev = recv_done;
        let mut at = |s: Stamp| {
            prev = span.get(s).unwrap_or(prev).max(prev);
            prev
        };
        let gather = at(Stamp::Enqueue).max(recv_done); // enqueue folds into lane-queue
        let gather = at(Stamp::GatherStart).max(gather);
        let seal = at(Stamp::Seal);
        let dispatch = at(Stamp::Dispatch);
        let h2d = at(Stamp::H2dDone);
        let pre = at(Stamp::PreprocDone);
        let infer = at(Stamp::InferDone);
        let d2h = at(Stamp::D2hDone);
        let reply = at(Stamp::ReplySend);

        let server_span = reply.saturating_sub(ring);
        let net = total_ns.saturating_sub(server_span);
        let mut ns = [0u64; N_STAGES];
        ns[Stage::RequestXfer.idx()] = net / 2 + (recv_done - ring);
        ns[Stage::LaneQueue.idx()] = gather - recv_done;
        ns[Stage::GatherWait.idx()] = seal - gather;
        ns[Stage::DispatchWait.idx()] = dispatch - seal;
        ns[Stage::CopyH2d.idx()] = h2d - dispatch;
        ns[Stage::Preproc.idx()] = pre - h2d;
        ns[Stage::Infer.idx()] = infer - pre;
        ns[Stage::CopyD2h.idx()] = d2h - infer;
        ns[Stage::ResponseXfer.idx()] = (reply - d2h) + (net - net / 2);
        StageBreakdown { ns }
    }

    /// Duration of one stage, ns.
    pub fn get(&self, stage: Stage) -> u64 {
        self.ns[stage.idx()]
    }

    /// Sum of all stage components, ns (equals the client total when
    /// the span stamps were monotone).
    pub fn sum(&self) -> u64 {
        self.ns.iter().sum()
    }
}

/// Streaming aggregate of stage breakdowns over a run: one
/// [`Series`] (ms domain) per stage plus the end-to-end total —
/// the live-plane twin of the sim's `StageAgg`.
#[derive(Debug, Clone, Default)]
pub struct BreakdownAgg {
    stages: [Series; N_STAGES],
    /// Client-observed end-to-end latency.
    pub total: Series,
}

impl BreakdownAgg {
    pub fn new() -> BreakdownAgg {
        BreakdownAgg::default()
    }

    /// Record one request's breakdown and its end-to-end total (ns).
    pub fn push(&mut self, b: &StageBreakdown, total_ns: u64) {
        for s in Stage::ALL {
            self.stages[s.idx()].push(b.get(s) as f64 / 1e6);
        }
        self.total.push(total_ns as f64 / 1e6);
    }

    /// The per-stage series.
    pub fn stage(&self, s: Stage) -> &Series {
        &self.stages[s.idx()]
    }

    /// Number of recorded requests.
    pub fn n(&self) -> usize {
        self.total.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::SpanRec;
    use crate::trace::wire::{decode_span_block, encode_span_block};
    use std::time::{Duration, Instant};

    fn block(stamps: &[(Stamp, u64)]) -> SpanBlock {
        let base = Instant::now();
        let mut s = SpanRec::begin_at(base);
        for &(stamp, ns) in stamps {
            s.mark_at(stamp, base + Duration::from_nanos(ns));
        }
        decode_span_block(&encode_span_block(&s)).unwrap().0
    }

    #[test]
    fn full_span_partitions_total_exactly() {
        let b = block(&[
            (Stamp::RecvDone, 100),
            (Stamp::Enqueue, 120),
            (Stamp::GatherStart, 500),
            (Stamp::Seal, 900),
            (Stamp::Dispatch, 1_000),
            (Stamp::H2dDone, 1_400),
            (Stamp::PreprocDone, 2_000),
            (Stamp::InferDone, 9_000),
            (Stamp::D2hDone, 9_300),
            (Stamp::ReplySend, 9_500),
        ]);
        let total = 12_000u64; // 2_500 ns of wire
        let d = StageBreakdown::from_span(&b, total);
        assert_eq!(d.sum(), total);
        assert_eq!(d.get(Stage::RequestXfer), 1_250 + 100);
        assert_eq!(d.get(Stage::LaneQueue), 400); // 100 -> 500 (enqueue folded)
        assert_eq!(d.get(Stage::GatherWait), 400);
        assert_eq!(d.get(Stage::DispatchWait), 100);
        assert_eq!(d.get(Stage::CopyH2d), 400);
        assert_eq!(d.get(Stage::Preproc), 600);
        assert_eq!(d.get(Stage::Infer), 7_000);
        assert_eq!(d.get(Stage::CopyD2h), 300);
        assert_eq!(d.get(Stage::ResponseXfer), 200 + 1_250);
    }

    #[test]
    fn missing_stamps_contribute_zero() {
        // No preproc (preprocessed input), no gather detail.
        let b = block(&[
            (Stamp::RecvDone, 100),
            (Stamp::Enqueue, 150),
            (Stamp::Dispatch, 1_000),
            (Stamp::InferDone, 5_000),
            (Stamp::ReplySend, 5_200),
        ]);
        let d = StageBreakdown::from_span(&b, 6_000);
        assert_eq!(d.sum(), 6_000);
        assert_eq!(d.get(Stage::Preproc), 0);
        assert_eq!(d.get(Stage::CopyH2d), 0);
        // Missing gather/seal fall forward to the enqueue stamp, so
        // the enqueue->dispatch gap lands in dispatch-wait.
        assert_eq!(d.get(Stage::LaneQueue), 50);
        assert_eq!(d.get(Stage::GatherWait), 0);
        assert_eq!(d.get(Stage::DispatchWait), 850);
        assert_eq!(d.get(Stage::Infer), 4_000);
    }

    #[test]
    fn server_span_longer_than_total_never_negative() {
        // A clock oddity where the client total undercuts the server
        // span must clamp the net share, not underflow.
        let b = block(&[(Stamp::RecvDone, 10), (Stamp::ReplySend, 10_000)]);
        let d = StageBreakdown::from_span(&b, 5_000);
        assert_eq!(d.get(Stage::RequestXfer), 10);
        assert!(d.sum() >= 10_000);
    }

    #[test]
    fn agg_accumulates_additively() {
        let b = block(&[
            (Stamp::RecvDone, 100),
            (Stamp::InferDone, 900),
            (Stamp::ReplySend, 1_000),
        ]);
        let d = StageBreakdown::from_span(&b, 2_000);
        let mut a = BreakdownAgg::new();
        for _ in 0..3 {
            a.push(&d, 2_000);
        }
        assert_eq!(a.n(), 3);
        assert!((a.total.mean() - 2e-3).abs() < 1e-12);
        // Stage means stay additive over the aggregate: they sum to
        // the end-to-end mean (the stagebreak table's invariant).
        let sum: f64 = Stage::ALL.iter().map(|&s| a.stage(s).mean()).sum();
        assert!((sum - a.total.mean()).abs() < 1e-9, "{sum}");
    }
}
