//! Prometheus text exposition for telemetry [`Snapshot`]s.
//!
//! Series names in the registry already embed their labels
//! (`accel_seal_total{reason="full"}`), so rendering splits each name
//! into `(family, labels)` at the first `{` and emits the standard
//! `# HELP` / `# TYPE` header once per family. Histograms expand into
//! the conventional `_bucket{le=…}` / `_sum` / `_count` series; only
//! buckets that change the cumulative count are listed (plus the
//! mandatory `le="+Inf"`), which keeps the output compact while
//! remaining a valid cumulative histogram. All values are integers and
//! inputs arrive sorted by name, so the rendering is byte-deterministic.

use crate::metrics::telemetry::{HistoSnap, Snapshot, BUCKET_BOUNDS, N_BUCKETS};

/// Split a registry series name into its family and label body:
/// `a_total{x="y"}` → `("a_total", "x=\"y\"")`; unlabeled names get an
/// empty label body.
pub fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i + 1..].trim_end_matches('}')),
        None => (name, ""),
    }
}

/// Re-attach a label body, optionally appending one extra label.
fn series(family: &str, labels: &str, extra: Option<(&str, &str)>) -> String {
    match (labels.is_empty(), extra) {
        (true, None) => family.to_string(),
        (true, Some((k, v))) => format!("{family}{{{k}=\"{v}\"}}"),
        (false, None) => format!("{family}{{{labels}}}"),
        (false, Some((k, v))) => format!("{family}{{{labels},{k}=\"{v}\"}}"),
    }
}

fn help_for(family: &str) -> &'static str {
    match family {
        "accel_jobs_total" => "Jobs executed by the local executor.",
        "accel_batches_total" => "Engine calls (sealed batches) executed.",
        "accel_interleaves_total" => "Cross-model interleaves observed by the scheduler.",
        "accel_seal_total" => "Batches sealed, by seal reason.",
        "accel_shed_total" => "Requests shed at admission, by reason.",
        "accel_credit_grants_total" => "Credit envelopes granted to clients.",
        "accel_credit_tokens_total" => "Credit tokens granted to clients.",
        "accel_queue_depth" => "Jobs currently queued across all lanes.",
        "accel_batch_size" => "Executed chunk size in jobs.",
        "accel_svc_ns" => "Engine service time per call, ns.",
        "accel_stage_ns" => "Executor pipeline stage latency, ns, by stage.",
        "accel_exec_ns" => "Enqueue-to-device-done latency, ns, by model.",
        _ => "accelserve telemetry series.",
    }
}

fn push_header(out: &mut String, done: &mut Vec<String>, family: &str, kind: &str) {
    if done.iter().any(|f| f == family) {
        return;
    }
    out.push_str(&format!("# HELP {family} {}\n", help_for(family)));
    out.push_str(&format!("# TYPE {family} {kind}\n"));
    done.push(family.to_string());
}

fn push_histo(out: &mut String, name: &str, h: &HistoSnap) {
    let (family, labels) = split_labels(name);
    let bucket_family = format!("{family}_bucket");
    let mut cum = 0u64;
    for i in 0..N_BUCKETS {
        let c = h.buckets.get(i).copied().unwrap_or(0);
        if c == 0 {
            continue;
        }
        cum += c;
        if BUCKET_BOUNDS[i] == u64::MAX {
            // Counts landing in the catch-all are covered by +Inf below.
            continue;
        }
        let le = BUCKET_BOUNDS[i].to_string();
        out.push_str(&format!(
            "{} {}\n",
            series(&bucket_family, labels, Some(("le", &le))),
            cum
        ));
    }
    out.push_str(&format!(
        "{} {}\n",
        series(&bucket_family, labels, Some(("le", "+Inf"))),
        h.count
    ));
    out.push_str(&format!("{} {}\n", series(&format!("{family}_sum"), labels, None), h.sum));
    out.push_str(&format!(
        "{} {}\n",
        series(&format!("{family}_count"), labels, None),
        h.count
    ));
}

/// Render a snapshot in Prometheus text exposition format.
pub fn render(snap: &Snapshot) -> String {
    let mut out = String::new();
    let mut done: Vec<String> = Vec::new();
    for (name, v) in &snap.counters {
        let (family, labels) = split_labels(name);
        push_header(&mut out, &mut done, family, "counter");
        out.push_str(&format!("{} {}\n", series(family, labels, None), v));
    }
    for (name, v) in &snap.gauges {
        let (family, labels) = split_labels(name);
        push_header(&mut out, &mut done, family, "gauge");
        out.push_str(&format!("{} {}\n", series(family, labels, None), v));
    }
    for (name, h) in &snap.histos {
        let (family, _) = split_labels(name);
        push_header(&mut out, &mut done, family, "histogram");
        push_histo(&mut out, name, h);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::telemetry::{labeled, Registry};

    #[test]
    fn split_labels_round_trips() {
        assert_eq!(split_labels("a_total"), ("a_total", ""));
        assert_eq!(
            split_labels(&labeled("a_total", "k", "v")),
            ("a_total", "k=\"v\"")
        );
    }

    #[test]
    fn render_emits_headers_once_per_family_and_valid_lines() {
        let reg = Registry::new();
        reg.counter(&labeled("accel_seal_total", "reason", "full")).add(3);
        reg.counter(&labeled("accel_seal_total", "reason", "flush")).add(1);
        reg.gauge("accel_queue_depth").set(7);
        let h = reg.histo("accel_svc_ns");
        h.observe(1);
        h.observe(100);
        h.observe(100);
        let text = render(&reg.snapshot());

        assert_eq!(text.matches("# TYPE accel_seal_total counter").count(), 1);
        assert!(text.contains("accel_seal_total{reason=\"flush\"} 1\n"));
        assert!(text.contains("accel_seal_total{reason=\"full\"} 3\n"));
        assert!(text.contains("# TYPE accel_queue_depth gauge"));
        assert!(text.contains("accel_queue_depth 7\n"));
        assert!(text.contains("# TYPE accel_svc_ns histogram"));
        assert!(text.contains("accel_svc_ns_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("accel_svc_ns_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("accel_svc_ns_sum 201\n"));
        assert!(text.contains("accel_svc_ns_count 3\n"));

        // Cumulative bucket counts must be non-decreasing.
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.starts_with("accel_svc_ns_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "cumulative must not decrease: {line}");
            prev = v;
        }

        // Every line is a header or `name[{labels}] value` — the same
        // shape the CI checker pins.
        for line in text.lines() {
            if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("series line");
            assert!(value.parse::<u64>().is_ok(), "integer value: {line}");
            assert!(
                series
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_alphabetic() || c == '_')
                    .unwrap_or(false),
                "series name: {line}"
            );
        }

        // Deterministic: rendering the same snapshot twice is identical.
        assert_eq!(text, render(&reg.snapshot()));
    }
}
