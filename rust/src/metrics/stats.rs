//! Per-request records and streaming aggregation (mean / percentiles /
//! CoV) for the Table I metrics.

use crate::sim::time::Ns;

/// Fine-grained latency breakdown of one model-serving request, the
/// direct analogue of the CUDA-event/WR-timestamp profiling in §III-B.
/// All stage durations include the queueing the request experienced in
/// that stage (exactly as bracketing events would measure).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReqRecord {
    pub client: usize,
    /// End-to-end model-serving latency.
    pub total: Ns,
    /// Client-to-server transport (incl. gateway hops in proxied mode).
    pub request: Ns,
    /// Server-to-client transport.
    pub response: Ns,
    /// Waiting in the model lane before the scheduler first considered
    /// the request for a gather (zero when the lane model is off).
    pub lane_queue: Ns,
    /// Waiting while the request's batch gathered peers (flush window).
    pub gather_wait: Ns,
    /// Sealed batch waiting for an execution stream.
    pub dispatch_wait: Ns,
    /// Host-to-device staging copy (zero for GDR/local).
    pub copy_h2d: Ns,
    /// Device-to-host staging copy (zero for GDR/local).
    pub copy_d2h: Ns,
    /// GPU preprocessing stage (zero when serving preprocessed tensors).
    pub preproc: Ns,
    /// GPU inference stage (incl. stream-slot queueing).
    pub infer: Ns,
    /// CPU time consumed serving this request (client+gateway+server).
    pub cpu_us: f64,
    /// High-priority client flag (Fig 16).
    pub priority: bool,
}

impl ReqRecord {
    /// copy-time of Table I: H2D + D2H.
    pub fn copy(&self) -> Ns {
        self.copy_h2d + self.copy_d2h
    }

    /// GPU processing time (preprocessing + inference), the quantity
    /// whose CoV Fig 15(c) reports.
    pub fn processing(&self) -> Ns {
        self.preproc + self.infer
    }

    /// Total data-movement time (copy + request + response), the
    /// "communication fraction" of Fig 8.
    pub fn data_movement(&self) -> Ns {
        self.copy() + self.request + self.response
    }
}

/// Streaming aggregate over a set of duration samples (ms domain).
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
    sorted: bool,
}

impl Series {
    pub fn new() -> Series {
        Series::default()
    }

    pub fn push(&mut self, v_ms: f64) {
        self.samples.push(v_ms);
        self.sorted = false;
    }

    pub fn push_ns(&mut self, v: Ns) {
        self.push(v.as_ms());
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64)
            .sqrt()
    }

    /// Coefficient of variation sigma/mu (Fig 15c).
    pub fn cov(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.std() / m
        }
    }

    /// Quantile in [0, 1] by nearest-rank on the sorted samples.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            // total_cmp is a total order over every f64 bit pattern, so
            // a stray NaN sample sorts (to the top) instead of panicking
            // the whole sweep mid-report.
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
        let idx = ((self.samples.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        self.samples[idx]
    }

    pub fn min(&mut self) -> f64 {
        self.quantile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.quantile(1.0)
    }

    /// One-shot reporting summary (n / mean / p50 / p99) computed on a
    /// working copy, so a shared aggregate needs no `&mut` clone dance.
    /// Every experiment table reports latency through this, keeping the
    /// live and sim planes' percentile math identical by construction.
    pub fn summary(&self) -> Summary {
        let mut s = self.clone();
        Summary {
            n: s.len(),
            mean: s.mean(),
            p50: s.quantile(0.5),
            p99: s.quantile(0.99),
        }
    }

    /// The statistic `stat` of this series (table-column dispatch).
    pub fn stat(&self, stat: Stat) -> f64 {
        match stat {
            Stat::Mean => self.mean(),
            Stat::P50 | Stat::P99 => self.summary().get(stat),
        }
    }
}

/// Which statistic a report column shows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stat {
    Mean,
    P50,
    P99,
}

impl Stat {
    /// Parse a CLI spec: `mean`, `p50`/`50`, `p99`/`99`.
    pub fn by_name(s: &str) -> Option<Stat> {
        match s.to_ascii_lowercase().as_str() {
            "mean" | "avg" => Some(Stat::Mean),
            "p50" | "50" | "median" => Some(Stat::P50),
            "p99" | "99" => Some(Stat::P99),
            _ => None,
        }
    }

    /// Label for table titles.
    pub fn name(self) -> &'static str {
        match self {
            Stat::Mean => "mean",
            Stat::P50 => "p50",
            Stat::P99 => "p99",
        }
    }
}

/// The standard reporting summary of one [`Series`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
}

impl Summary {
    /// Field selector by [`Stat`] (lets table code compute one summary
    /// and read several statistics from it).
    pub fn get(&self, stat: Stat) -> f64 {
        match stat {
            Stat::Mean => self.mean,
            Stat::P50 => self.p50,
            Stat::P99 => self.p99,
        }
    }
}

/// Aggregated per-stage breakdown over a run (the Fig 6/8/12/13 rows).
#[derive(Debug, Clone, Default)]
pub struct StageAgg {
    pub total: Series,
    pub request: Series,
    pub response: Series,
    pub lane_queue: Series,
    pub gather_wait: Series,
    pub dispatch_wait: Series,
    pub copy_h2d: Series,
    pub copy_d2h: Series,
    pub preproc: Series,
    pub infer: Series,
    pub processing: Series,
    pub cpu_us: Series,
}

impl StageAgg {
    pub fn new() -> StageAgg {
        StageAgg::default()
    }

    pub fn push(&mut self, r: &ReqRecord) {
        self.total.push_ns(r.total);
        self.request.push_ns(r.request);
        self.response.push_ns(r.response);
        self.lane_queue.push_ns(r.lane_queue);
        self.gather_wait.push_ns(r.gather_wait);
        self.dispatch_wait.push_ns(r.dispatch_wait);
        self.copy_h2d.push_ns(r.copy_h2d);
        self.copy_d2h.push_ns(r.copy_d2h);
        self.preproc.push_ns(r.preproc);
        self.infer.push_ns(r.infer);
        self.processing.push_ns(r.processing());
        self.cpu_us.push(r.cpu_us);
    }

    pub fn n(&self) -> usize {
        self.total.len()
    }

    /// Mean copy-time (H2D + D2H), ms.
    pub fn copy_mean(&self) -> f64 {
        self.copy_h2d.mean() + self.copy_d2h.mean()
    }

    /// Mean data-movement time (Fig 8's communication share), ms.
    pub fn data_movement_mean(&self) -> f64 {
        self.copy_mean() + self.request.mean() + self.response.mean()
    }

    /// Fraction of mean total time spent in each stage:
    /// (request+response, copy, preproc+infer). Sums to ~1.
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total.mean();
        if t == 0.0 {
            return (0.0, 0.0, 0.0);
        }
        let net = (self.request.mean() + self.response.mean()) / t;
        let copy = self.copy_mean() / t;
        let proc = (self.preproc.mean() + self.infer.mean()) / t;
        (net, copy, proc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(total_ms: f64) -> ReqRecord {
        ReqRecord {
            total: Ns::from_ms(total_ms),
            request: Ns::from_ms(total_ms * 0.1),
            response: Ns::from_ms(total_ms * 0.1),
            copy_h2d: Ns::from_ms(total_ms * 0.05),
            copy_d2h: Ns::from_ms(total_ms * 0.05),
            preproc: Ns::from_ms(total_ms * 0.1),
            infer: Ns::from_ms(total_ms * 0.6),
            cpu_us: total_ms * 100.0,
            ..Default::default()
        }
    }

    #[test]
    fn series_moments() {
        let mut s = Series::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
        assert!((s.cov() - 0.527).abs() < 1e-2);
        assert_eq!(s.quantile(0.5), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn series_empty_and_single() {
        let mut s = Series::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.quantile(0.5), 0.0);
        s.push(7.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.cov(), 0.0);
        assert_eq!(s.quantile(0.99), 7.0);
    }

    #[test]
    fn quantile_bounds_property() {
        // For any sample set and q, min <= quantile(q) <= max, monotone in q.
        let mut rng = crate::sim::rng::Rng::new(5);
        for _ in 0..50 {
            let mut s = Series::new();
            let n = 1 + rng.below(200);
            for _ in 0..n {
                s.push(rng.uniform(-100.0, 100.0));
            }
            let mut prev = f64::NEG_INFINITY;
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let v = s.quantile(q);
                assert!(v >= prev - 1e-12);
                prev = v;
            }
            let lo = s.min();
            let hi = s.max();
            assert!(lo <= hi);
        }
    }

    #[test]
    fn quantile_survives_nan_samples() {
        // A single NaN sample must not panic the sort; real samples
        // stay ordered beneath it (total_cmp puts NaN above +inf).
        let mut s = Series::new();
        for v in [3.0, f64::NAN, 1.0, 2.0] {
            s.push(v);
        }
        assert_eq!(s.quantile(0.0), 1.0);
        // idx = round((4-1)*0.5) = 2 over sorted [1, 2, 3, NaN].
        assert_eq!(s.quantile(0.5), 3.0);
        assert!(s.quantile(1.0).is_nan());
        // All-NaN input is equally panic-free.
        let mut all_nan = Series::new();
        all_nan.push(f64::NAN);
        assert!(all_nan.quantile(0.5).is_nan());
    }

    #[test]
    fn summary_matches_direct_quantiles() {
        let mut s = Series::new();
        for v in [5.0, 1.0, 4.0, 2.0, 3.0] {
            s.push(v);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 5);
        assert_eq!(sum.mean, 3.0);
        assert_eq!(sum.p50, s.quantile(0.5));
        assert_eq!(sum.p99, s.quantile(0.99));
        assert_eq!(s.stat(Stat::Mean), 3.0);
        assert_eq!(s.stat(Stat::P50), sum.p50);
        assert_eq!(s.stat(Stat::P99), sum.p99);
    }

    #[test]
    fn stat_parses_cli_specs() {
        assert_eq!(Stat::by_name("mean"), Some(Stat::Mean));
        assert_eq!(Stat::by_name("P50"), Some(Stat::P50));
        assert_eq!(Stat::by_name("99"), Some(Stat::P99));
        assert_eq!(Stat::by_name("p75"), None);
        assert_eq!(Stat::P99.name(), "p99");
    }

    #[test]
    fn record_derived_metrics() {
        let r = rec(10.0);
        assert!((r.copy().as_ms() - 1.0).abs() < 1e-9);
        assert!((r.processing().as_ms() - 7.0).abs() < 1e-9);
        assert!((r.data_movement().as_ms() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn stage_fractions_sum_to_one() {
        let mut agg = StageAgg::new();
        for i in 0..100 {
            agg.push(&rec(5.0 + i as f64 * 0.1));
        }
        let (net, copy, proc) = agg.fractions();
        assert!(((net + copy + proc) - 1.0).abs() < 1e-6);
        assert!(proc > net && proc > copy);
    }
}
