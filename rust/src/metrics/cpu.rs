//! CPU and memory usage sampling for the live plane, via the Linux
//! /proc filesystem (the paper's §III-B uses /proc plus nvidia-smi).

use std::fs;
use std::time::Instant;

/// One CPU-time sample of the current process (user+system jiffies).
#[derive(Debug, Clone, Copy)]
pub struct CpuSample {
    pub utime_ticks: u64,
    pub stime_ticks: u64,
    pub wall: Instant,
}

/// RSS memory sample, bytes.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemSample {
    pub rss_bytes: u64,
    pub vsz_bytes: u64,
}

/// Reads /proc/self/stat. Returns None off-Linux or on parse failure.
pub fn sample_cpu() -> Option<CpuSample> {
    let stat = fs::read_to_string("/proc/self/stat").ok()?;
    // Field 14 = utime, 15 = stime (1-indexed, after the comm field which
    // may contain spaces — skip past the closing paren).
    let rest = stat.rsplit_once(')')?.1;
    let fields: Vec<&str> = rest.split_whitespace().collect();
    Some(CpuSample {
        utime_ticks: fields.get(11)?.parse().ok()?,
        stime_ticks: fields.get(12)?.parse().ok()?,
        wall: Instant::now(),
    })
}

/// Reads /proc/self/statm.
pub fn sample_mem() -> Option<MemSample> {
    let statm = fs::read_to_string("/proc/self/statm").ok()?;
    let mut it = statm.split_whitespace();
    let page = 4096u64;
    let vsz: u64 = it.next()?.parse().ok()?;
    let rss: u64 = it.next()?.parse().ok()?;
    Some(MemSample {
        rss_bytes: rss * page,
        vsz_bytes: vsz * page,
    })
}

/// CPU seconds burned between two samples (user + system).
pub fn cpu_secs_between(a: &CpuSample, b: &CpuSample) -> f64 {
    let hz = ticks_per_second();
    let du = b.utime_ticks.saturating_sub(a.utime_ticks);
    let ds = b.stime_ticks.saturating_sub(a.stime_ticks);
    (du + ds) as f64 / hz
}

fn ticks_per_second() -> f64 {
    // SC_CLK_TCK; 100 on every mainstream Linux.
    let v = unsafe { libc::sysconf(libc::_SC_CLK_TCK) };
    if v > 0 {
        v as f64
    } else {
        100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_sampling_works_on_linux() {
        let a = sample_cpu().expect("proc stat");
        // Burn a little CPU.
        let mut acc = 0u64;
        for i in 0..20_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let b = sample_cpu().expect("proc stat");
        let secs = cpu_secs_between(&a, &b);
        assert!(secs >= 0.0);
        assert!(b.utime_ticks >= a.utime_ticks);
    }

    #[test]
    fn mem_sampling_positive() {
        let m = sample_mem().expect("proc statm");
        assert!(m.rss_bytes > 1024 * 1024);
        assert!(m.vsz_bytes >= m.rss_bytes);
    }
}
