//! Performance metrics (Table I) and their aggregation.
//!
//! | Category  | Metric             | Where                         |
//! |-----------|--------------------|-------------------------------|
//! | —         | total-time         | `ReqRecord::total`            |
//! | Transport | request-time       | `ReqRecord::request`          |
//! | Transport | response-time      | `ReqRecord::response`         |
//! | GPU       | copy-time          | `ReqRecord::copy_h2d + d2h`   |
//! | GPU       | preprocessing-time | `ReqRecord::preproc`          |
//! | GPU       | inference-time     | `ReqRecord::infer`            |
//! | CPU       | cpu-usage          | `ReqRecord::cpu_us` / `cpu`   |
//! | Memory    | memory-usage       | `cpu::MemSample`              |

pub mod cpu;
pub mod expose;
pub mod stats;
pub mod telemetry;

pub use stats::{ReqRecord, Series, StageAgg};
pub use telemetry::{
    Counter, Gauge, Histo, HistoHandle, HistoSnap, MetricsReport, Registry, Sample, SampleRing,
    Sampler, Snapshot,
};
