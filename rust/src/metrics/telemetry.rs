//! Always-on telemetry plane: a lock-light registry of named counters,
//! gauges, and log-bucketed mergeable histograms, plus a background
//! sampler that turns the registry into a bounded ring of timestamped
//! deltas.
//!
//! Design constraints, in order:
//!
//! - **O(atomic add) per event.** Hot-path call sites resolve their
//!   [`Counter`]/[`Gauge`]/[`HistoHandle`] once at startup; recording
//!   an event is one or three `fetch_add`s on `Relaxed` atomics. The
//!   registry's interior mutex guards only the name→handle map and is
//!   taken on registration and snapshot, never per event.
//! - **Mergeable across a fleet.** Histograms use one fixed, global
//!   bucket layout ([`BUCKET_BOUNDS`]: ~1.25× growth per bucket), so
//!   merging snapshots from many backends is a bucket-wise add and a
//!   quantile read off the merged histogram has the same bounded
//!   relative error (one bucket width, ≤ 25%) as a local read.
//! - **No wall clock.** Sample timestamps are milliseconds since the
//!   sampler started (monotonic), which is all a counter track needs.
//!
//! Label convention: series names embed Prometheus-style labels
//! directly, e.g. `accel_seal_total{reason="full"}` — see [`labeled`].
//! The exposition layer ([`crate::metrics::expose`]) splits the family
//! name back out; nothing else needs a structured label model.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Number of histogram buckets, shared by every histogram in the
/// process and across the fleet (merge is index-wise).
pub const N_BUCKETS: usize = 128;

/// Upper bounds (inclusive) of the shared log-bucket layout. Bounds
/// grow by `max(prev + prev/4, prev + 1)` from 1, so consecutive
/// bounds differ by at most 25% once past the exact small-integer
/// range, and the last bucket is a `u64::MAX` catch-all. In
/// nanoseconds the layout spans 1 ns to ~45 min, which covers every
/// duration this crate measures.
pub const BUCKET_BOUNDS: [u64; N_BUCKETS] = bucket_bounds();

const fn bucket_bounds() -> [u64; N_BUCKETS] {
    let mut b = [0u64; N_BUCKETS];
    b[0] = 1;
    let mut i = 1;
    while i < N_BUCKETS - 1 {
        let prev = b[i - 1];
        let grown = prev + prev / 4;
        b[i] = if grown > prev { grown } else { prev + 1 };
        i += 1;
    }
    b[N_BUCKETS - 1] = u64::MAX;
    b
}

/// Index of the bucket whose range contains `v`.
pub fn bucket_idx(v: u64) -> usize {
    BUCKET_BOUNDS.partition_point(|&b| b < v).min(N_BUCKETS - 1)
}

/// Finite display value for a bucket's upper bound (the catch-all
/// bucket reports the largest finite bound).
fn finite_bound(i: usize) -> u64 {
    if BUCKET_BOUNDS[i] == u64::MAX {
        BUCKET_BOUNDS[N_BUCKETS - 2]
    } else {
        BUCKET_BOUNDS[i]
    }
}

/// Build a labeled series name, e.g.
/// `labeled("accel_seal_total", "reason", "full")` →
/// `accel_seal_total{reason="full"}`.
pub fn labeled(family: &str, key: &str, val: &str) -> String {
    format!("{family}{{{key}=\"{val}\"}}")
}

/// A live histogram: fixed log buckets of `Relaxed` atomics.
pub struct Histo {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histo {
    /// An empty histogram. Standalone use (sweep-side quantiles) as
    /// well as [`Registry::histo`] go through here.
    pub fn new() -> Histo {
        Histo {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation. Three relaxed atomic adds.
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_idx(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Copy the current state out (best-effort consistent: concurrent
    /// observes may be partially visible, which only shifts the
    /// snapshot boundary by a single event).
    pub fn snap(&self) -> HistoSnap {
        HistoSnap {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
        }
    }
}

impl Default for Histo {
    fn default() -> Histo {
        Histo::new()
    }
}

/// A point-in-time copy of a [`Histo`]; the unit that merges and
/// travels on the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistoSnap {
    /// Total observations.
    pub count: u64,
    /// Sum of observed values.
    pub sum: u64,
    /// Per-bucket counts; always [`N_BUCKETS`] long.
    pub buckets: Vec<u64>,
}

impl Default for HistoSnap {
    fn default() -> HistoSnap {
        HistoSnap {
            count: 0,
            sum: 0,
            buckets: vec![0; N_BUCKETS],
        }
    }
}

impl HistoSnap {
    /// Bucket-wise add. Because every histogram shares
    /// [`BUCKET_BOUNDS`], this is exact: merging fleet snapshots then
    /// reading a quantile equals reading the quantile of the union.
    pub fn merge(&mut self, other: &HistoSnap) {
        self.buckets.resize(N_BUCKETS, 0);
        for (i, &c) in other.buckets.iter().enumerate().take(N_BUCKETS) {
            self.buckets[i] += c;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Nearest-rank quantile (same rank convention as
    /// `Series::quantile`), reported as the upper bound of the bucket
    /// holding the ranked observation — an overestimate by at most one
    /// bucket width (≤ 25% relative).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                return finite_bound(i);
            }
        }
        finite_bound(N_BUCKETS - 1)
    }

    /// Mean of observed values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// True when nothing has been observed.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

/// Handle to a registered counter. Cheap to clone; all clones share
/// one atomic cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle to a registered gauge (an instantaneous level, e.g. queue
/// depth). Backed by a `u64`; `sub` saturates at zero so a transient
/// imbalance cannot wrap the exposition output.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the level.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the level by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Lower the level by `n`, saturating at zero.
    pub fn sub(&self, n: u64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    /// Current level.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Shared handle to a registered histogram.
pub type HistoHandle = Arc<Histo>;

/// The process-wide metric registry. Series are created on first use
/// and live forever; reads and writes after registration never touch
/// the registry lock.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histos: Mutex<BTreeMap<String, Arc<Histo>>>,
}

impl Registry {
    /// Fresh, empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut map = self.counters.lock().unwrap();
        Counter(Arc::clone(
            map.entry(name.to_string()).or_default(),
        ))
    }

    /// Get or create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut map = self.gauges.lock().unwrap();
        Gauge(Arc::clone(map.entry(name.to_string()).or_default()))
    }

    /// Get or create the histogram named `name`.
    pub fn histo(&self, name: &str) -> HistoHandle {
        let mut map = self.histos.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histo::new())),
        )
    }

    /// Copy every series out, sorted by name.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let histos = self
            .histos
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snap()))
            .collect();
        Snapshot {
            counters,
            gauges,
            histos,
        }
    }
}

/// A point-in-time copy of a [`Registry`]: every series, sorted by
/// name. The unit the wire carries and the gateway merges.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Snapshot {
    /// `(name, value)` counters, sorted by name.
    pub counters: Vec<(String, u64)>,
    /// `(name, level)` gauges, sorted by name.
    pub gauges: Vec<(String, u64)>,
    /// `(name, state)` histograms, sorted by name.
    pub histos: Vec<(String, HistoSnap)>,
}

fn merge_kv(dst: &mut Vec<(String, u64)>, src: &[(String, u64)]) {
    let mut out: Vec<(String, u64)> = Vec::with_capacity(dst.len() + src.len());
    let (mut i, mut j) = (0, 0);
    while i < dst.len() && j < src.len() {
        match dst[i].0.cmp(&src[j].0) {
            std::cmp::Ordering::Less => {
                out.push(dst[i].clone());
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(src[j].clone());
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push((dst[i].0.clone(), dst[i].1 + src[j].1));
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&dst[i..]);
    out.extend_from_slice(&src[j..]);
    *dst = out;
}

impl Snapshot {
    /// Merge another snapshot in: counters and gauges add by name
    /// (gauges add because fleet-wide depth is the sum of per-backend
    /// depths), histograms add bucket-wise. Associative and
    /// commutative, so fleet merge order does not matter.
    pub fn merge(&mut self, other: &Snapshot) {
        merge_kv(&mut self.counters, &other.counters);
        merge_kv(&mut self.gauges, &other.gauges);
        let mut out: Vec<(String, HistoSnap)> =
            Vec::with_capacity(self.histos.len() + other.histos.len());
        let (mut i, mut j) = (0, 0);
        while i < self.histos.len() && j < other.histos.len() {
            match self.histos[i].0.cmp(&other.histos[j].0) {
                std::cmp::Ordering::Less => {
                    out.push(self.histos[i].clone());
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(other.histos[j].clone());
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let mut h = self.histos[i].1.clone();
                    h.merge(&other.histos[j].1);
                    out.push((self.histos[i].0.clone(), h));
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&self.histos[i..]);
        out.extend_from_slice(&other.histos[j..]);
        self.histos = out;
    }

    /// Value of a counter, if present.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    }

    /// Level of a gauge, if present.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    }

    /// State of a histogram, if present.
    pub fn histo(&self, name: &str) -> Option<&HistoSnap> {
        self.histos
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, h)| h)
    }

    /// True when no series is present.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histos.is_empty()
    }
}

/// One sampler tick: counter **deltas** since the previous tick and
/// gauge **levels** at the tick, stamped with milliseconds since the
/// sampler started. The shape a timeline counter track wants.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Sample {
    /// Milliseconds since the sampler started.
    pub at_ms: u64,
    /// `(name, delta)` counter increments over the tick, sorted by name.
    pub counters: Vec<(String, u64)>,
    /// `(name, level)` gauge levels at the tick, sorted by name.
    pub gauges: Vec<(String, u64)>,
}

/// Bounded ring of [`Sample`]s with the previous-tick counter state
/// needed to compute deltas. Synchronous — the [`Sampler`] thread owns
/// one behind a mutex, and tests drive it directly.
pub struct SampleRing {
    cap: usize,
    prev: BTreeMap<String, u64>,
    ring: VecDeque<Sample>,
}

impl SampleRing {
    /// Ring holding at most `cap` samples (oldest evicted first).
    pub fn new(cap: usize) -> SampleRing {
        SampleRing {
            cap: cap.max(1),
            prev: BTreeMap::new(),
            ring: VecDeque::new(),
        }
    }

    /// Record one tick from a registry snapshot.
    pub fn push(&mut self, at_ms: u64, snap: &Snapshot) {
        let mut counters = Vec::with_capacity(snap.counters.len());
        for (name, v) in &snap.counters {
            let before = self.prev.get(name).copied().unwrap_or(0);
            counters.push((name.clone(), v.saturating_sub(before)));
            self.prev.insert(name.clone(), *v);
        }
        let sample = Sample {
            at_ms,
            counters,
            gauges: snap.gauges.clone(),
        };
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(sample);
    }

    /// Samples oldest-first.
    pub fn samples(&self) -> Vec<Sample> {
        self.ring.iter().cloned().collect()
    }

    /// Number of samples held.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// True when no sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
}

/// Default sampler period (ms) — `serve --sample-ms` overrides.
pub const DEFAULT_SAMPLE_MS: u64 = 100;

/// Default ring capacity: one minute of history at the default period.
pub const DEFAULT_RING_CAP: usize = 600;

struct SamplerInner {
    reg: Arc<Registry>,
    ring: Mutex<SampleRing>,
    stop: AtomicBool,
    started: Instant,
}

/// Background thread that snapshots a registry every `every_ms` into a
/// bounded [`SampleRing`]. Stops (and joins) on [`Sampler::stop`] or
/// drop.
pub struct Sampler {
    inner: Arc<SamplerInner>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Sampler {
    /// Start sampling `reg` every `every_ms` ms, keeping `cap` samples.
    pub fn start(reg: Arc<Registry>, every_ms: u64, cap: usize) -> Sampler {
        let inner = Arc::new(SamplerInner {
            reg,
            ring: Mutex::new(SampleRing::new(cap)),
            stop: AtomicBool::new(false),
            started: Instant::now(),
        });
        let th = Arc::clone(&inner);
        let every = Duration::from_millis(every_ms.max(5));
        let handle = thread::Builder::new()
            .name("telemetry-sampler".into())
            .spawn(move || {
                let slice = Duration::from_millis(5);
                let mut next = th.started + every;
                loop {
                    while Instant::now() < next {
                        if th.stop.load(Ordering::Relaxed) {
                            return;
                        }
                        thread::sleep(slice.min(next - Instant::now()));
                    }
                    if th.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    let snap = th.reg.snapshot();
                    let at_ms = th.started.elapsed().as_millis() as u64;
                    th.ring.lock().unwrap().push(at_ms, &snap);
                    next += every;
                }
            })
            .expect("spawn telemetry sampler");
        Sampler {
            inner,
            handle: Some(handle),
        }
    }

    /// Copy the sample ring out, oldest-first.
    pub fn ring(&self) -> Vec<Sample> {
        self.inner.ring.lock().unwrap().samples()
    }

    /// Milliseconds since the sampler started (the `at_ms` clock).
    pub fn elapsed_ms(&self) -> u64 {
        self.inner.started.elapsed().as_millis() as u64
    }

    /// Stop the thread and join it. Idempotent.
    pub fn stop(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop();
    }
}

/// What `OP_METRICS` serves: the current snapshot plus the sample
/// ring. A gateway-merged report carries an empty ring (per-backend
/// rings are on different clocks and do not merge meaningfully).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct MetricsReport {
    /// Current registry snapshot.
    pub snap: Snapshot,
    /// Sampler ring, oldest-first.
    pub ring: Vec<Sample>,
}

impl MetricsReport {
    /// Merge per-backend reports into one fleet report: snapshots
    /// merge series-wise ([`Snapshot::merge`]), the ring is dropped.
    pub fn merged<'a, I: IntoIterator<Item = &'a MetricsReport>>(reports: I) -> MetricsReport {
        let mut snap = Snapshot::default();
        for r in reports {
            snap.merge(&r.snap);
        }
        MetricsReport { snap, ring: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Series;

    #[test]
    fn bucket_bounds_are_strictly_increasing_and_bounded_growth() {
        for i in 1..N_BUCKETS - 1 {
            assert!(
                BUCKET_BOUNDS[i] > BUCKET_BOUNDS[i - 1],
                "bounds must increase at {i}"
            );
            // Growth never exceeds 25% + the integer-rounding unit.
            assert!(
                BUCKET_BOUNDS[i] <= BUCKET_BOUNDS[i - 1] + BUCKET_BOUNDS[i - 1] / 4 + 1,
                "growth too fast at {i}"
            );
        }
        assert_eq!(BUCKET_BOUNDS[N_BUCKETS - 1], u64::MAX);
        // The finite range must cover multi-minute latencies in ns.
        assert!(BUCKET_BOUNDS[N_BUCKETS - 2] > 120_000_000_000);
    }

    #[test]
    fn bucket_idx_places_values_on_bound_edges() {
        assert_eq!(bucket_idx(0), 0);
        assert_eq!(bucket_idx(1), 0);
        assert_eq!(bucket_idx(2), 1);
        for i in 0..N_BUCKETS - 1 {
            assert_eq!(bucket_idx(BUCKET_BOUNDS[i]), i, "bound {i} maps to itself");
            assert_eq!(
                bucket_idx(BUCKET_BOUNDS[i] + 1),
                i + 1,
                "bound {i}+1 maps up"
            );
        }
        assert_eq!(bucket_idx(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn histogram_quantile_error_is_within_one_bucket_of_exact() {
        // Satellite: histogram-vs-exact quantile relative error must
        // stay within one bucket width (25%) on a known sample set.
        let mut series = Series::new();
        let h = Histo::new();
        let mut v: u64 = 3;
        for i in 0..500 {
            v = (v * 17 + i) % 2_000_000 + 1;
            series.push(v as f64);
            h.observe(v);
        }
        let snap = h.snap();
        for q in [0.5, 0.9, 0.99] {
            let exact = series.quantile(q);
            let est = snap.quantile(q) as f64;
            assert!(est >= exact, "q{q}: histogram must overestimate");
            assert!(
                est <= exact * 1.25 + 1.0,
                "q{q}: est {est} vs exact {exact} exceeds one bucket width"
            );
        }
        assert!((snap.mean() - series.mean()).abs() < 1.0);
    }

    fn snap_of(pairs: &[(&str, &[u64])]) -> Snapshot {
        let reg = Registry::new();
        for (name, vals) in pairs {
            let h = reg.histo(name);
            for &v in *vals {
                h.observe(v);
            }
            reg.counter(&format!("{name}_events")).add(vals.len() as u64);
            reg.gauge(&format!("{name}_level")).set(vals.len() as u64);
        }
        reg.snapshot()
    }

    #[test]
    fn snapshot_merge_is_associative_and_commutative() {
        let a = snap_of(&[("h_a", &[1, 50, 900]), ("h_b", &[7])]);
        let b = snap_of(&[("h_b", &[7, 7000]), ("h_c", &[123_456])]);
        let c = snap_of(&[("h_a", &[2]), ("h_c", &[9])]);

        // (a+b)+c == a+(b+c)
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "merge must be associative");

        // a+b == b+a
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must be commutative");

        // Merged totals are the union's totals.
        assert_eq!(left.histo("h_a").unwrap().count, 4);
        assert_eq!(left.histo("h_b").unwrap().count, 3);
        assert_eq!(left.counter("h_b_events"), Some(3));
        assert_eq!(left.gauge("h_c_level"), Some(2));
    }

    #[test]
    fn merged_fleet_quantile_equals_quantile_of_union() {
        let h1 = Histo::new();
        let h2 = Histo::new();
        let all = Histo::new();
        for i in 0..400u64 {
            let v = i * 37 % 100_000 + 1;
            if i % 2 == 0 { h1.observe(v) } else { h2.observe(v) }
            all.observe(v);
        }
        let mut merged = h1.snap();
        merged.merge(&h2.snap());
        assert_eq!(merged, all.snap());
        assert_eq!(merged.quantile(0.99), all.snap().quantile(0.99));
    }

    #[test]
    fn registry_handles_share_state_and_snapshot_sorts() {
        let reg = Registry::new();
        let c1 = reg.counter("z_total");
        let c2 = reg.counter("z_total");
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        reg.counter("a_total").inc();
        let g = reg.gauge("depth");
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
        g.sub(100);
        assert_eq!(g.get(), 0, "gauge sub saturates");
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["a_total", "z_total"]);
    }

    #[test]
    fn sample_ring_deltas_and_wraparound() {
        let reg = Registry::new();
        let c = reg.counter("jobs_total");
        let g = reg.gauge("depth");
        let mut ring = SampleRing::new(3);
        for tick in 1..=5u64 {
            c.add(10);
            g.set(tick);
            ring.push(tick * 100, &reg.snapshot());
        }
        let samples = ring.samples();
        assert_eq!(samples.len(), 3, "ring must cap at 3");
        // Oldest two ticks were evicted.
        assert_eq!(samples[0].at_ms, 300);
        assert_eq!(samples[2].at_ms, 500);
        for s in &samples {
            assert_eq!(s.counters, vec![("jobs_total".to_string(), 10)]);
        }
        assert_eq!(samples[2].gauges, vec![("depth".to_string(), 5)]);
    }

    #[test]
    fn sampler_thread_ticks_and_stops() {
        let reg = Arc::new(Registry::new());
        reg.counter("ticks_total").add(7);
        let mut s = Sampler::start(Arc::clone(&reg), 5, 8);
        let deadline = Instant::now() + Duration::from_secs(2);
        while s.ring().is_empty() && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(2));
        }
        s.stop();
        let ring = s.ring();
        assert!(!ring.is_empty(), "sampler must tick within 2s");
        assert_eq!(ring[0].counters, vec![("ticks_total".to_string(), 7)]);
        assert!(ring.len() <= 8);
        s.stop(); // idempotent
    }

    #[test]
    fn merged_report_sums_snaps_and_drops_rings() {
        let mut r1 = MetricsReport::default();
        r1.snap = snap_of(&[("lat_ns", &[10, 20])]);
        r1.ring = vec![Sample { at_ms: 1, ..Default::default() }];
        let mut r2 = MetricsReport::default();
        r2.snap = snap_of(&[("lat_ns", &[30])]);
        let m = MetricsReport::merged([&r1, &r2]);
        assert_eq!(m.snap.histo("lat_ns").unwrap().count, 3);
        assert!(m.ring.is_empty(), "merged report carries no ring");
    }

    #[test]
    fn labeled_formats_prometheus_style() {
        assert_eq!(
            labeled("accel_seal_total", "reason", "full"),
            "accel_seal_total{reason=\"full\"}"
        );
    }
}
