//! Calibration probe: render key figures quickly.
use accelserve::experiments::figs as f;
fn main() {
    let n = 150;
    print!("{}", f::fig5(n).render());
    print!("{}", f::fig6(n).render());
    print!("{}", f::fig7(n, true).render());
    print!("{}", f::fig8(n, true).render());
    print!("{}", f::fig11("MobileNetV3", n).render());
    print!("{}", f::fig11("DeepLabV3_ResNet50", 60).render());
    print!("{}", f::fig12_13("MobileNetV3", accelserve::net::params::Transport::Tcp, n).render());
    print!("{}", f::fig12_13("DeepLabV3_ResNet50", accelserve::net::params::Transport::Tcp, 60).render());
    print!("{}", f::fig15a(100).render());
    print!("{}", f::fig15c(100).render());
    print!("{}", f::fig16(60).render());
    print!("{}", f::fig17(100).render());
}
