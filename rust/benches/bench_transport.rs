//! Live transport microbenchmarks: framed-TCP loopback vs shared-memory
//! queue vs RDMA-verbs ring vs GDR round-trip latency across payload
//! sizes (the live-plane analogue of the paper's transport comparison),
//! the per-stage transport-matrix table, plus simulator throughput
//! (events/sec) as the sim-plane §Perf metric.

use std::time::Instant;

use accelserve::experiments::{run_matrix, MatrixCfg};
use accelserve::metrics::stats::Series;
use accelserve::models::zoo::PaperModel;
use accelserve::net::params::Transport;
use accelserve::sim::world::{Scenario, World};
use accelserve::transport::rdma::{rdma_pair, RingCfg};
use accelserve::transport::shm::shm_pair;
use accelserve::transport::tcp::TcpTransport;
use accelserve::transport::MsgTransport;

fn rtt(name: &str, iters: usize, mut send_recv: impl FnMut(&[u8]) -> usize, payload: &[u8]) {
    for _ in 0..10 {
        send_recv(payload);
    }
    let mut s = Series::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        let n = send_recv(payload);
        s.push(t0.elapsed().as_secs_f64() * 1e3);
        assert_eq!(n, payload.len());
    }
    println!(
        "{name:<40} {:>9.4} ms p50  {:>9.4} p99  ({:.1} MB/s rt)",
        s.quantile(0.5),
        s.quantile(0.99),
        2.0 * payload.len() as f64 / (s.mean() / 1e3) / 1e6
    );
}

/// Echo benchmark over an already-connected transport pair. An echo by
/// definition bounces the payload back through host memory, so GDR's
/// zero-copy receive cannot show up here — the per-stage matrix table
/// below is where that effect is measured.
fn echo_pair<T: MsgTransport + 'static>(
    label: &str,
    iters: usize,
    payload: &[u8],
    pair: (T, T),
) {
    let (mut cli, mut srv) = pair;
    let server = std::thread::spawn(move || {
        while let Ok(m) = srv.recv() {
            if srv.send(&m).is_err() {
                break;
            }
        }
    });
    rtt(
        label,
        iters,
        |p| {
            cli.send(p).unwrap();
            cli.recv().unwrap().len()
        },
        payload,
    );
    drop(cli);
    server.join().ok();
}

fn main() {
    let iters: usize = std::env::var("ACCELSERVE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    println!("== bench_transport: live transports (echo round trip) ==");
    for &size in &[4_096usize, 602_112, 4 << 20] {
        let payload: Vec<u8> = (0..size).map(|i| i as u8).collect();

        // TCP loopback echo.
        let listener = TcpTransport::listen("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(s);
            while let Ok(m) = t.recv() {
                if t.send(&m).is_err() {
                    break;
                }
            }
        });
        {
            let mut c = TcpTransport::connect(addr).unwrap();
            rtt(
                &format!("tcp {:>8} B", size),
                iters,
                |p| {
                    c.send(p).unwrap();
                    c.recv().unwrap().len()
                },
                &payload,
            );
        }
        server.join().ok();

        // Shared-memory queue echo.
        echo_pair(&format!("shm {:>8} B", size), iters, &payload, shm_pair(8));

        // RDMA-verbs ring echo (single-slot payloads). The GDR variant
        // is deliberately absent: its receive-side saving is invisible
        // to an echo loop (see the matrix table below for it).
        echo_pair(
            &format!("rdma {:>8} B", size),
            iters,
            &payload,
            rdma_pair(RingCfg::for_payload(size), false),
        );

        // Chunked framing: the same payload through a small-slot ring.
        echo_pair(
            &format!("rdma/64KiB-slots {:>8} B", size),
            iters,
            &payload,
            rdma_pair(
                RingCfg {
                    slots: 8,
                    slot_bytes: 64 << 10,
                },
                false,
            ),
        );
    }

    println!("\n== transport matrix (per-stage breakdown, 1 MiB raw frames) ==");
    let cfg = MatrixCfg {
        requests: iters.min(160),
        ..MatrixCfg::default()
    };
    print!("{}", run_matrix(&cfg).expect("matrix run").render());

    println!("\n== simulator throughput (events/sec) ==");
    for (model, clients, reqs) in [("MobileNetV3", 16usize, 400usize), ("DeepLabV3_ResNet50", 16, 100)] {
        let m = PaperModel::by_name(model).unwrap();
        let t0 = Instant::now();
        let s = World::run(
            Scenario::direct(m, Transport::Rdma)
                .with_clients(clients)
                .with_requests(reqs),
        );
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{model:<20} x{clients}: {:>10} events in {:.3}s = {:.2} M events/s",
            s.events,
            dt,
            s.events as f64 / dt / 1e6
        );
    }
}
