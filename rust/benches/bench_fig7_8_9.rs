//! `cargo bench` target regenerating Fig 7, 8, 9 (all Table II models, single client) at paper scale
//! (closed-loop clients, 1000 requests each by default; override with
//! ACCELSERVE_BENCH_REQS for a faster pass).

use accelserve::experiments::figs;

fn reqs(default: usize) -> usize {
    std::env::var("ACCELSERVE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let t0 = std::time::Instant::now();
    print!("{}", figs::fig7(reqs(600), true).render());
    print!("{}", figs::fig7(reqs(600), false).render());
    print!("{}", figs::fig8(reqs(600), true).render());
    print!("{}", figs::fig8(reqs(600), false).render());
    print!("{}", figs::fig9(reqs(600)).render());
    eprintln!("[{} done in {:.1}s]", "bench_fig7_8_9", t0.elapsed().as_secs_f64());
}
