//! L3/L2 hot-path microbenchmarks (live plane): PJRT inference latency
//! per artifact, executor round-trip overhead, and batching throughput.
//! Hand-rolled harness (criterion is unavailable offline): warmup +
//! timed loop + mean/p50/p99.

use std::sync::Arc;
use std::time::Instant;

use accelserve::coordinator::{BatchCfg, Executor};
use accelserve::metrics::stats::Series;
use accelserve::runtime::{Engine, TensorBuf};

fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..(iters / 10).max(3) {
        f(); // warmup
    }
    let mut s = Series::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "{name:<42} {:>9.4} ms mean  {:>9.4} p50  {:>9.4} p99",
        s.mean(),
        s.quantile(0.5),
        s.quantile(0.99)
    );
    s.mean()
}

fn main() {
    accelserve::models::gen::ensure_artifacts("artifacts").expect("gen artifacts");
    let iters: usize = std::env::var("ACCELSERVE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);

    println!("== bench_runtime: PJRT hot path ==");
    let engine = Engine::load("artifacts").unwrap();
    let f32_in = TensorBuf::F32(vec![0.25; 32 * 32 * 3]);
    let u8_in = TensorBuf::U8(vec![128u8; 64 * 64 * 3]);

    for name in [
        "preprocess",
        "tiny_mobilenet_b1",
        "tiny_resnet_b1",
        "tiny_segnet_b1",
        "tiny_resnet_raw",
    ] {
        let input = if name == "preprocess" || name.ends_with("_raw") {
            u8_in.clone()
        } else {
            f32_in.clone()
        };
        // compile outside the timed loop
        let _ = engine.infer(name, &input).unwrap();
        bench(&format!("engine.infer({name})"), iters, || {
            let _ = std::hint::black_box(engine.infer(name, &input).unwrap());
        });
    }

    // Batched throughput: items/sec at b=1 vs b=8.
    for b in [1usize, 8] {
        let name = format!("tiny_resnet_b{b}");
        let input = TensorBuf::F32(vec![0.25; b * 32 * 32 * 3]);
        let _ = engine.infer(&name, &input).unwrap();
        let mean_ms = bench(&format!("engine.infer({name}) [batch {b}]"), iters, || {
            let _ = std::hint::black_box(engine.infer(&name, &input).unwrap());
        });
        println!(
            "{:<42} {:>9.1} items/s",
            format!("  -> throughput b={b}"),
            b as f64 / (mean_ms / 1e3)
        );
    }

    // Executor round trip (queue + dispatch overhead over raw infer).
    let exec = Arc::new(
        Executor::start(
            "artifacts",
            1,
            BatchCfg::none(),
            &["tiny_mobilenet_b1"],
        )
        .unwrap(),
    );
    bench("executor.infer_sync(tiny_mobilenet)", iters, || {
        let _ = std::hint::black_box(
            exec.infer_sync("tiny_mobilenet", false, 0, f32_in.clone())
                .unwrap(),
        );
    });
}
