//! `cargo bench` target regenerating Fig 11-13 (scalability + stage fractions) at paper scale
//! (closed-loop clients, 1000 requests each by default; override with
//! ACCELSERVE_BENCH_REQS for a faster pass).

use accelserve::experiments::figs;

fn reqs(default: usize) -> usize {
    std::env::var("ACCELSERVE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let t0 = std::time::Instant::now();
    print!("{}", figs::fig11("MobileNetV3", reqs(500)).render());
    print!("{}", figs::fig11("DeepLabV3_ResNet50", reqs(500) / 3).render());
    for tr in [accelserve::net::params::Transport::Tcp,
               accelserve::net::params::Transport::Rdma,
               accelserve::net::params::Transport::Gdr] {
        print!("{}", figs::fig12_13("MobileNetV3", tr, reqs(500)).render());
        print!("{}", figs::fig12_13("DeepLabV3_ResNet50", tr, reqs(500) / 3).render());
    }
    eprintln!("[{} done in {:.1}s]", "bench_fig11_12_13", t0.elapsed().as_secs_f64());
}
