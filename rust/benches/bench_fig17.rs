//! `cargo bench` target regenerating Fig 17 (GPU sharing methods, EfficientNetB0) at paper scale
//! (closed-loop clients, 1000 requests each by default; override with
//! ACCELSERVE_BENCH_REQS for a faster pass).

use accelserve::experiments::figs;

fn reqs(default: usize) -> usize {
    std::env::var("ACCELSERVE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let t0 = std::time::Instant::now();
    print!("{}", figs::fig17(reqs(400)).render());
    eprintln!("[{} done in {:.1}s]", "bench_fig17", t0.elapsed().as_secs_f64());
}
