//! Pure-Rust stand-in for the `xla` crate (the xla_extension / PJRT
//! binding the live-plane `Engine` runs compiled HLO artifacts on).
//!
//! Unlike the original error-only stub, this crate now *executes*: it
//! parses the HLO text `python/compile/aot.py` (or the offline
//! `accelserve gen-artifacts` generator) emits into an op graph and
//! interprets it over f32/u8 literals. The API surface is exactly what
//! `rust/src/runtime/engine.rs` uses, so swapping in the real
//! xla_extension binding still requires no call-site changes — this is
//! a reference evaluator, not a compiler.
//!
//! Supported HLO opcodes (see `parser.rs` / `interp.rs`):
//! `parameter`, `constant`, `iota`, `reshape`, `broadcast`, `convert`,
//! `add`, `subtract`, `multiply`, `divide`, `maximum`, `minimum`,
//! `dot` (single contracting dim), `reduce` (add/mul/max/min regions),
//! `convolution` (NHWC x HWIO, stride + zero padding), `transpose`,
//! `slice`, `call`, `tuple`, `get-tuple-element`.

mod interp;
mod parser;

use std::fmt;
use std::sync::Arc;

/// Errors surfaced by the interpreter: always a rendered message.
pub struct Error(String);

impl Error {
    pub(crate) fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the engine materializes literals for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 => 4,
            ElementType::U8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ElementType::F32 => "f32",
            ElementType::U8 => "u8",
        }
    }
}

/// Typed element storage of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    U8(Vec<u8>),
    Tuple(Vec<Literal>),
}

/// A host literal: shape + dtype + elements (or a tuple of literals).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    pub(crate) dims: Vec<usize>,
    pub(crate) data: LiteralData,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * ty.byte_size() {
            return Err(Error::msg(format!(
                "literal: {} bytes for {} x {} ({} expected)",
                data.len(),
                elems,
                ty.name(),
                elems * ty.byte_size()
            )));
        }
        let data = match ty {
            ElementType::F32 => LiteralData::F32(
                data.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            ElementType::U8 => LiteralData::U8(data.to_vec()),
        };
        Ok(Literal {
            dims: dims.to_vec(),
            data,
        })
    }

    /// Scalar/array constructors used by the interpreter and tests.
    pub fn from_f32s(dims: &[usize], values: Vec<f32>) -> Literal {
        debug_assert_eq!(dims.iter().product::<usize>(), values.len());
        Literal {
            dims: dims.to_vec(),
            data: LiteralData::F32(values),
        }
    }

    pub fn from_u8s(dims: &[usize], values: Vec<u8>) -> Literal {
        debug_assert_eq!(dims.iter().product::<usize>(), values.len());
        Literal {
            dims: dims.to_vec(),
            data: LiteralData::U8(values),
        }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::U8(v) => v.len(),
            LiteralData::Tuple(t) => t.iter().map(Literal::element_count).sum(),
        }
    }

    pub fn element_type(&self) -> Option<ElementType> {
        match &self.data {
            LiteralData::F32(_) => Some(ElementType::F32),
            LiteralData::U8(_) => Some(ElementType::U8),
            LiteralData::Tuple(_) => None,
        }
    }

    /// Unwrap a 1-tuple (aot.py lowers with `return_tuple=True`).
    pub fn to_tuple1(self) -> Result<Literal> {
        match self.data {
            LiteralData::Tuple(mut elems) if elems.len() == 1 => Ok(elems.remove(0)),
            LiteralData::Tuple(elems) => Err(Error::msg(format!(
                "to_tuple1: literal is a {}-tuple",
                elems.len()
            ))),
            _ => Err(Error::msg("to_tuple1: literal is not a tuple")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Native element types extractable from a [`Literal`].
pub trait NativeType: Sized {
    fn from_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn from_literal(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            _ => Err(Error::msg("to_vec::<f32>: literal is not f32")),
        }
    }
}

impl NativeType for u8 {
    fn from_literal(lit: &Literal) -> Result<Vec<u8>> {
        match &lit.data {
            LiteralData::U8(v) => Ok(v.clone()),
            _ => Err(Error::msg("to_vec::<u8>: literal is not u8")),
        }
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    pub(crate) module: Arc<parser::HloModule>,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading HLO text {path}: {e}")))?;
        Self::from_text(&text)
    }

    /// Parse HLO text directly (tests, in-memory fixtures).
    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        Ok(HloModuleProto {
            module: Arc::new(parser::parse(text)?),
        })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    module: Arc<parser::HloModule>,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            module: proto.module.clone(),
        }
    }
}

/// A device buffer returned by an execution.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A compiled, loaded executable: here, the interpretable op graph.
pub struct PjRtLoadedExecutable {
    module: Arc<parser::HloModule>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let arg_refs: Vec<&Literal> = args.iter().map(AsRef::as_ref).collect();
        let out = interp::evaluate_entry(&self.module, &arg_refs)?;
        Ok(vec![vec![PjRtBuffer { lit: out }]])
    }
}

/// The PJRT client ("device context").
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    /// "Compilation" validates that the entry computation exists and
    /// every opcode is interpretable, so unsupported-op problems surface
    /// at engine warm-up (like a real compile). Shape/attribute
    /// inconsistencies in a malformed module surface as `Err` from
    /// `execute` — the per-op evaluators validate before indexing.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        interp::check_supported(&comp.module)?;
        Ok(PjRtLoadedExecutable {
            module: comp.module.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ADD_MODULE: &str = "\
HloModule add_one

ENTRY main.1 {
  x = f32[2,2] parameter(0)
  one = f32[] constant(1)
  ones = f32[2,2] broadcast(one), dimensions={}
  sum = f32[2,2] add(x, ones)
  ROOT out = (f32[2,2]) tuple(sum)
}
";

    #[test]
    fn client_compiles_and_executes() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        let proto = HloModuleProto::from_text(ADD_MODULE).unwrap();
        let exe = c.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[1.0f32, 2.0, 3.0, 4.0]
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect::<Vec<u8>>(),
        )
        .unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn literal_validates_byte_length() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0; 15]
        )
        .is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[3], &[7, 8, 9])
                .unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), vec![7, 8, 9]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn missing_file_reports_path() {
        let err = HloModuleProto::from_text_file("/no/such/file.hlo.txt").unwrap_err();
        assert!(format!("{err}").contains("/no/such/file.hlo.txt"));
    }
}
