//! Offline stand-in for the `xla` crate (the xla_extension / PJRT
//! binding the live-plane `Engine` runs compiled HLO artifacts on).
//!
//! This container has no network access and no prebuilt xla_extension
//! runtime, so the workspace vendors the exact API surface
//! `rust/src/runtime/engine.rs` uses. Client construction succeeds (so
//! `Engine::load` works against a manifest and the graceful-skip
//! pattern in the tests keeps functioning); anything that would need a
//! real PJRT runtime — parsing HLO text, compiling, executing —
//! returns a descriptive error instead.
//!
//! Swap this path dependency for the real `xla` binding when building
//! in an environment with xla_extension; no call sites change.

use std::fmt;

/// Errors surfaced by the stub: always a rendered message.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "xla stub: {what} requires the real xla_extension/PJRT runtime \
             (this build vendors rust/vendor/xla)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the engine materializes literals for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!(
            "xla stub: cannot parse HLO text {path}: the real \
             xla_extension/PJRT runtime is not available in this build"
        )))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A host literal: shape + dtype + raw bytes.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// A device buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client ("device context").
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The stub client constructs fine: `Engine::load` only needs it to
    /// exist; per-artifact compilation is where the stub reports itself.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0; 16])
                .unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
