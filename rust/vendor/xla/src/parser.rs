//! HLO-text parser: turns the text `XlaComputation::as_hlo_text()`
//! prints (and the offline `accelserve gen-artifacts` generator emits)
//! into an op graph the interpreter can walk.
//!
//! The grammar handled is the standard instruction line
//!
//! ```text
//!   [ROOT ]name = shape opcode(operand, ...), attr={...}, attr=value
//! ```
//!
//! inside `ENTRY name {` / `name {` computation blocks. Layout suffixes
//! (`{1,0}`) and unknown attributes (e.g. `metadata=`) are skipped, so
//! real jax-emitted modules parse as long as they stay inside the
//! supported opcode set.

use std::collections::HashMap;

use crate::{ElementType, Error, Result};

/// An array or tuple shape.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Shape {
    Array { ty: ElementType, dims: Vec<usize> },
    Tuple(Vec<Shape>),
}

impl Shape {
    pub fn elems(&self) -> usize {
        match self {
            Shape::Array { dims, .. } => dims.iter().product(),
            Shape::Tuple(t) => t.iter().map(Shape::elems).sum(),
        }
    }

    pub fn array(&self) -> Result<(ElementType, &[usize])> {
        match self {
            Shape::Array { ty, dims } => Ok((*ty, dims)),
            Shape::Tuple(_) => Err(Error::msg("expected array shape, got tuple")),
        }
    }
}

/// One parsed instruction.
#[derive(Debug, Clone)]
pub(crate) struct Instr {
    pub name: String,
    pub shape: Shape,
    pub opcode: String,
    pub operands: Vec<String>,
    pub attrs: Vec<(String, String)>,
    /// `constant(...)` payload, row-major.
    pub consts: Option<Vec<f64>>,
    /// `parameter(N)` index.
    pub param_index: Option<usize>,
    pub is_root: bool,
}

impl Instr {
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// A computation-name attribute (`to_apply=...`), with the optional
    /// `%` sigil stripped to match the computation map keys.
    pub fn attr_computation(&self, key: &str) -> Option<&str> {
        self.attr(key).map(|v| v.trim_start_matches('%'))
    }

    /// An attr of the form `{1,2}` parsed as a list of usize.
    pub fn attr_dims(&self, key: &str) -> Result<Vec<usize>> {
        let v = self
            .attr(key)
            .ok_or_else(|| Error::msg(format!("{}: missing attr {key}", self.name)))?;
        parse_usize_list(v)
    }
}

/// One named computation (entry or region).
#[derive(Debug, Clone)]
pub(crate) struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub index: HashMap<String, usize>,
    pub root: usize,
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub(crate) struct HloModule {
    pub name: String,
    pub computations: HashMap<String, Computation>,
    pub entry: String,
}

impl HloModule {
    pub fn entry_computation(&self) -> Result<&Computation> {
        self.computations
            .get(&self.entry)
            .ok_or_else(|| Error::msg(format!("no entry computation {}", self.entry)))
    }
}

/// Parse a full HLO-text module.
pub(crate) fn parse(text: &str) -> Result<HloModule> {
    let mut name = String::new();
    let mut computations = HashMap::new();
    let mut entry: Option<String> = None;
    let mut last_comp: Option<String> = None;
    let mut cur: Option<Computation> = None;
    let mut saw_root = false;

    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule") {
            name = rest
                .trim()
                .split([',', ' '])
                .next()
                .unwrap_or("")
                .to_string();
            continue;
        }
        if line == "}" {
            let mut c = cur
                .take()
                .ok_or_else(|| Error::msg("unmatched '}' outside a computation"))?;
            if c.instrs.is_empty() {
                return Err(Error::msg(format!("computation {} is empty", c.name)));
            }
            if !saw_root {
                c.root = c.instrs.len() - 1;
            }
            last_comp = Some(c.name.clone());
            computations.insert(c.name.clone(), c);
            continue;
        }
        if line.ends_with('{') && !line.contains('=') {
            if cur.is_some() {
                return Err(Error::msg("nested computation block"));
            }
            let head = line[..line.len() - 1].trim();
            let (is_entry, head) = match head.strip_prefix("ENTRY ") {
                Some(rest) => (true, rest),
                None => (false, head),
            };
            let cname = head
                .split([' ', ','])
                .next()
                .unwrap_or("")
                .trim_start_matches('%')
                .to_string();
            if cname.is_empty() {
                return Err(Error::msg(format!("bad computation header: {line}")));
            }
            if is_entry {
                entry = Some(cname.clone());
            }
            cur = Some(Computation {
                name: cname,
                instrs: Vec::new(),
                index: HashMap::new(),
                root: 0,
            });
            saw_root = false;
            continue;
        }
        let comp = cur
            .as_mut()
            .ok_or_else(|| Error::msg(format!("instruction outside computation: {line}")))?;
        let instr = parse_instr(line)?;
        if instr.is_root {
            comp.root = comp.instrs.len();
            saw_root = true;
        }
        comp.index.insert(instr.name.clone(), comp.instrs.len());
        comp.instrs.push(instr);
    }
    if cur.is_some() {
        return Err(Error::msg("unterminated computation block"));
    }
    let entry = entry
        .or(last_comp)
        .ok_or_else(|| Error::msg("module has no computations"))?;
    Ok(HloModule {
        name,
        computations,
        entry,
    })
}

fn parse_instr(line: &str) -> Result<Instr> {
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let eq = line
        .find(" = ")
        .ok_or_else(|| Error::msg(format!("instruction missing '=': {line}")))?;
    let name = line[..eq].trim().trim_start_matches('%').to_string();
    let rest = line[eq + 3..].trim();

    // Shape: a tuple "(...)" or a space-free token like f32[4,3]{1,0}.
    let (shape_str, rest) = if let Some(stripped) = rest.strip_prefix('(') {
        let close = matching(stripped, '(', ')')?;
        (&rest[..close + 2], rest[close + 2..].trim_start())
    } else {
        let sp = rest
            .find(' ')
            .ok_or_else(|| Error::msg(format!("instruction missing opcode: {line}")))?;
        (&rest[..sp], rest[sp + 1..].trim_start())
    };
    let shape = parse_shape(shape_str)?;

    // Opcode + parenthesized operand list.
    let par = rest
        .find('(')
        .ok_or_else(|| Error::msg(format!("opcode missing '(': {line}")))?;
    let opcode = rest[..par].trim().to_string();
    if opcode.is_empty() || opcode.contains(' ') {
        return Err(Error::msg(format!("bad opcode in: {line}")));
    }
    let close_rel = matching(&rest[par + 1..], '(', ')')?;
    let inner = &rest[par + 1..par + 1 + close_rel];
    let after = rest[par + 1 + close_rel + 1..]
        .trim_start()
        .trim_start_matches(',')
        .trim();

    let mut consts = None;
    let mut param_index = None;
    let mut operands = Vec::new();
    match opcode.as_str() {
        "constant" => consts = Some(parse_numbers(inner)?),
        "parameter" => {
            param_index = Some(inner.trim().parse::<usize>().map_err(|_| {
                Error::msg(format!("bad parameter index '{inner}' in: {line}"))
            })?)
        }
        _ => {
            for tok in split_top(inner) {
                let tok = tok.trim();
                if tok.is_empty() {
                    continue;
                }
                // Operands may be printed with their shape prefix
                // ("f32[2]{0} %x"); the name is the last token.
                let opname = tok
                    .split_whitespace()
                    .last()
                    .unwrap_or(tok)
                    .trim_start_matches('%');
                operands.push(opname.to_string());
            }
        }
    }

    let mut attrs = Vec::new();
    for piece in split_top(after) {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        if let Some(eqi) = piece.find('=') {
            attrs.push((
                piece[..eqi].trim().to_string(),
                piece[eqi + 1..].trim().to_string(),
            ));
        }
    }

    Ok(Instr {
        name,
        shape,
        opcode,
        operands,
        attrs,
        consts,
        param_index,
        is_root,
    })
}

/// Index of the closing delimiter matching an already-consumed opener.
fn matching(s: &str, open: char, close: char) -> Result<usize> {
    let mut depth = 1usize;
    for (i, c) in s.char_indices() {
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Ok(i);
            }
        }
    }
    Err(Error::msg(format!("unbalanced '{open}' in: {s}")))
}

/// Split on top-level commas (outside (), {} and []).
fn split_top(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth -= 1,
            ',' if depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn parse_shape(s: &str) -> Result<Shape> {
    let s = s.trim();
    if let Some(stripped) = s.strip_prefix('(') {
        let close = matching(stripped, '(', ')')?;
        let inner = &stripped[..close];
        let mut members = Vec::new();
        for part in split_top(inner) {
            let part = part.trim();
            if !part.is_empty() {
                members.push(parse_shape(part)?);
            }
        }
        return Ok(Shape::Tuple(members));
    }
    let lb = s
        .find('[')
        .ok_or_else(|| Error::msg(format!("shape missing '[': {s}")))?;
    let rb = s
        .find(']')
        .ok_or_else(|| Error::msg(format!("shape missing ']': {s}")))?;
    let ty = match &s[..lb] {
        "f32" => ElementType::F32,
        "u8" => ElementType::U8,
        other => {
            return Err(Error::msg(format!(
                "unsupported element type {other} (supported: f32, u8)"
            )))
        }
    };
    let dims_str = &s[lb + 1..rb];
    let mut dims = Vec::new();
    if !dims_str.trim().is_empty() {
        for d in dims_str.split(',') {
            dims.push(
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::msg(format!("bad dimension '{d}' in shape {s}")))?,
            );
        }
    }
    // Anything after ']' is the layout ({1,0}); skipped.
    Ok(Shape::Array { ty, dims })
}

/// Parse `{1,2}` / `1` style lists of usize.
pub(crate) fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(
            tok.parse::<usize>()
                .map_err(|_| Error::msg(format!("bad index '{tok}' in list {s}")))?,
        );
    }
    Ok(out)
}

/// Extract every numeric token from a (possibly nested-brace) constant
/// payload, row-major.
fn parse_numbers(s: &str) -> Result<Vec<f64>> {
    let cleaned: String = s
        .chars()
        .map(|c| if c == '{' || c == '}' || c == ',' { ' ' } else { c })
        .collect();
    let mut out = Vec::new();
    for tok in cleaned.split_whitespace() {
        out.push(
            tok.parse::<f64>()
                .map_err(|_| Error::msg(format!("bad constant token '{tok}'")))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_instruction_forms() {
        let i = parse_instr(
            "dot.14 = f32[4,8]{1,0} dot(Arg_0.1, divide.13), \
             lhs_contracting_dims={1}, rhs_contracting_dims={0}",
        )
        .unwrap();
        assert_eq!(i.name, "dot.14");
        assert_eq!(i.opcode, "dot");
        assert_eq!(i.operands, vec!["Arg_0.1", "divide.13"]);
        assert_eq!(i.attr_dims("lhs_contracting_dims").unwrap(), vec![1]);
        assert_eq!(i.attr_dims("rhs_contracting_dims").unwrap(), vec![0]);
        let (ty, dims) = i.shape.array().unwrap();
        assert_eq!(ty, ElementType::F32);
        assert_eq!(dims, &[4, 8]);

        let c = parse_instr("k = f32[2,2] constant({ { 1, 2.5 }, { -3, 4e-2 } })").unwrap();
        assert_eq!(c.consts.unwrap(), vec![1.0, 2.5, -3.0, 0.04]);

        let p = parse_instr("Arg_0.1 = u8[64,64,3]{2,1,0} parameter(0)").unwrap();
        assert_eq!(p.param_index, Some(0));

        let r = parse_instr(
            "ROOT tuple.27 = (f32[4,8]{1,0}) tuple(add.26)",
        )
        .unwrap();
        assert!(r.is_root);
        assert!(matches!(r.shape, Shape::Tuple(ref t) if t.len() == 1));
    }

    #[test]
    fn window_attrs_survive_splitting() {
        let i = parse_instr(
            "conv = f32[1,16,16,8] convolution(x, w), \
             window={size=3x3 stride=2x2 pad=0_1x0_1}, dim_labels=b01f_01io->b01f",
        )
        .unwrap();
        assert_eq!(
            i.attr("window").unwrap(),
            "{size=3x3 stride=2x2 pad=0_1x0_1}"
        );
        assert_eq!(i.attr("dim_labels").unwrap(), "b01f_01io->b01f");
    }

    #[test]
    fn parses_module_with_region() {
        let m = parse(
            "HloModule t, entry_computation_layout={(f32[4]{0})->f32[]}\n\n\
             region_0.3 {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n\
             \x20 ROOT s = f32[] add(a, b)\n}\n\n\
             ENTRY main.9 {\n  x = f32[4]{0} parameter(0)\n  z = f32[] constant(0)\n\
             \x20 ROOT r = f32[] reduce(x, z), dimensions={0}, to_apply=region_0.3\n}\n",
        )
        .unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.entry, "main.9");
        assert_eq!(m.computations.len(), 2);
        let e = m.entry_computation().unwrap();
        assert_eq!(e.instrs.len(), 3);
        assert_eq!(e.root, 2);
        assert_eq!(e.instrs[2].attr("to_apply").unwrap(), "region_0.3");
    }

    #[test]
    fn percent_sigils_stripped_everywhere() {
        // Long-form HLO prints %-prefixed names; names, operands and
        // computation-name attributes must all resolve sigil-free.
        let i = parse_instr(
            "%r = f32[] reduce(%x, %z), dimensions={0}, to_apply=%region_0.3",
        )
        .unwrap();
        assert_eq!(i.name, "r");
        assert_eq!(i.operands, vec!["x", "z"]);
        assert_eq!(i.attr("to_apply").unwrap(), "%region_0.3");
        assert_eq!(i.attr_computation("to_apply").unwrap(), "region_0.3");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_instr("garbage").is_err());
        assert!(parse_instr("x = f32[2] add(a, b").is_err());
        assert!(parse_shape("q17[3]").is_err());
        assert!(parse("ENTRY main {\n  x = f32[1] parameter(0)\n").is_err());
    }
}
