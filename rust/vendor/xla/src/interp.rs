//! Reference evaluator for the parsed HLO op graph.
//!
//! Shapes are tiny (the serving artifacts are scaled-down CNNs), so
//! every op is implemented as a direct index-space loop over row-major
//! buffers — clarity over throughput. The declared result shape of each
//! instruction is trusted for output allocation and cross-checked where
//! it is cheap to do so.

use crate::parser::{Computation, HloModule, Instr};
use crate::{ElementType, Error, Literal, LiteralData, Result};

/// Validate that every instruction in every computation is within the
/// interpreter's opcode set (the "compile" step).
pub(crate) fn check_supported(module: &HloModule) -> Result<()> {
    const SUPPORTED: &[&str] = &[
        "parameter",
        "constant",
        "iota",
        "reshape",
        "broadcast",
        "convert",
        "add",
        "subtract",
        "multiply",
        "divide",
        "maximum",
        "minimum",
        "dot",
        "reduce",
        "convolution",
        "transpose",
        "slice",
        "call",
        "tuple",
        "get-tuple-element",
    ];
    module.entry_computation()?;
    for comp in module.computations.values() {
        for ins in &comp.instrs {
            if !SUPPORTED.contains(&ins.opcode.as_str()) {
                return Err(Error::msg(format!(
                    "unsupported HLO opcode '{}' ({} in {}); the pure-Rust \
                     interpreter supports: {}",
                    ins.opcode,
                    ins.name,
                    comp.name,
                    SUPPORTED.join(", ")
                )));
            }
        }
    }
    Ok(())
}

/// Evaluate the module's entry computation on `args`.
pub(crate) fn evaluate_entry(module: &HloModule, args: &[&Literal]) -> Result<Literal> {
    let entry = module.entry_computation()?;
    let owned: Vec<Literal> = args.iter().map(|l| (*l).clone()).collect();
    evaluate(module, entry, &owned)
}

fn evaluate(module: &HloModule, comp: &Computation, args: &[Literal]) -> Result<Literal> {
    let mut env: Vec<Option<Literal>> = vec![None; comp.instrs.len()];
    for i in 0..comp.instrs.len() {
        let val = eval_instr(module, comp, &comp.instrs[i], &env, args)?;
        env[i] = Some(val);
    }
    env[comp.root]
        .take()
        .ok_or_else(|| Error::msg(format!("{}: missing root value", comp.name)))
}

fn operand<'a>(
    comp: &Computation,
    env: &'a [Option<Literal>],
    ins: &Instr,
    i: usize,
) -> Result<&'a Literal> {
    let name = ins.operands.get(i).ok_or_else(|| {
        Error::msg(format!("{}: missing operand #{i}", ins.name))
    })?;
    let idx = *comp.index.get(name).ok_or_else(|| {
        Error::msg(format!("{}: unknown operand {name}", ins.name))
    })?;
    env[idx].as_ref().ok_or_else(|| {
        Error::msg(format!(
            "{}: operand {name} not evaluated yet (module not in def-before-use order)",
            ins.name
        ))
    })
}

fn f32s(lit: &Literal, ctx: &str) -> Result<Vec<f32>> {
    match &lit.data {
        LiteralData::F32(v) => Ok(v.clone()),
        LiteralData::U8(_) => Err(Error::msg(format!("{ctx}: expected f32 operand, got u8"))),
        LiteralData::Tuple(_) => {
            Err(Error::msg(format!("{ctx}: expected f32 operand, got tuple")))
        }
    }
}

/// Row-major strides for `dims`.
fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Decompose `linear` into a multi-index over `dims`.
fn unravel(mut linear: usize, dims: &[usize], out: &mut Vec<usize>) {
    out.clear();
    out.resize(dims.len(), 0);
    for i in (0..dims.len()).rev() {
        out[i] = linear % dims[i];
        linear /= dims[i];
    }
}

fn eval_instr(
    module: &HloModule,
    comp: &Computation,
    ins: &Instr,
    env: &[Option<Literal>],
    args: &[Literal],
) -> Result<Literal> {
    match ins.opcode.as_str() {
        "parameter" => {
            let idx = ins
                .param_index
                .ok_or_else(|| Error::msg(format!("{}: parameter without index", ins.name)))?;
            let arg = args.get(idx).ok_or_else(|| {
                Error::msg(format!(
                    "{}: parameter({idx}) but only {} arguments were passed",
                    ins.name,
                    args.len()
                ))
            })?;
            let (ty, dims) = ins.shape.array()?;
            let elems: usize = dims.iter().product();
            if arg.element_count() != elems || arg.element_type() != Some(ty) {
                return Err(Error::msg(format!(
                    "{}: argument {idx} is {} x {:?}, computation expects {} x {}{:?}",
                    ins.name,
                    arg.element_count(),
                    arg.element_type().map(ElementType::name),
                    elems,
                    ty.name(),
                    dims
                )));
            }
            Ok(Literal {
                dims: dims.to_vec(),
                data: arg.data.clone(),
            })
        }
        "constant" => {
            let (ty, dims) = ins.shape.array()?;
            let vals = ins
                .consts
                .as_ref()
                .ok_or_else(|| Error::msg(format!("{}: constant without payload", ins.name)))?;
            let elems: usize = dims.iter().product();
            if vals.len() != elems {
                return Err(Error::msg(format!(
                    "{}: constant has {} values for shape {:?}",
                    ins.name,
                    vals.len(),
                    dims
                )));
            }
            let data = match ty {
                ElementType::F32 => LiteralData::F32(vals.iter().map(|v| *v as f32).collect()),
                ElementType::U8 => LiteralData::U8(vals.iter().map(|v| *v as u8).collect()),
            };
            Ok(Literal {
                dims: dims.to_vec(),
                data,
            })
        }
        "iota" => {
            let (ty, dims) = ins.shape.array()?;
            let d = ins
                .attr("iota_dimension")
                .and_then(|v| v.parse::<usize>().ok())
                .ok_or_else(|| Error::msg(format!("{}: iota without dimension", ins.name)))?;
            let n: usize = dims.iter().product();
            let mut idx = Vec::new();
            let mut vals = Vec::with_capacity(n);
            for lin in 0..n {
                unravel(lin, dims, &mut idx);
                vals.push(idx[d] as f32);
            }
            let data = match ty {
                ElementType::F32 => LiteralData::F32(vals),
                ElementType::U8 => LiteralData::U8(vals.into_iter().map(|v| v as u8).collect()),
            };
            Ok(Literal {
                dims: dims.to_vec(),
                data,
            })
        }
        "reshape" => {
            let x = operand(comp, env, ins, 0)?;
            let (_, dims) = ins.shape.array()?;
            let elems: usize = dims.iter().product();
            if x.element_count() != elems {
                return Err(Error::msg(format!(
                    "{}: reshape {} elements into {:?}",
                    ins.name,
                    x.element_count(),
                    dims
                )));
            }
            Ok(Literal {
                dims: dims.to_vec(),
                data: x.data.clone(),
            })
        }
        "broadcast" => {
            let x = operand(comp, env, ins, 0)?;
            let (_, out_dims) = ins.shape.array()?;
            let map = match ins.attr("dimensions") {
                Some(v) => crate::parser::parse_usize_list(v)?,
                None => Vec::new(),
            };
            if map.len() != x.dims.len() {
                return Err(Error::msg(format!(
                    "{}: broadcast maps {} dims for a rank-{} operand",
                    ins.name,
                    map.len(),
                    x.dims.len()
                )));
            }
            if let Some(&bad) = map.iter().find(|&&od| od >= out_dims.len()) {
                return Err(Error::msg(format!(
                    "{}: broadcast dimension {bad} out of range for rank-{} result",
                    ins.name,
                    out_dims.len()
                )));
            }
            for (k, &od) in map.iter().enumerate() {
                if x.dims[k] != out_dims[od] {
                    return Err(Error::msg(format!(
                        "{}: broadcast operand dim {k} (extent {}) mapped to result \
                         dim {od} (extent {})",
                        ins.name, x.dims[k], out_dims[od]
                    )));
                }
            }
            let xs = f32s(x, &ins.name)?;
            let xstr = strides(&x.dims);
            let n: usize = out_dims.iter().product();
            let mut idx = Vec::new();
            let mut out = Vec::with_capacity(n);
            for lin in 0..n {
                unravel(lin, out_dims, &mut idx);
                let mut off = 0usize;
                for (k, &od) in map.iter().enumerate() {
                    off += idx[od] * xstr[k];
                }
                out.push(xs[off]);
            }
            Ok(Literal::from_f32s(out_dims, out))
        }
        "convert" => {
            let x = operand(comp, env, ins, 0)?;
            let (ty, dims) = ins.shape.array()?;
            let data = match (&x.data, ty) {
                (LiteralData::U8(v), ElementType::F32) => {
                    LiteralData::F32(v.iter().map(|&b| b as f32).collect())
                }
                (LiteralData::F32(v), ElementType::U8) => LiteralData::U8(
                    v.iter().map(|&f| f.round().clamp(0.0, 255.0) as u8).collect(),
                ),
                (LiteralData::F32(v), ElementType::F32) => LiteralData::F32(v.clone()),
                (LiteralData::U8(v), ElementType::U8) => LiteralData::U8(v.clone()),
                (LiteralData::Tuple(_), _) => {
                    return Err(Error::msg(format!("{}: convert of tuple", ins.name)))
                }
            };
            Ok(Literal {
                dims: dims.to_vec(),
                data,
            })
        }
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" => {
            let a = f32s(operand(comp, env, ins, 0)?, &ins.name)?;
            let b = f32s(operand(comp, env, ins, 1)?, &ins.name)?;
            if a.len() != b.len() {
                return Err(Error::msg(format!(
                    "{}: elementwise {} on {} vs {} elements",
                    ins.name,
                    ins.opcode,
                    a.len(),
                    b.len()
                )));
            }
            let f: fn(f32, f32) -> f32 = match ins.opcode.as_str() {
                "add" => |x, y| x + y,
                "subtract" => |x, y| x - y,
                "multiply" => |x, y| x * y,
                "divide" => |x, y| x / y,
                "maximum" => f32::max,
                _ => f32::min,
            };
            let (_, dims) = ins.shape.array()?;
            let out: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| f(x, y)).collect();
            Ok(Literal::from_f32s(dims, out))
        }
        "dot" => eval_dot(comp, env, ins),
        "reduce" => eval_reduce(module, comp, env, ins),
        "convolution" => eval_conv(comp, env, ins),
        "transpose" => {
            let x = operand(comp, env, ins, 0)?;
            let perm = ins.attr_dims("dimensions")?;
            if perm.len() != x.dims.len() || perm.iter().any(|&p| p >= x.dims.len()) {
                return Err(Error::msg(format!(
                    "{}: transpose permutation {:?} invalid for rank-{} operand",
                    ins.name,
                    perm,
                    x.dims.len()
                )));
            }
            let (_, out_dims) = ins.shape.array()?;
            for (i, &p) in perm.iter().enumerate() {
                if out_dims.get(i) != Some(&x.dims[p]) {
                    return Err(Error::msg(format!(
                        "{}: transpose result {:?} inconsistent with operand {:?} \
                         permuted by {:?}",
                        ins.name, out_dims, x.dims, perm
                    )));
                }
            }
            let xs = f32s(x, &ins.name)?;
            let xstr = strides(&x.dims);
            let n = xs.len();
            let mut idx = Vec::new();
            let mut out = Vec::with_capacity(n);
            for lin in 0..n {
                unravel(lin, out_dims, &mut idx);
                let mut off = 0usize;
                for (i, &p) in perm.iter().enumerate() {
                    off += idx[i] * xstr[p];
                }
                out.push(xs[off]);
            }
            Ok(Literal::from_f32s(out_dims, out))
        }
        "slice" => eval_slice(comp, env, ins),
        "call" => {
            let target = ins
                .attr_computation("to_apply")
                .ok_or_else(|| Error::msg(format!("{}: call without to_apply", ins.name)))?;
            let callee = module.computations.get(target).ok_or_else(|| {
                Error::msg(format!("{}: unknown computation {target}", ins.name))
            })?;
            let mut call_args = Vec::with_capacity(ins.operands.len());
            for i in 0..ins.operands.len() {
                call_args.push(operand(comp, env, ins, i)?.clone());
            }
            evaluate(module, callee, &call_args)
        }
        "tuple" => {
            let mut elems = Vec::with_capacity(ins.operands.len());
            for i in 0..ins.operands.len() {
                elems.push(operand(comp, env, ins, i)?.clone());
            }
            Ok(Literal {
                dims: Vec::new(),
                data: LiteralData::Tuple(elems),
            })
        }
        "get-tuple-element" => {
            let x = operand(comp, env, ins, 0)?;
            let idx = ins
                .attr("index")
                .and_then(|v| v.parse::<usize>().ok())
                .ok_or_else(|| Error::msg(format!("{}: missing tuple index", ins.name)))?;
            match &x.data {
                LiteralData::Tuple(t) => t.get(idx).cloned().ok_or_else(|| {
                    Error::msg(format!("{}: tuple index {idx} out of range", ins.name))
                }),
                _ => Err(Error::msg(format!(
                    "{}: get-tuple-element of non-tuple",
                    ins.name
                ))),
            }
        }
        other => Err(Error::msg(format!(
            "{}: unsupported opcode {other}",
            ins.name
        ))),
    }
}

/// General dot with one contracting dim per side and no batch dims.
fn eval_dot(comp: &Computation, env: &[Option<Literal>], ins: &Instr) -> Result<Literal> {
    let lhs = operand(comp, env, ins, 0)?;
    let rhs = operand(comp, env, ins, 1)?;
    let lc = single_dim(ins, "lhs_contracting_dims")?;
    let rc = single_dim(ins, "rhs_contracting_dims")?;
    if lc >= lhs.dims.len() || rc >= rhs.dims.len() {
        return Err(Error::msg(format!(
            "{}: contracting dims [{lc}]/[{rc}] out of range for {:?}/{:?}",
            ins.name, lhs.dims, rhs.dims
        )));
    }
    if lhs.dims.get(lc) != rhs.dims.get(rc) {
        return Err(Error::msg(format!(
            "{}: contracting dims disagree ({:?}[{lc}] vs {:?}[{rc}])",
            ins.name, lhs.dims, rhs.dims
        )));
    }
    let k = lhs.dims[lc];
    let a = f32s(lhs, &ins.name)?;
    let b = f32s(rhs, &ins.name)?;
    let astr = strides(&lhs.dims);
    let bstr = strides(&rhs.dims);
    let lfree: Vec<usize> = (0..lhs.dims.len()).filter(|&d| d != lc).collect();
    let rfree: Vec<usize> = (0..rhs.dims.len()).filter(|&d| d != rc).collect();
    let lfree_dims: Vec<usize> = lfree.iter().map(|&d| lhs.dims[d]).collect();
    let rfree_dims: Vec<usize> = rfree.iter().map(|&d| rhs.dims[d]).collect();
    let (_, out_dims) = ins.shape.array()?;
    let m: usize = lfree_dims.iter().product();
    let n: usize = rfree_dims.iter().product();
    let mut out = Vec::with_capacity(m * n);
    let mut li = Vec::new();
    let mut ri = Vec::new();
    for lm in 0..m {
        unravel(lm, &lfree_dims, &mut li);
        let abase: usize = lfree.iter().zip(&li).map(|(&d, &i)| i * astr[d]).sum();
        for rn in 0..n {
            unravel(rn, &rfree_dims, &mut ri);
            let bbase: usize = rfree.iter().zip(&ri).map(|(&d, &i)| i * bstr[d]).sum();
            let mut acc = 0f32;
            for kk in 0..k {
                acc += a[abase + kk * astr[lc]] * b[bbase + kk * bstr[rc]];
            }
            out.push(acc);
        }
    }
    if out.len() != out_dims.iter().product::<usize>() {
        return Err(Error::msg(format!(
            "{}: dot produced {} elements for shape {:?}",
            ins.name,
            out.len(),
            out_dims
        )));
    }
    Ok(Literal::from_f32s(out_dims, out))
}

fn single_dim(ins: &Instr, key: &str) -> Result<usize> {
    let dims = ins.attr_dims(key)?;
    if dims.len() != 1 {
        return Err(Error::msg(format!(
            "{}: {} = {:?}; only a single contracting dim is supported",
            ins.name, key, dims
        )));
    }
    Ok(dims[0])
}

/// Reduce over `dimensions` with a monoid region (add/mul/max/min).
fn eval_reduce(
    module: &HloModule,
    comp: &Computation,
    env: &[Option<Literal>],
    ins: &Instr,
) -> Result<Literal> {
    let x = operand(comp, env, ins, 0)?;
    let init_lit = operand(comp, env, ins, 1)?;
    let init = *f32s(init_lit, &ins.name)?.first().ok_or_else(|| {
        Error::msg(format!("{}: reduce init must be a scalar", ins.name))
    })?;
    let red_dims = ins.attr_dims("dimensions")?;
    let target = ins
        .attr_computation("to_apply")
        .ok_or_else(|| Error::msg(format!("{}: reduce without to_apply", ins.name)))?;
    let region = module.computations.get(target).ok_or_else(|| {
        Error::msg(format!("{}: unknown reduce region {target}", ins.name))
    })?;
    let f: fn(f32, f32) -> f32 = match region.instrs[region.root].opcode.as_str() {
        "add" => |a, b| a + b,
        "multiply" => |a, b| a * b,
        "maximum" => f32::max,
        "minimum" => f32::min,
        other => {
            return Err(Error::msg(format!(
                "{}: reduce region {target} applies '{other}'; only \
                 add/multiply/maximum/minimum regions are supported",
                ins.name
            )))
        }
    };
    let xs = f32s(x, &ins.name)?;
    let (_, out_dims) = ins.shape.array()?;
    let keep: Vec<usize> = (0..x.dims.len())
        .filter(|d| !red_dims.contains(d))
        .collect();
    let keep_dims: Vec<usize> = keep.iter().map(|&d| x.dims[d]).collect();
    if keep_dims != out_dims {
        return Err(Error::msg(format!(
            "{}: reduce of {:?} over {:?} gives {:?}, shape says {:?}",
            ins.name, x.dims, red_dims, keep_dims, out_dims
        )));
    }
    let out_n: usize = keep_dims.iter().product();
    let kstr = strides(&keep_dims);
    let mut out = vec![init; out_n.max(1)];
    let mut idx = Vec::new();
    for (lin, &v) in xs.iter().enumerate() {
        unravel(lin, &x.dims, &mut idx);
        let mut off = 0usize;
        for (j, &d) in keep.iter().enumerate() {
            off += idx[d] * kstr[j];
        }
        out[off] = f(out[off], v);
    }
    Ok(Literal::from_f32s(out_dims, out))
}

/// NHWC x HWIO convolution with stride and zero padding
/// (`dim_labels=b01f_01io->b01f`, the layout jax emits for our models).
fn eval_conv(comp: &Computation, env: &[Option<Literal>], ins: &Instr) -> Result<Literal> {
    let x = operand(comp, env, ins, 0)?;
    let w = operand(comp, env, ins, 1)?;
    if let Some(labels) = ins.attr("dim_labels") {
        if labels != "b01f_01io->b01f" {
            return Err(Error::msg(format!(
                "{}: dim_labels {labels} unsupported (only b01f_01io->b01f)",
                ins.name
            )));
        }
    }
    if x.dims.len() != 4 || w.dims.len() != 4 {
        return Err(Error::msg(format!(
            "{}: convolution expects rank-4 operands, got {:?} and {:?}",
            ins.name, x.dims, w.dims
        )));
    }
    let win = Window::parse(ins.attr("window").unwrap_or(""))?;
    let (b, ih, iw, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (kh, kw, kci, co) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
    if kci != ci {
        return Err(Error::msg(format!(
            "{}: kernel input channels {kci} vs input channels {ci}",
            ins.name
        )));
    }
    if win.size != [kh, kw] {
        return Err(Error::msg(format!(
            "{}: window size {:?} vs kernel spatial dims [{kh}, {kw}]",
            ins.name, win.size
        )));
    }
    let (_, out_dims) = ins.shape.array()?;
    let (oh, ow) = (out_dims[1], out_dims[2]);
    let xv = f32s(x, &ins.name)?;
    let wv = f32s(w, &ins.name)?;
    let mut out = Vec::with_capacity(b * oh * ow * co);
    for n in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for c in 0..co {
                    let mut acc = 0f32;
                    for ky in 0..kh {
                        let iy = (oy * win.stride[0] + ky) as isize - win.pad_lo[0] as isize;
                        if iy < 0 || iy as usize >= ih {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix =
                                (ox * win.stride[1] + kx) as isize - win.pad_lo[1] as isize;
                            if ix < 0 || ix as usize >= iw {
                                continue;
                            }
                            for ic in 0..ci {
                                let xi = ((n * ih + iy as usize) * iw + ix as usize) * ci + ic;
                                let wi = ((ky * kw + kx) * ci + ic) * co + c;
                                acc += xv[xi] * wv[wi];
                            }
                        }
                    }
                    out.push(acc);
                }
            }
        }
    }
    Ok(Literal::from_f32s(out_dims, out))
}

/// Parsed `window={size=3x3 stride=2x2 pad=0_1x0_1}` attribute.
struct Window {
    size: [usize; 2],
    stride: [usize; 2],
    pad_lo: [usize; 2],
}

impl Window {
    fn parse(s: &str) -> Result<Window> {
        let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
        let mut size = [1usize, 1];
        let mut stride = [1usize, 1];
        let mut pad_lo = [0usize, 0];
        for part in inner.split_whitespace() {
            let (key, val) = match part.split_once('=') {
                Some(kv) => kv,
                None => continue,
            };
            let fields: Vec<&str> = val.split('x').collect();
            if fields.len() != 2 {
                return Err(Error::msg(format!("window {key}={val}: expected HxW")));
            }
            match key {
                "size" | "stride" => {
                    let mut dims = [0usize; 2];
                    for (i, f) in fields.iter().enumerate() {
                        dims[i] = f.parse::<usize>().map_err(|_| {
                            Error::msg(format!("window {key}: bad value {f}"))
                        })?;
                    }
                    if key == "size" {
                        size = dims;
                    } else {
                        stride = dims;
                    }
                }
                "pad" => {
                    for (i, f) in fields.iter().enumerate() {
                        // `lo_hi`; the high edge is implied by the output
                        // shape, so only the low edge shifts indexing.
                        let lo = f.split('_').next().unwrap_or("0");
                        pad_lo[i] = lo.parse::<usize>().map_err(|_| {
                            Error::msg(format!("window pad: bad value {f}"))
                        })?;
                    }
                }
                _ => {}
            }
        }
        Ok(Window {
            size,
            stride,
            pad_lo,
        })
    }
}

/// `slice={[0:64:2], [0:3]}`-style strided slices.
fn eval_slice(comp: &Computation, env: &[Option<Literal>], ins: &Instr) -> Result<Literal> {
    let x = operand(comp, env, ins, 0)?;
    let spec = ins
        .attr("slice")
        .ok_or_else(|| Error::msg(format!("{}: slice without ranges", ins.name)))?;
    let inner = spec.trim().trim_start_matches('{').trim_end_matches('}');
    let mut ranges = Vec::new();
    for part in inner.split(',') {
        let part = part.trim().trim_start_matches('[').trim_end_matches(']');
        if part.is_empty() {
            continue;
        }
        let nums: Vec<usize> = part
            .split(':')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::msg(format!("{}: bad slice bound {t}", ins.name)))
            })
            .collect::<Result<_>>()?;
        let (start, limit, step) = match nums.as_slice() {
            [s, l] => (*s, *l, 1),
            [s, l, st] => (*s, *l, *st),
            _ => return Err(Error::msg(format!("{}: bad slice range {part}", ins.name))),
        };
        ranges.push((start, limit, step.max(1)));
    }
    if ranges.len() != x.dims.len() {
        return Err(Error::msg(format!(
            "{}: {} slice ranges for rank-{} operand",
            ins.name,
            ranges.len(),
            x.dims.len()
        )));
    }
    let (_, out_dims) = ins.shape.array()?;
    for (d, &(start, limit, step)) in ranges.iter().enumerate() {
        let span = if limit > start {
            (limit - start).div_ceil(step)
        } else {
            0
        };
        if limit > x.dims[d] || span != out_dims[d] {
            return Err(Error::msg(format!(
                "{}: slice range [{start}:{limit}:{step}] invalid for dim {d} \
                 (operand extent {}, result extent {})",
                ins.name, x.dims[d], out_dims[d]
            )));
        }
    }
    let xs = f32s(x, &ins.name)?;
    let xstr = strides(&x.dims);
    let n: usize = out_dims.iter().product();
    let mut idx = Vec::new();
    let mut out = Vec::with_capacity(n);
    for lin in 0..n {
        unravel(lin, out_dims, &mut idx);
        let mut off = 0usize;
        for (d, &(start, _limit, step)) in ranges.iter().enumerate() {
            off += (start + idx[d] * step) * xstr[d];
        }
        out.push(xs[off]);
    }
    Ok(Literal::from_f32s(out_dims, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::HloModuleProto;

    fn run(text: &str, args: &[Literal]) -> Literal {
        let m = HloModuleProto::from_text(text).unwrap();
        check_supported(&m.module).unwrap();
        let refs: Vec<&Literal> = args.iter().collect();
        evaluate_entry(&m.module, &refs).unwrap()
    }

    #[test]
    fn dot_matmul_golden() {
        // [[1,2,3],[4,5,6]] x [[1,0],[0,1],[1,1]] = [[4,5],[10,11]]
        let out = run(
            "HloModule t\nENTRY main {\n\
             x = f32[2,3] parameter(0)\n\
             w = f32[3,2] constant({ { 1, 0 }, { 0, 1 }, { 1, 1 } })\n\
             ROOT d = f32[2,2] dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n",
            &[Literal::from_f32s(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])],
        );
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn reduce_sum_and_max_golden() {
        let text = "HloModule t\n\
            sum {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n\
            \x20 ROOT s = f32[] add(a, b)\n}\n\
            mx {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n\
            \x20 ROOT m = f32[] maximum(a, b)\n}\n\
            ENTRY main {\n  x = f32[2,3] parameter(0)\n  z = f32[] constant(0)\n\
            \x20 neg = f32[] constant(-1e9)\n\
            \x20 rows = f32[2] reduce(x, z), dimensions={1}, to_apply=sum\n\
            \x20 peaks = f32[2] reduce(x, neg), dimensions={1}, to_apply=mx\n\
            \x20 ROOT both = (f32[2], f32[2]) tuple(rows, peaks)\n}\n";
        let out = run(
            text,
            &[Literal::from_f32s(&[2, 3], vec![1.0, -2.0, 3.0, 4.0, 9.0, -6.0])],
        );
        match out.data {
            LiteralData::Tuple(t) => {
                assert_eq!(t[0].to_vec::<f32>().unwrap(), vec![2.0, 7.0]);
                assert_eq!(t[1].to_vec::<f32>().unwrap(), vec![3.0, 9.0]);
            }
            _ => panic!("expected tuple"),
        }
    }

    #[test]
    fn broadcast_golden() {
        // Scalar -> [2,2], and [2] -> [2,2] along each axis.
        let out = run(
            "HloModule t\nENTRY main {\n\
             v = f32[2] parameter(0)\n\
             rows = f32[2,2] broadcast(v), dimensions={0}\n\
             cols = f32[2,2] broadcast(v), dimensions={1}\n\
             ROOT o = (f32[2,2], f32[2,2]) tuple(rows, cols)\n}\n",
            &[Literal::from_f32s(&[2], vec![10.0, 20.0])],
        );
        match out.data {
            LiteralData::Tuple(t) => {
                assert_eq!(
                    t[0].to_vec::<f32>().unwrap(),
                    vec![10.0, 10.0, 20.0, 20.0],
                    "dimensions={{0}}: operand indexes rows"
                );
                assert_eq!(
                    t[1].to_vec::<f32>().unwrap(),
                    vec![10.0, 20.0, 10.0, 20.0],
                    "dimensions={{1}}: operand indexes columns"
                );
            }
            _ => panic!("expected tuple"),
        }
    }

    #[test]
    fn convert_golden() {
        let out = run(
            "HloModule t\nENTRY main {\n\
             x = u8[4] parameter(0)\n\
             ROOT f = f32[4] convert(x)\n}\n",
            &[Literal::from_u8s(&[4], vec![0, 1, 128, 255])],
        );
        assert_eq!(
            out.to_vec::<f32>().unwrap(),
            vec![0.0, 1.0, 128.0, 255.0]
        );
    }

    #[test]
    fn convolution_golden() {
        // 1x4x4x1 input of 1..16, 2x2x1x1 kernel [[1,0],[0,1]], stride 2,
        // no padding: windows {1+6, 3+8, 9+14, 11+16}.
        let out = run(
            "HloModule t\nENTRY main {\n\
             x = f32[1,4,4,1] parameter(0)\n\
             w = f32[2,2,1,1] constant({ { { { 1 } }, { { 0 } } }, { { { 0 } }, { { 1 } } } })\n\
             ROOT c = f32[1,2,2,1] convolution(x, w), window={size=2x2 stride=2x2}, \
             dim_labels=b01f_01io->b01f\n}\n",
            &[Literal::from_f32s(
                &[1, 4, 4, 1],
                (1..=16).map(|v| v as f32).collect(),
            )],
        );
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![7.0, 11.0, 23.0, 27.0]);
    }

    #[test]
    fn convolution_same_padding_golden() {
        // 1x2x2x1 input [[1,2],[3,4]], 3x3 all-ones kernel, stride 1,
        // pad 1_1: every output is the sum of the in-bounds 3x3 window —
        // all four windows cover the whole input => 10 everywhere.
        let out = run(
            "HloModule t\nENTRY main {\n\
             x = f32[1,2,2,1] parameter(0)\n\
             w = f32[3,3,1,1] constant({ { { { 1 } }, { { 1 } }, { { 1 } } }, \
             { { { 1 } }, { { 1 } }, { { 1 } } }, { { { 1 } }, { { 1 } }, { { 1 } } } })\n\
             ROOT c = f32[1,2,2,1] convolution(x, w), \
             window={size=3x3 stride=1x1 pad=1_1x1_1}, dim_labels=b01f_01io->b01f\n}\n",
            &[Literal::from_f32s(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0])],
        );
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn transpose_slice_iota_golden() {
        let out = run(
            "HloModule t\nENTRY main {\n\
             i = f32[6] iota(), iota_dimension=0\n\
             m = f32[2,3] reshape(i)\n\
             tr = f32[3,2] transpose(m), dimensions={1,0}\n\
             ROOT s = f32[2,2] slice(tr), slice={[0:3:2], [0:2]}\n}\n",
            &[],
        );
        // m = [[0,1,2],[3,4,5]]; tr = [[0,3],[1,4],[2,5]]; rows 0 and 2.
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![0.0, 3.0, 2.0, 5.0]);
    }

    #[test]
    fn unsupported_opcode_reported_at_compile() {
        let m = HloModuleProto::from_text(
            "HloModule t\nENTRY main {\n\
             x = f32[2] parameter(0)\n\
             ROOT r = f32[2] tanh(x)\n}\n",
        )
        .unwrap();
        let err = check_supported(&m.module).unwrap_err();
        assert!(format!("{err}").contains("tanh"));
    }

    #[test]
    fn arity_and_shape_mismatches_error() {
        let m = HloModuleProto::from_text(
            "HloModule t\nENTRY main {\n\
             x = f32[4] parameter(0)\n\
             ROOT r = f32[4] add(x, x)\n}\n",
        )
        .unwrap();
        let bad = Literal::from_f32s(&[3], vec![0.0; 3]);
        let refs = vec![&bad];
        assert!(evaluate_entry(&m.module, &refs).is_err());
        assert!(evaluate_entry(&m.module, &[]).is_err());
    }
}
