//! Integration tests: parse the checked-in minimal HLO fixture from
//! disk (the same `from_text_file` path the engine uses) and verify the
//! full parse -> compile -> execute round trip against hand-computed
//! values.

use xla::{ElementType, HloModuleProto, Literal, PjRtClient, XlaComputation};

fn fixture_path() -> String {
    format!(
        "{}/tests/fixtures/min_classifier.hlo.txt",
        env!("CARGO_MANIFEST_DIR")
    )
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

#[test]
fn fixture_roundtrips_through_engine_path() {
    let proto = HloModuleProto::from_text_file(&fixture_path()).unwrap();
    let client = PjRtClient::cpu().unwrap();
    let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();

    // Batch 0: conv = 1*0.5 + 2*(-0.5) + 3*1 + 4*0.25 = 3.5 -> relu 3.5
    // Batch 1: conv = -0.5 + 0 + 0.5 - 0.5 = -0.5          -> relu 0
    // logits = relu * [1, 2, -1] + [0.1, 0.2, 0.3], then / 4.
    let input = [1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 0.5, -2.0];
    let lit = Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        &[2, 2, 2, 1],
        &f32s_to_bytes(&input),
    )
    .unwrap();
    let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap()
        .to_vec::<f32>()
        .unwrap();

    let want = [0.9f32, 1.8, -0.8, 0.025, 0.05, 0.075];
    assert_eq!(out.len(), want.len());
    for (got, expect) in out.iter().zip(&want) {
        assert!(
            (got - expect).abs() < 1e-6,
            "got {got}, expected {expect} (all: {out:?})"
        );
    }
}

#[test]
fn fixture_is_deterministic_across_executions() {
    let proto = HloModuleProto::from_text_file(&fixture_path()).unwrap();
    let client = PjRtClient::cpu().unwrap();
    let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
    let lit = || {
        Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2, 2, 1],
            &f32s_to_bytes(&[0.25; 8]),
        )
        .unwrap()
    };
    let run = |l: Literal| {
        exe.execute::<Literal>(&[l]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap()
    };
    assert_eq!(run(lit()), run(lit()));
}

#[test]
fn wrong_arity_and_shape_surface_as_errors() {
    let proto = HloModuleProto::from_text_file(&fixture_path()).unwrap();
    let client = PjRtClient::cpu().unwrap();
    let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
    assert!(exe.execute::<Literal>(&[]).is_err(), "no args must error");
    let bad = Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        &[2, 2],
        &f32s_to_bytes(&[0.0; 4]),
    )
    .unwrap();
    assert!(
        exe.execute::<Literal>(&[bad]).is_err(),
        "wrong element count must error"
    );
}
